"""ENEAC MoE dispatch: capacity chunks, overflow → fallback, invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI container has no hypothesis; use the vendored shim
    from _propcheck import given, settings, strategies as st

from repro.core import moe_dispatch as md
from repro.core.moe_dispatch import CapacityController

pytestmark = pytest.mark.slow  # property sweep retraces jax per example


def _plan(T=32, E=4, k=2, C=8, seed=0):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (T, E))
    r = md.route_topk(logits, k)
    return md.make_dispatch_plan(r.expert_ids, r.expert_probs, E, C), r


class TestRouting:
    def test_topk_shapes_and_normalization(self):
        r = md.route_topk(jax.random.normal(jax.random.PRNGKey(0), (16, 8)), 3)
        assert r.expert_ids.shape == (16, 3)
        np.testing.assert_allclose(np.sum(np.asarray(r.expert_probs), -1), 1.0,
                                   rtol=1e-5)

    def test_aux_loss_minimal_when_balanced(self):
        # uniform logits ⇒ aux loss ≈ 1 (its minimum for top-1 fraction)
        logits = jnp.zeros((1024, 4))
        r = md.route_topk(logits, 1)
        assert float(r.aux_loss) == pytest.approx(1.0, abs=0.05)


class TestDispatchPlan:
    @given(T=st.integers(1, 64), E=st.integers(1, 8), k=st.integers(1, 3),
           C=st.integers(1, 32), seed=st.integers(0, 100))
    @settings(max_examples=60, deadline=None)
    def test_slot_assignment_invariants(self, T, E, k, C, seed):
        if k > E:
            return
        plan, _ = _plan(T, E, k, C, seed)
        slot = np.asarray(plan.slot_index)
        overflow = np.asarray(plan.overflow).reshape(-1)
        # every non-overflow assignment has a unique slot in range
        live = slot[slot >= 0]
        assert len(np.unique(live)) == len(live)
        assert (live < E * C).all()
        # overflow ⇔ slot == -1
        np.testing.assert_array_equal(slot == -1, overflow)
        # per-expert occupancy ≤ C
        experts = live // C
        for e, cnt in zip(*np.unique(experts, return_counts=True)):
            assert cnt <= C
        # slot table consistency: every filled (e,c) maps back to a token
        st_tok = np.asarray(plan.slot_token)
        valid = np.asarray(plan.slot_valid)
        assert (st_tok[valid] < T).all()
        assert int(valid.sum()) == len(live)

    def test_first_come_first_served_within_expert(self):
        # tokens routed in order; capacity 2 ⇒ tokens 0,1 get slots, 2 spills
        ids = jnp.zeros((3, 1), jnp.int32)
        probs = jnp.ones((3, 1))
        plan = md.make_dispatch_plan(ids, probs, num_experts=2, capacity=2)
        assert not bool(plan.overflow[0, 0])
        assert not bool(plan.overflow[1, 0])
        assert bool(plan.overflow[2, 0])


class TestDispatchCombine:
    def test_roundtrip_no_overflow(self):
        T, E, k, C, d = 16, 4, 2, 16, 8
        plan, r = _plan(T, E, k, C)
        x = jax.random.normal(jax.random.PRNGKey(1), (T, d))
        xe = md.dispatch(x, plan)
        # identity experts + zero fallback ⇒ output = sum_k gate * token = token
        out = md.combine(xe, jnp.zeros((T, d)), plan)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-5)

    def test_overflow_goes_to_fallback(self):
        # capacity 1, all tokens to expert 0 ⇒ token 0 on expert, rest fallback
        T, d = 4, 4
        ids = jnp.zeros((T, 1), jnp.int32)
        probs = jnp.ones((T, 1))
        plan = md.make_dispatch_plan(ids, probs, num_experts=1, capacity=1)
        x = jnp.arange(T * d, dtype=jnp.float32).reshape(T, d)
        xe = md.dispatch(x, plan)
        fb = -jnp.ones((T, d))
        out = md.combine(xe * 0.0, fb, plan)
        np.testing.assert_allclose(np.asarray(out[0]), 0.0)
        np.testing.assert_allclose(np.asarray(out[1:]), -1.0)

    def test_gradients_flow_through_both_paths(self):
        T, E, k, C, d = 8, 2, 1, 2, 4   # tight capacity forces overflow
        plan, _ = _plan(T, E, k, C)

        def f(x, fb_w):
            xe = md.dispatch(x, plan)
            return jnp.sum(md.combine(xe * 2.0, x @ fb_w, plan))

        x = jax.random.normal(jax.random.PRNGKey(2), (T, d))
        w = jnp.eye(d)
        gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
        assert float(jnp.sum(jnp.abs(gx))) > 0
        assert float(jnp.sum(jnp.abs(gw))) > 0  # fallback used ⇒ grads


class TestCapacityController:
    def test_grows_on_overflow(self):
        c = CapacityController(capacity_factor=1.0)
        changed = c.update(overflow_frac=0.3, mean_load=0.9)
        assert changed and c.capacity_factor > 1.0

    def test_shrinks_when_underfull(self):
        c = CapacityController(capacity_factor=2.0)
        changed = c.update(overflow_frac=0.0, mean_load=0.2)
        assert changed and c.capacity_factor < 2.0

    def test_quantized_hysteresis(self):
        c = CapacityController(capacity_factor=1.25, quantum=0.25)
        assert not c.update(overflow_frac=0.021, mean_load=0.8)  # tiny breach

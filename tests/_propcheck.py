"""Dependency-free property-testing shim with a hypothesis-shaped API.

The CI container has no ``hypothesis``; the property tests in this suite
only use a small, well-defined slice of its API (``given`` with keyword
strategies, ``settings(max_examples=..., deadline=...)``, and the
``integers`` / ``floats`` / ``lists`` strategies).  This module provides
that slice over seeded pseudo-random sampling so the same test bodies run
unchanged:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _propcheck import given, settings, strategies as st

Sampling is deterministic per test (seeded from the test's qualified
name), so failures reproduce run-to-run.  On assertion failure the
falsifying example is attached to the exception message, hypothesis-style.
There is no shrinking — examples are reported as drawn.
"""

from __future__ import annotations

import functools
import inspect
import random
import types
import zlib
from typing import Any, Callable, Dict

__all__ = ["given", "settings", "strategies", "st"]

_DEFAULT_MAX_EXAMPLES = 100


class SearchStrategy:
    """A sampler: ``example(rng) -> value``."""

    def __init__(self, sample: Callable[[random.Random], Any], repr_: str) -> None:
        self._sample = sample
        self._repr = repr_

    def example(self, rng: random.Random) -> Any:
        return self._sample(rng)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return self._repr


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: rng.randint(min_value, max_value),
        f"integers({min_value}, {max_value})",
    )


def floats(min_value: float, max_value: float) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: rng.uniform(min_value, max_value),
        f"floats({min_value}, {max_value})",
    )


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.getrandbits(1)), "booleans()")


def sampled_from(options) -> SearchStrategy:
    opts = list(options)
    return SearchStrategy(lambda rng: rng.choice(opts), f"sampled_from({opts!r})")


def lists(elements: SearchStrategy, *, min_size: int = 0, max_size: int = 10) -> SearchStrategy:
    def sample(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]

    return SearchStrategy(sample, f"lists({elements!r}, {min_size}, {max_size})")


strategies = types.SimpleNamespace(
    integers=integers,
    floats=floats,
    booleans=booleans,
    sampled_from=sampled_from,
    lists=lists,
)
st = strategies  # common alias


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Record run parameters on the test; order-independent with ``given``."""

    def deco(fn):
        fn._propcheck_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(**strategy_kwargs: SearchStrategy):
    """Run the test once per drawn example (keyword strategies only)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = (
                getattr(wrapper, "_propcheck_settings", None)
                or getattr(fn, "_propcheck_settings", None)
                or {}
            )
            max_examples = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            for i in range(max_examples):
                rng = random.Random(seed * 1_000_003 + i)
                example: Dict[str, Any] = {
                    name: strat.example(rng)
                    for name, strat in strategy_kwargs.items()
                }
                try:
                    fn(*args, **kwargs, **example)
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{max_examples}): "
                        f"{fn.__qualname__}({example!r})"
                    ) from exc

        # Hide the strategy-bound parameters from pytest so it does not
        # look for fixtures named after them.
        sig = inspect.signature(fn)
        params = [p for n, p in sig.parameters.items() if n not in strategy_kwargs]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper

    return deco

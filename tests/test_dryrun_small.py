"""Small-mesh dry-run: the full lower+compile+analyze pipeline on CPU.

These tests exercise the same code path as the 512-device production
dry-run but on the single real device (mesh 1×1), so the pipeline itself
is covered by every CI run; the production meshes are certified by
``python -m repro.launch.dryrun --all --both-meshes``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.configs.base import InputShape
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import make_model
from repro.optim import AdamW
from repro.parallel.mesh_rules import MeshRules


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestLowerCompile:
    @pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                      "qwen3-moe-30b-a3b"])
    def test_train_step_lowers_smoke(self, arch):
        cfg = get_config(arch).smoke()
        model = make_model(cfg)
        mesh = _mesh()
        rules = MeshRules(mesh, cfg.parallel)
        shape = InputShape("t", 32, 4, "train")
        opt = AdamW()
        bundle = make_train_step(model, opt, rules, shape, loss_chunk=0)
        with mesh:
            compiled = bundle.jit().lower(
                model.abstract_params(),
                opt.abstract_state(model.abstract_params()),
                model.input_specs(shape)["batch"],
            ).compile()
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes > 0
        rep = analyze_hlo(compiled.as_text())
        assert rep.dot_flops > 0

    def test_decode_step_lowers_smoke(self):
        cfg = get_config("recurrentgemma-9b").smoke()
        model = make_model(cfg)
        mesh = _mesh()
        rules = MeshRules(mesh, cfg.parallel)
        shape = InputShape("d", 64, 4, "decode")
        bundle = make_decode_step(model, rules, shape)
        spec = model.input_specs(shape)
        with mesh:
            compiled = bundle.jit().lower(
                model.abstract_params(), spec["tokens"], spec["positions"],
                spec["caches"],
            ).compile()
        assert compiled.memory_analysis().argument_size_in_bytes > 0


class TestHloAnalysis:
    def test_scan_trip_count_correction(self):
        """The analyzer multiplies loop bodies; cost_analysis does not."""
        L, d = 8, 64

        def f(params, x):
            def body(x, w):
                return jnp.tanh(x @ w), None
            y, _ = jax.lax.scan(body, x, params)
            return jnp.sum(y)

        params = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
        x = jax.ShapeDtypeStruct((4, d), jnp.float32)
        compiled = jax.jit(f).lower(params, x).compile()
        rep = analyze_hlo(compiled.as_text())
        analytic = L * 2 * 4 * d * d
        assert rep.dot_flops == pytest.approx(analytic, rel=0.01)
        assert L in rep.trip_counts.values()

    def test_collective_detection(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from jax.sharding import NamedSharding, PartitionSpec as P

        def f(x):
            return jnp.sum(x)

        with mesh:
            compiled = jax.jit(
                f, in_shardings=NamedSharding(mesh, P(None))
            ).lower(jax.ShapeDtypeStruct((64,), jnp.float32)).compile()
        rep = analyze_hlo(compiled.as_text())
        assert rep.collective_bytes >= 0  # no collectives on 1 device

    def test_shape_bytes_parser(self):
        from repro.launch.hlo_analysis import _shape_bytes
        assert _shape_bytes("bf16[2,4]{1,0}") == 16
        assert _shape_bytes("f32[10]") == 40
        assert _shape_bytes("(f32[2], s32[2])") == 16
        assert _shape_bytes("pred[8]") == 8


class TestProductionArtifacts:
    """The committed dry-run artifacts (if present) are coherent."""

    def test_artifacts_cover_all_cells(self):
        import json
        from pathlib import Path
        d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
        files = list(d.glob("*__pod16x16.json")) if d.exists() else []
        if len(files) < 40:
            pytest.skip("production dry-run artifacts not generated yet")
        ok = skip = 0
        for f in files:
            rec = json.loads(f.read_text())
            if rec["status"] == "ok":
                ok += 1
                assert rec["roofline"]["bound_s"] > 0
            else:
                skip += 1
                assert "sub-quadratic" in rec["reason"]
        assert ok + skip == 40

"""Pipeline parallelism: GPipe schedule == sequential oracle.

shard_map needs >1 device, and the device count locks at first jax init,
so this test runs in a subprocess with 8 forced host devices.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"

PROGRAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.parallel.pipeline import pipeline_apply, stage_partition

    mesh = jax.make_mesh((4, 2), ("pod", "model"))
    L, d, n_micro, B = 8, 16, 6, 4
    key = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(key, (L, d, d)) * 0.3,
        "b": jax.random.normal(jax.random.fold_in(key, 1), (L, d)) * 0.1,
    }
    x = jax.random.normal(jax.random.fold_in(key, 2), (n_micro, B, d))

    def layer_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    # sequential oracle
    def oracle(x1):
        y = x1
        for i in range(L):
            y = layer_fn({"w": params["w"][i], "b": params["b"][i]}, y)
        return y
    ref = jnp.stack([oracle(x[i]) for i in range(n_micro)])

    with mesh:
        out = pipeline_apply(params, x, layer_fn, mesh, axis="pod")
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-5, f"pipeline != sequential oracle: {err}"

    # stage partitioning sanity
    assert stage_partition(8, 4) == [(0, 2), (2, 4), (4, 6), (6, 8)]
    assert stage_partition(7, 2) == [(0, 4), (4, 7)]
    print("PIPELINE_OK", err)
""") % str(SRC)


def test_gpipe_matches_sequential():
    res = subprocess.run(
        [sys.executable, "-c", PROGRAM],
        capture_output=True, text=True, timeout=300,
    )
    assert "PIPELINE_OK" in res.stdout, res.stdout + res.stderr

"""HeteroRuntime: the unified scheduler × engine × clock pipeline.

Everything here runs under :class:`SimulatedClock` (virtual time, no
``time.sleep``) except the explicit wall-clock smoke tests, so scheduler
dynamics are deterministic and the whole module runs in well under a
second.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI container has no hypothesis; use the vendored shim
    from _propcheck import given, settings, strategies as st

from repro.core import (
    HeteroRuntime,
    SimulatedClock,
    WallClock,
    WorkerKind,
)
from repro.core.runtime import ENGINES, POLICIES


def make_runtime(n_acc=2, n_cc=2, acc_speed=8e3, cc_speed=1e3, clock=None):
    rt = HeteroRuntime(clock=clock if clock is not None else SimulatedClock())
    for i in range(n_acc):
        rt.register_unit(f"acc{i}", WorkerKind.ACC, speed=acc_speed)
    for i in range(n_cc):
        rt.register_unit(f"cc{i}", WorkerKind.CC, speed=cc_speed)
    return rt


def zipf_costs(n, seed=0, a=1.5, cap=50.0):
    """Heavy-tailed per-item costs — the paper's irregular (SPMM) workload."""
    rng = np.random.default_rng(seed)
    return rng.zipf(a, n).clip(max=cap).astype(float)


def assert_exact_tiling(spans, n_items):
    assert spans, "no chunks completed"
    assert spans[0][0] == 0
    assert spans[-1][1] == n_items
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c, f"gap or overlap at {b}:{c}"


class TestCoverageInvariant:
    """Chunks tile [0, N) exactly — every policy × every engine."""

    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("engine", ENGINES)
    def test_tiling_all_policies_and_engines(self, policy, engine):
        seen = []
        rt = make_runtime()
        rep = rt.parallel_for(
            lambda c: seen.append((c.start, c.stop)),
            997,  # prime: exercises remainders in every splitter
            policy=policy,
            engine=engine,
            acc_chunk=64,
        )
        assert rep.items == 997
        assert_exact_tiling(rep.coverage, 997)
        assert_exact_tiling(sorted(seen), 997)
        assert rep.coverage == sorted(seen)

    @given(
        n_items=st.integers(1, 3000),
        acc_chunk=st.integers(1, 400),
        n_acc=st.integers(1, 3),
        n_cc=st.integers(0, 3),
        acc_speed=st.floats(1.0, 100.0),
        cc_speed=st.floats(0.1, 10.0),
        pick=st.integers(0, 8),
    )
    @settings(max_examples=60, deadline=None)
    def test_tiling_property(self, n_items, acc_chunk, n_acc, n_cc,
                             acc_speed, cc_speed, pick):
        policy = POLICIES[pick % 3]
        engine = ENGINES[pick // 3]
        rt = make_runtime(n_acc, n_cc, acc_speed, cc_speed)
        rep = rt.parallel_for(
            num_items=n_items, policy=policy, engine=engine, acc_chunk=acc_chunk,
        )
        assert rep.items == n_items
        assert rep.chunks == len(rep.coverage)
        assert_exact_tiling(rep.coverage, n_items)


class TestVirtualTime:
    def test_simulated_runs_are_deterministic(self):
        costs = zipf_costs(512)
        reps = [
            make_runtime().parallel_for(
                num_items=512, policy="multidynamic", engine="interrupt",
                acc_chunk=64, item_cost=costs,
            )
            for _ in range(2)
        ]
        assert reps[0].makespan == reps[1].makespan
        assert reps[0].coverage == reps[1].coverage
        assert reps[0].per_worker_items == reps[1].per_worker_items

    def test_interrupt_overlaps_polling_serializes(self):
        # regular workload, equal units: interrupt time ≈ serial time / units
        rt_i = make_runtime(n_acc=4, n_cc=0, acc_speed=1e3)
        rep_i = rt_i.parallel_for(num_items=1024, policy="static",
                                  engine="interrupt")
        rt_p = make_runtime(n_acc=4, n_cc=0, acc_speed=1e3)
        rep_p = rt_p.parallel_for(num_items=1024, policy="static",
                                  engine="polling")
        assert rep_i.makespan == pytest.approx(rep_p.makespan / 4, rel=1e-6)

    def test_utilization_and_makespan_consistency(self):
        rep = make_runtime().parallel_for(
            num_items=2048, policy="multidynamic", engine="interrupt",
            acc_chunk=128, item_cost=zipf_costs(2048, seed=3),
        )
        assert rep.makespan > 0
        for name, u in rep.utilization.items():
            assert 0.0 <= u <= 1.0, (name, u)
        # completion-driven refill keeps every unit nearly saturated
        assert min(rep.utilization.values()) > 0.5
        assert max(rep.per_worker_busy.values()) <= rep.makespan * (1 + 1e-9)

    def test_multidynamic_interrupt_beats_static_polling_on_zipf(self):
        """The paper's headline ablation, in virtual time: adaptive chunking
        + completion-driven offload strictly beats even pre-split +
        busy-wait on an irregular workload."""
        costs = zipf_costs(4096, seed=1)
        rep_md = make_runtime().parallel_for(
            num_items=4096, policy="multidynamic", engine="interrupt",
            acc_chunk=256, item_cost=costs,
        )
        rep_st = make_runtime().parallel_for(
            num_items=4096, policy="static", engine="polling",
            item_cost=costs, poll_interval=1e-5,
        )
        assert rep_md.makespan < rep_st.makespan
        # and the win survives giving the baseline the interrupt engine:
        # adaptation alone beats an even split across unequal units
        rep_si = make_runtime().parallel_for(
            num_items=4096, policy="static", engine="interrupt",
            item_cost=costs,
        )
        assert rep_md.makespan < rep_si.makespan
        assert rep_md.load_balance < rep_si.load_balance


class TestPoliciesAndPlanning:
    def test_oracle_plan_is_throughput_proportional(self):
        rt = HeteroRuntime(clock=SimulatedClock())
        rt.register_unit("fast", WorkerKind.ACC, speed=9.0)
        rt.register_unit("slow", WorkerKind.CC, speed=1.0)
        plan = rt.plan(100, policy="oracle")
        assert plan["fast"] == (0, 90)
        assert plan["slow"] == (90, 100)

    def test_fixed_mapping_policy(self):
        rt = make_runtime(n_acc=1, n_cc=1)
        rep = rt.parallel_for(
            num_items=100,
            policy={"acc0": (0, 64), "cc0": (64, 100)},
            engine="inline",
        )
        assert rep.per_worker_items == {"acc0": 64, "cc0": 36}
        assert_exact_tiling(rep.coverage, 100)

    def test_multidynamic_favours_fast_units(self):
        rep = make_runtime(acc_speed=1e4, cc_speed=1e3).parallel_for(
            num_items=2048, policy="multidynamic", engine="interrupt",
            acc_chunk=128,
        )
        assert rep.per_worker_items["acc0"] > rep.per_worker_items["cc0"]

    def test_unknown_policy_engine_rejected(self):
        rt = make_runtime()
        with pytest.raises(ValueError):
            rt.parallel_for(num_items=10, policy="nope")
        with pytest.raises(ValueError):
            rt.parallel_for(num_items=10, engine="nope")
        with pytest.raises(ValueError):
            rt.parallel_for(num_items=0)

    def test_duplicate_unit_rejected(self):
        rt = make_runtime()
        with pytest.raises(ValueError):
            rt.register_unit("acc0", WorkerKind.ACC)

    def test_num_items_passed_positionally_is_caught(self):
        rt = make_runtime()
        with pytest.raises(TypeError, match="num_items"):
            rt.parallel_for(4096, policy="static")

    def test_zero_speed_unit_models_a_stall(self):
        rt = HeteroRuntime(clock=SimulatedClock())
        rt.register_unit("live", WorkerKind.ACC, speed=10.0)
        rt.register_unit("stalled", WorkerKind.CC, speed=0.0)
        # oracle gives a zero-throughput unit no work…
        assert "stalled" not in rt.plan(100, policy="oracle")
        # …and an even split prices its share near-infinitely, not at the
        # 1.0 items/s default
        rep = rt.parallel_for(num_items=100, policy="static", engine="interrupt")
        assert rep.makespan > 1e10


class TestWorkQueue:
    def test_unit_chunks_cover_space_in_order(self):
        rt = make_runtime(n_acc=3, n_cc=0)
        feed = rt.work_queue(7, acc_chunk=1)
        order = []
        # completion-driven refill: free units always take the next index
        outstanding = {}
        while True:
            for name in list(feed.idle_units):
                chunk = feed.acquire(name)
                if chunk is not None:
                    assert chunk.size == 1
                    order.append(chunk.start)
                    outstanding[name] = chunk
            if not outstanding:
                break
            done = sorted(outstanding)[0]
            outstanding.pop(done)
            feed.complete(done)
        assert order == list(range(7))
        rep = feed.report()
        assert rep.items == 7
        assert_exact_tiling(rep.coverage, 7)


@pytest.mark.slow
class TestWallBackendMakespanGuard:
    """Flake guard (ISSUE 4): real backends must track the *scheduled*
    makespan.  The same Zipf workload is priced in virtual time under
    SimulatedClock and then executed for real with sleep-calibrated
    work functions; the wall makespan may not regress the scheduled one
    by more than 10% — pinning that the event-driven engine's dispatch
    overhead and thread wakeups stay in the noise for both the
    overlapping (threads) and serial (inline) backends.
    """

    SPEEDS = {"acc0": 5e3, "acc1": 5e3, "cc0": 1250.0, "cc1": 1250.0}

    def _runtime(self, prefix, clock=None):
        import time as _time

        rt = HeteroRuntime(clock=clock)
        for name, speed in self.SPEEDS.items():
            kind = WorkerKind.ACC if name.startswith("acc") else WorkerKind.CC

            def fn(chunk, speed=speed):
                _time.sleep((prefix[chunk.stop] - prefix[chunk.start]) / speed)

            rt.register_unit(name, kind, speed=speed, work_fn=fn)
        return rt

    @pytest.mark.parametrize("backend,sim_engine", [
        ("threads", "interrupt"),   # real overlap vs event-heap replay
        ("inline", "inline"),       # serial backend vs serial replay
    ])
    def test_zipf_makespan_within_band(self, backend, sim_engine):
        n = 512
        costs = zipf_costs(n, seed=7)
        prefix = np.concatenate([[0.0], np.cumsum(costs)])
        scheduled = self._runtime(prefix, clock=SimulatedClock()).parallel_for(
            num_items=n, policy="multidynamic", engine=sim_engine,
            acc_chunk=64, item_cost=costs,
        )
        real = self._runtime(prefix).parallel_for(
            num_items=n, policy="multidynamic", engine="interrupt",
            acc_chunk=64, backend=backend,
        )
        assert real.items == scheduled.items == n
        ratio = real.makespan / scheduled.makespan
        assert ratio <= 1.10, (
            f"{backend} backend regressed scheduled makespan by "
            f"{(ratio - 1) * 100:.1f}% ({real.makespan:.3f}s vs "
            f"{scheduled.makespan:.3f}s scheduled)"
        )
        # sleeps cannot finish early either: a large shortfall would mean
        # the engine lost work, not that it got faster
        assert ratio >= 0.90, (backend, ratio)


class TestWallClock:
    def test_inline_engine_runs_real_work(self):
        rt = HeteroRuntime(clock=WallClock())
        done = []
        rt.register_unit("a", WorkerKind.ACC, work_fn=lambda c: done.append(c.size))
        rep = rt.parallel_for(num_items=100, policy="multidynamic",
                              engine="inline", acc_chunk=32)
        assert sum(done) == 100
        assert rep.items == 100

    def test_missing_work_fn_rejected_on_wall_clock(self):
        rt = HeteroRuntime()
        rt.register_unit("a", WorkerKind.ACC)
        with pytest.raises(ValueError):
            rt.parallel_for(num_items=10)

    def test_item_cost_rejected_on_wall_clock(self):
        rt = HeteroRuntime()
        rt.register_unit("a", WorkerKind.ACC, work_fn=lambda c: None)
        with pytest.raises(ValueError):
            rt.parallel_for(num_items=10, item_cost=[1.0] * 10)

import os

# Tests run on the single real CPU device (NOT the 512-device dry-run
# override — that env var belongs exclusively to launch/dryrun.py).
# A small deterministic platform config keeps CI stable.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

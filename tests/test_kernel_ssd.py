"""SSD scan kernel vs the chunked-oracle (which is itself decode-validated)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.ops import ssd_ref, ssd_scan

KEY = jax.random.PRNGKey(0)


def _inputs(b, s, h, p, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    # realistic decays: log_a in [-0.2, 0)
    log_a = -0.2 * jax.random.uniform(ks[1], (b, s, h))
    Bm = jax.random.normal(ks[2], (b, s, n)) * 0.3
    Cm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    return x, log_a, Bm, Cm


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 3, 8, 16, 32),
    (1, 96, 1, 32, 32, 32),
])
def test_kernel_matches_oracle(b, s, h, p, n, chunk):
    x, log_a, Bm, Cm = _inputs(b, s, h, p, n, seed=s)
    y_ref, h_ref = ssd_ref(x, log_a, Bm, Cm, chunk)
    y, h_f = ssd_scan(x, log_a, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_f), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_chunk_invariance():
    """The chunked algorithm computes the same sequence map for any chunk."""
    x, log_a, Bm, Cm = _inputs(1, 64, 2, 8, 8, seed=3)
    y16, _ = ssd_scan(x, log_a, Bm, Cm, chunk=16)
    y32, _ = ssd_scan(x, log_a, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y32),
                               rtol=2e-4, atol=2e-4)

"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + finiteness (assignment deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, all_configs, get_config
from repro.configs.base import ParallelConfig
from repro.models import make_model

pytestmark = pytest.mark.slow  # full per-arch sweep; gated out of the fast tier

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
    batch = {
        "tokens": toks[:, :S],
        "labels": toks[:, 1:],
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.full((B, cfg.encoder_seq, cfg.d_model), 0.1, jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.full(
            (B, cfg.num_image_tokens, cfg.d_model), 0.1, jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
class TestArchSmoke:
    def test_forward_shapes_and_finiteness(self, arch):
        cfg = get_config(arch).smoke()
        model = make_model(cfg)
        params = model.init(KEY)
        B, S = 2, 16
        batch = _batch(cfg, B, S)
        hidden, _, aux = model.forward(params, batch, mode="train")
        assert hidden.shape == (B, S, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))
        logits = model.logits(params, hidden)
        assert logits.shape == (B, S, cfg.padded_vocab)

    def test_train_step_loss_and_grads_finite(self, arch):
        cfg = get_config(arch).smoke()
        model = make_model(cfg)
        params = model.init(KEY)
        batch = _batch(cfg)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch, loss_chunk=0), has_aux=True
        )(params)
        assert np.isfinite(float(loss))
        # random init on 256-vocab: loss near ln(256)
        assert 3.0 < float(metrics["ce_loss"]) < 8.0
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    def test_decode_matches_teacher_forcing(self, arch):
        """Prefill + one decode step == full forward at high capacity."""
        cfg = get_config(arch).smoke()
        if cfg.family == "moe":
            cfg = cfg.replace(parallel=ParallelConfig(capacity_factor=8.0))
        model = make_model(cfg)
        params = model.init(KEY)
        B, S = 2, 8
        toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :S]}
        if cfg.family == "encdec":
            batch["frames"] = jnp.full((B, cfg.encoder_seq, cfg.d_model), 0.1,
                                       jnp.float32)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.full(
                (B, cfg.num_image_tokens, cfg.d_model), 0.1, jnp.float32)
        _, caches = model.prefill(params, batch, max_len=S + 4)
        logits_dec, _ = model.decode_step(
            params, toks[:, S:S + 1], jnp.full((B, 1), S, jnp.int32), caches)
        full = dict(batch)
        full["tokens"] = toks
        hidden, _, _ = model.forward(params, full, mode="train")
        oracle = model.logits(params, hidden)[:, -1, :]
        np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(oracle),
                                   atol=2e-3, rtol=2e-2)


class TestConfigExactness:
    """The full configs carry the assignment's exact dimensions."""

    EXPECT = {
        "stablelm-12b": dict(num_layers=40, d_model=5120, num_heads=32,
                             num_kv_heads=8, d_ff=13824, vocab_size=100352),
        "tinyllama-1.1b": dict(num_layers=22, d_model=2048, num_heads=32,
                               num_kv_heads=4, d_ff=5632, vocab_size=32000),
        "qwen3-14b": dict(num_layers=40, d_model=5120, num_heads=40,
                          num_kv_heads=8, d_ff=17408, vocab_size=151936,
                          qk_norm=True),
        "llama3.2-3b": dict(num_layers=28, d_model=3072, num_heads=24,
                            num_kv_heads=8, d_ff=8192, vocab_size=128256),
        "whisper-large-v3": dict(num_layers=32, encoder_layers=32, d_model=1280,
                                 num_heads=20, num_kv_heads=20, d_ff=5120,
                                 vocab_size=51866),
        "mamba2-130m": dict(num_layers=24, d_model=768, vocab_size=50280,
                            ssm_state=128),
        "grok-1-314b": dict(num_layers=64, d_model=6144, num_heads=48,
                            num_kv_heads=8, d_ff=32768, vocab_size=131072,
                            num_experts=8, experts_per_token=2),
        "qwen3-moe-30b-a3b": dict(num_layers=48, d_model=2048, num_heads=32,
                                  num_kv_heads=4, vocab_size=151936,
                                  num_experts=128, experts_per_token=8,
                                  moe_d_ff=768),
        "llama-3.2-vision-90b": dict(num_layers=100, d_model=8192, num_heads=64,
                                     num_kv_heads=8, d_ff=28672,
                                     vocab_size=128256, cross_attn_every=5),
        "recurrentgemma-9b": dict(num_layers=38, d_model=4096, num_heads=16,
                                  num_kv_heads=1, d_ff=12288, vocab_size=256000,
                                  window=2048),
    }

    @pytest.mark.parametrize("arch", ARCH_NAMES)
    def test_dims(self, arch):
        cfg = get_config(arch)
        for k, v in self.EXPECT[arch].items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"

    def test_all_ten_archs_registered(self):
        assert len(ARCH_NAMES) == 10

    @pytest.mark.parametrize("arch", ARCH_NAMES)
    def test_param_spec_tree_matches_param_tree(self, arch):
        cfg = get_config(arch).smoke()
        model = make_model(cfg)
        specs = model.param_specs()
        abstract = model.abstract_params()
        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x)
        flat_s = jax.tree_util.tree_flatten(specs, is_leaf=is_axes)[0]
        flat_a = jax.tree_util.tree_leaves(abstract)
        assert len(flat_s) == len(flat_a)
        for s, a in zip(flat_s, flat_a):
            assert len(s) == len(a.shape)

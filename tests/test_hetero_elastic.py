"""Hetero partitioner, straggler mitigation, elastic rescale."""

import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI container has no hypothesis; use the vendored shim
    from _propcheck import given, settings, strategies as st

from repro.core import (
    ElasticMeshManager,
    HeterogeneousPartitioner,
    StragglerMitigator,
)
from repro.core.hetero import HeterogeneousPartitioner as HP


class TestPartitioner:
    @given(
        total=st.integers(4, 512),
        tps=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=8),
    )
    @settings(max_examples=80, deadline=None)
    def test_counts_sum_exactly(self, total, tps):
        groups = {f"g{i}": t for i, t in enumerate(tps)}
        if total < len(groups):
            return
        p = HeterogeneousPartitioner().proportional(total, groups)
        assert p.total == total
        assert all(v >= 1 for v in p.counts.values())
        assert abs(sum(p.weights.values()) - 1.0) < 1e-9

    def test_proportionality(self):
        p = HeterogeneousPartitioner().proportional(
            100, {"fast": 3.0, "slow": 1.0}
        )
        assert p.counts["fast"] > 2.5 * p.counts["slow"]

    def test_hysteresis_suppresses_noise(self):
        hp = HeterogeneousPartitioner(rebalance_threshold=0.3)
        p1 = hp.update(64, {"a": 1.0, "b": 1.0})
        p2 = hp.update(64, {"a": 1.05, "b": 0.98})   # noise
        assert p2 is p1
        p3 = hp.update(64, {"a": 3.0, "b": 1.0})     # real shift
        assert p3 is not p1

    def test_predicted_step_time_improves(self):
        tps = {"a": 2.0, "b": 1.0, "c": 1.0, "d": 0.5}
        uniform = HP.uniform(32, list(tps))
        prop = HeterogeneousPartitioner().proportional(32, tps)
        assert HP.step_time(prop, tps) < HP.step_time(uniform, tps)


class TestStragglerMitigation:
    def test_detects_persistent_straggler_only(self):
        m = StragglerMitigator(["g0", "g1", "g2", "g3"], total_microbatches=32)
        # one transient slow step: no plan
        assert m.step({"g0": 1.0, "g1": 1.0, "g2": 1.0, "g3": 2.5}) is None
        plan = None
        for _ in range(6):
            plan = m.step({"g0": 1.0, "g1": 1.0, "g2": 1.0, "g3": 2.5}) or plan
        assert plan is not None
        assert plan.partition.counts["g3"] < plan.partition.counts["g0"]
        assert plan.predicted_speedup > 1.0

    def test_no_false_positive_on_homogeneous_fleet(self):
        m = StragglerMitigator(["g0", "g1"], total_microbatches=8)
        for _ in range(10):
            assert m.step({"g0": 1.0, "g1": 1.02}) is None


class TestElastic:
    def test_intact_mesh_no_plan(self):
        e = ElasticMeshManager((2, 16, 16), ("pod", "data", "model"))
        assert e.plan() is None

    def test_host_failure_takes_8_chips_and_shrinks_dp(self):
        e = ElasticMeshManager((2, 16, 16), ("pod", "data", "model"))
        e.mark_failed(17)
        plan = e.plan()
        assert plan is not None
        assert len(plan.lost_devices) == 8          # whole host fails
        assert plan.new_shape[2] == 16              # model axis sacred
        assert plan.new_device_count <= len(plan.healthy_devices) + 8
        assert plan.dp_scale < 1.0

    def test_miss_threshold(self):
        e = ElasticMeshManager((16, 16), ("data", "model"), miss_threshold=3)
        e.miss(0); e.miss(0)
        assert e.plan() is None
        e.miss(0)
        assert e.plan() is not None

    def test_heartbeat_resets_misses(self):
        e = ElasticMeshManager((16, 16), ("data", "model"), miss_threshold=2)
        e.miss(5)
        e.heartbeat(5)
        e.miss(5)
        assert e.plan() is None

    def test_model_axis_unsatisfiable_raises(self):
        e = ElasticMeshManager((1, 16), ("data", "model"), host_size=8)
        for d in range(0, 16, 8):
            e.mark_failed(d)
        with pytest.raises(RuntimeError):
            e.plan()

    def test_apply_adopts_new_shape(self):
        e = ElasticMeshManager((2, 16, 16), ("pod", "data", "model"))
        e.mark_failed(0)
        plan = e.plan()
        e.apply(plan)
        assert e.shape == plan.new_shape

"""Optimizer, data pipeline, checkpointing, compression, serving."""

import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import MemmapTokens, Prefetcher, SyntheticTokens
from repro.checkpoint import Checkpointer
from repro.models import make_model
from repro.optim import AdamW, clip_by_global_norm, warmup_cosine
from repro.optim.compression import CompressionState, ef_compress_tree, init_state
from repro.serving import Request, ServingEngine

pytestmark = pytest.mark.slow  # end-to-end substrate tier (model init + serving)


class TestAdamW:
    def test_quadratic_convergence(self):
        opt = AdamW(weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            upd, state = opt.update(g, state, params, 0.1)
            params = AdamW.apply_updates(params, upd)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.05

    def test_bf16_state_dtype(self):
        opt = AdamW(state_dtype=jnp.bfloat16)
        state = opt.init({"w": jnp.zeros((4,), jnp.bfloat16)})
        assert state.mu["w"].dtype == jnp.bfloat16

    def test_weight_decay_only_on_matrices(self):
        opt = AdamW(weight_decay=0.5)
        params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
        state = opt.init(params)
        zero_g = jax.tree.map(jnp.zeros_like, params)
        upd, _ = opt.update(zero_g, state, params, 1.0)
        assert float(jnp.max(jnp.abs(upd["w"]))) > 0      # decayed
        assert float(jnp.max(jnp.abs(upd["b"]))) == 0     # not decayed

    def test_clip(self):
        tree = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) > 1.0
        import math
        assert math.isclose(
            float(jnp.linalg.norm(clipped["a"])), 1.0, rel_tol=1e-5)

    def test_schedule(self):
        lr = warmup_cosine(jnp.asarray(5), peak_lr=1e-3, warmup_steps=10,
                           total_steps=100)
        assert float(lr) == pytest.approx(5e-4)


class TestData:
    def test_synthetic_deterministic(self):
        s = SyntheticTokens(1000, 32)
        b1 = s.batch(3, 0, 4, 2)
        b2 = s.batch(3, 0, 4, 2)
        np.testing.assert_array_equal(b1.tokens, b2.tokens)
        b3 = s.batch(3, 1, 4, 2)
        assert not np.array_equal(b1.tokens, b3.tokens)

    def test_labels_are_next_tokens(self):
        s = SyntheticTokens(1000, 16)
        b = s.batch(0, 0, 1, 2)
        assert b.tokens.shape == b.labels.shape == (2, 16)

    def test_memmap_roundtrip(self, tmp_path):
        corpus = np.arange(10_000, dtype=np.int32) % 512
        path = tmp_path / "tokens.bin"
        MemmapTokens.write_corpus(path, corpus)
        src = MemmapTokens(path, seq_len=32)
        b = src.batch(0, 0, 2, 3)
        assert b.tokens.shape == (3, 32)
        # windows are contiguous corpus slices
        row = b.tokens[0]
        assert ((np.diff(row) == 1) | (np.diff(row) == 1 - 512)).all()

    def test_prefetcher_orders_and_closes(self):
        made = []
        p = Prefetcher(lambda s: made.append(s) or s * 10, depth=2)
        steps = [p.get()[1] for _ in range(5)]
        p.close()
        assert steps == [0, 10, 20, 30, 40]

    def test_prefetcher_propagates_errors(self):
        def boom(step):
            if step == 1:
                raise ValueError("bad shard")
            return step
        p = Prefetcher(boom, depth=1)
        p.get()
        with pytest.raises(ValueError):
            p.get()
            p.get()
        p.close()


class TestCheckpointer:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {
            "layer": {"w": jax.random.normal(k, (8, 4)),
                      "b": jnp.zeros((4,))},
            "step_count": jnp.asarray(7, jnp.int32),
        }

    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(tmp_path)
        tree = self._tree()
        ck.save(10, tree, blocking=True)
        like = jax.tree.map(np.asarray, tree)
        restored, step = ck.restore(None, like)
        assert step == 10
        np.testing.assert_allclose(restored["layer"]["w"],
                                   np.asarray(tree["layer"]["w"]))

    def test_async_save_completion_event(self, tmp_path):
        ck = Checkpointer(tmp_path)
        done = ck.save(1, self._tree())
        info = done.wait(timeout=30)
        assert info.step == 1
        assert (info.path / "manifest.json").exists()

    def test_gc_keeps_newest(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, self._tree(), blocking=True)
        assert ck.latest_step() == 4
        steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
        assert steps == [3, 4]

    def test_corruption_detected(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(5, self._tree(), blocking=True)
        victim = next((tmp_path / "step_00000005").glob("arr_*.npy"))
        arr = np.load(victim)
        np.save(victim, arr + 1.0)
        with pytest.raises(IOError):
            ck.restore(None, jax.tree.map(np.asarray, self._tree()))

    def test_incomplete_checkpoint_ignored(self, tmp_path):
        ck = Checkpointer(tmp_path)
        ck.save(1, self._tree(), blocking=True)
        # a torn write: directory without manifest
        (tmp_path / "step_00000009").mkdir()
        assert ck.latest_step() == 1


class TestCompression:
    def test_error_feedback_preserves_sum(self):
        """EF invariant: Σ_t transmitted_t + residual_T = Σ_t grad_t."""
        key = jax.random.PRNGKey(0)
        grads = [{"w": 0.01 * jax.random.normal(jax.random.fold_in(key, i), (64,))}
                 for i in range(20)]
        state = init_state(grads[0])
        sent_total = jnp.zeros((64,))
        for g in grads:
            sent, state = ef_compress_tree(g, state)
            sent_total = sent_total + sent["w"]
        true_total = sum(g["w"] for g in grads)
        drift = sent_total + state.residual["w"] - true_total
        assert float(jnp.max(jnp.abs(drift))) < 1e-5

    def test_compression_is_int8_range(self):
        from repro.optim.compression import compress, decompress
        x = jax.random.normal(jax.random.PRNGKey(1), (128,)) * 3
        q, s = compress(x)
        assert q.dtype == jnp.int8
        rel = float(jnp.max(jnp.abs(decompress(q, s) - x)) / jnp.max(jnp.abs(x)))
        assert rel < 0.02


class TestServing:
    @pytest.fixture(scope="class")
    def model_and_params(self):
        cfg = get_config("tinyllama-1.1b").smoke()
        m = make_model(cfg)
        return cfg, m, m.init(jax.random.PRNGKey(0))

    def _requests(self, cfg, n=8, seed=0):
        rng = np.random.default_rng(seed)
        return [
            Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(2, 8))).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 12)))
            for i in range(n)
        ]

    def test_all_requests_complete_exact_lengths(self, model_and_params):
        cfg, m, params = model_and_params
        reqs = self._requests(cfg)
        eng = ServingEngine(m, params, slots=3, max_len=48, mode="continuous")
        for r in reqs:
            eng.submit(r)
        res = eng.run()
        assert len(res) == len(reqs)
        for r in reqs:
            assert len(res[r.rid].tokens) == r.max_new_tokens

    def test_continuous_no_worse_than_static(self, model_and_params):
        cfg, m, params = model_and_params
        outcomes = {}
        for mode in ("static", "continuous"):
            eng = ServingEngine(m, params, slots=4, max_len=48, mode=mode)
            for r in self._requests(cfg, n=10, seed=1):
                eng.submit(r)
            eng.run()
            outcomes[mode] = eng.throughput_report()
        assert (outcomes["continuous"]["tokens_per_step"]
                >= outcomes["static"]["tokens_per_step"])
        assert outcomes["continuous"]["tokens"] == outcomes["static"]["tokens"]

    def test_deterministic_greedy_generation(self, model_and_params):
        cfg, m, params = model_and_params
        outs = []
        for _ in range(2):
            eng = ServingEngine(m, params, slots=2, max_len=48)
            for r in self._requests(cfg, n=4, seed=2):
                eng.submit(r)
            res = eng.run()
            outs.append({k: tuple(v.tokens) for k, v in res.items()})
        assert outs[0] == outs[1]

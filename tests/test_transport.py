"""Multi-host transport backends (ISSUE 5).

The contract under test: a :class:`~repro.core.transport.RemoteUnit`
driving a :class:`~repro.core.transport.RemoteWorker` across a message
transport behaves like any other backend unit — and keeps behaving like
one when the *medium* misbehaves:

* completions pumped back over the transport land on the local
  ``CompletionBus`` and tile the space exactly,
* the seq/retransmit/dedup protocol survives seeded drop / delay /
  duplicate / reorder injection (``FlakyTransport``) with **exact-once
  work-function side effects** — parity with inline execution — across
  ≥20 random seeds, with monotone event times,
* a definitive connection loss requeues the in-flight chunk to the
  survivors (an ``action="lost"`` event) instead of hanging or failing
  the run,
* real ``SocketTransport`` worker *subprocesses* behind a
  ``ShardedSpace(placement=...)`` produce byte-identical results versus
  ``backend="inline"`` (the ISSUE's acceptance line),
* ``RunReport.dispatch_latency`` is split: ``wire_latency`` carries the
  send→remote-execution-start component for remote units.

Loopback tests pass frames by reference (shared side-effect ledgers);
socket tests exercise the length-prefixed pickle codec and cross-process
execution for real.  CI's ``transport`` job runs this module under the
hang-killing ``tools/run_with_timeout.py``.
"""

import os
import socket
import threading
import time
from collections import Counter

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI container has no hypothesis; use the vendored shim
    from _propcheck import given, settings, strategies as st

from repro.core import (
    ElasticSchedule,
    FlakyTransport,
    HeteroRuntime,
    LoopbackTransport,
    RemoteUnit,
    RemoteWorker,
    ShardedSpace,
    SocketTransport,
    TransportClosed,
    TransportError,
    WorkerKind,
    WorkerServer,
)
from repro.core.backends import CompletionBus, make_backend
from repro.core.runtime import POLICIES
from repro.core.scheduler import Chunk
from repro.core.transport import (
    AUTO_BATCH_MAX,
    FrameDecoder,
    SleepWork,
    encode_frame,
    spawn_worker,
)


def assert_exact_tiling(spans, n_items):
    assert spans, "no chunks completed"
    assert spans[0][0] == 0
    assert spans[-1][1] == n_items
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c, f"gap or overlap at {b}:{c}"


class Recorder:
    """Thread-safe exact-once ledger (shared by reference over loopback)."""

    def __init__(self, per_item_sleep=0.0):
        self.lock = threading.Lock()
        self.counts = Counter()
        self.per_item_sleep = per_item_sleep

    def __call__(self, chunk):
        if self.per_item_sleep:
            time.sleep(chunk.size * self.per_item_sleep)
        with self.lock:
            self.counts.update(chunk.indices())

    def assert_exactly_once(self, n_items):
        assert set(self.counts) == set(range(n_items)), (
            f"missing {sorted(set(range(n_items)) - set(self.counts))[:5]}..."
        )
        dupes = {i: c for i, c in self.counts.items() if c != 1}
        assert not dupes, f"indices executed more than once: {dupes}"


def start_loopback_worker(*, flaky_seed=None, **faults):
    """(client endpoint, worker, serve thread) over an in-process pair."""
    client_end, worker_end = LoopbackTransport.pair()
    client_side, worker_side = client_end, worker_end
    if flaky_seed is not None:
        client_side = FlakyTransport(client_end, seed=flaky_seed, **faults)
        worker_side = FlakyTransport(worker_end, seed=flaky_seed + 1, **faults)
    worker = RemoteWorker(worker_side, poll_interval=0.05)
    t = threading.Thread(target=worker.serve, daemon=True)
    t.start()
    return client_side, worker, t


def loopback_unit(name, *, flaky_seed=None, retry_interval=0.02,
                  max_retries=600, batch_frames=1, fn_cache=True, **faults):
    client_side, worker, _t = start_loopback_worker(
        flaky_seed=flaky_seed, **faults)
    return RemoteUnit(name, transport=client_side,
                      retry_interval=retry_interval, max_retries=max_retries,
                      batch_frames=batch_frames, fn_cache=fn_cache)


class FrameTap:
    """Pass-through transport recording every frame sent through it."""

    def __init__(self, inner):
        self.inner = inner
        self.sent = []
        self._lock = threading.Lock()

    def send(self, frame):
        with self._lock:
            self.sent.append(frame)
        self._forward(frame)

    def _forward(self, frame):
        """Override to drop/mangle frames (still recorded in .sent)."""
        self.inner.send(frame)

    def recv(self, timeout=None):
        return self.inner.recv(timeout)

    def close(self):
        self.inner.close()

    @property
    def closed(self):
        return self.inner.closed

    def kinds(self):
        with self._lock:
            return Counter(f.get("kind") for f in self.sent)

    def frames(self, kind):
        with self._lock:
            return [f for f in self.sent if f.get("kind") == kind]


def tapped_loopback_unit(name, *, batch_frames=1, fn_cache=True,
                         tap_cls=FrameTap, **kw):
    """A clean-medium loopback unit whose client->worker frames are
    recorded in (and optionally filtered by) the returned FrameTap."""
    client_end, worker_end = LoopbackTransport.pair()
    worker = RemoteWorker(worker_end, poll_interval=0.02)
    threading.Thread(target=worker.serve, daemon=True).start()
    tap = tap_cls(client_end)
    unit = RemoteUnit(name, transport=tap, retry_interval=0.05,
                      max_retries=200, batch_frames=batch_frames,
                      fn_cache=fn_cache, **kw)
    return unit, tap, worker


# ---------------------------------------------------------------------------
# frame codec
# ---------------------------------------------------------------------------
class TestFrameCodec:
    def test_roundtrip_single_frame(self):
        frame = {"kind": "submit", "seq": 7, "chunk": Chunk(3, 9, "u0"),
                 "payload": list(range(10))}
        dec = FrameDecoder()
        (out,) = dec.feed(encode_frame(frame))
        assert out == frame

    def test_incremental_feed_any_segmentation(self):
        frames = [{"kind": "done", "seq": i, "blob": b"x" * (i * 13)}
                  for i in range(6)]
        stream = b"".join(encode_frame(f) for f in frames)
        for step in (1, 2, 3, 5, 7, 64, len(stream)):
            dec = FrameDecoder()
            out = []
            for i in range(0, len(stream), step):
                out.extend(dec.feed(stream[i:i + step]))
            assert out == frames, f"segmentation step={step} corrupted frames"

    def test_corrupt_header_raises(self):
        dec = FrameDecoder()
        with pytest.raises(TransportError, match="corrupt"):
            dec.feed(b"\xff\xff\xff\xff garbage")

    def test_unpicklable_payload_becomes_poison_frame(self):
        # a payload that pickled fine on the sender but cannot unpickle
        # here (e.g. a work_fn from a module this process cannot import)
        # must not kill the session: the decoder yields an ignorable
        # poison frame and the stream stays aligned for frames after it
        import struct

        good = {"kind": "done", "seq": 1}
        payload = b"cno_such_module_xyz\nGhost\n."  # GLOBAL opcode, bad module
        data = struct.pack(">I", len(payload)) + payload
        dec = FrameDecoder()
        out = dec.feed(data + encode_frame(good))
        assert out[0]["kind"] == "undecodable"
        assert out[1] == good


def _random_batched_frame(rng):
    """A randomized fast-path frame (work_batch / done_batch / singletons)."""
    kind = rng.choice(["work_batch", "done_batch", "submit", "register_fn"])
    frame = {"kind": kind, "unit": f"u{rng.randrange(4)}"}
    if kind in ("work_batch", "submit"):
        frame["floor"] = rng.randrange(64)
    if kind == "register_fn":
        frame["fn_id"] = f"h:{rng.getrandbits(64):016x}"
        frame["fn"] = SleepWork(rng.random() * 1e-6)
        return frame
    items = []
    for i in range(rng.randint(1, 8)):
        start = rng.randrange(1000)
        items.append({
            "seq": rng.randrange(512),
            "chunk": Chunk(start, start + rng.randint(1, 32), frame["unit"]),
            "t_submit": rng.random(),
            "blob": bytes(rng.getrandbits(8)
                          for _ in range(rng.randint(0, 300))),
        })
    if kind == "submit":
        frame.update(items[0])
    else:
        frame["items"] = items
    return frame


class TestBatchedFrameCodecProperty:
    """encode_frame/FrameDecoder on randomized fast-path frames, split
    across arbitrary byte boundaries, with poison recovery mid-batch."""

    @given(seed=st.integers(0, 10**6))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_any_segmentation(self, seed):
        import random

        rng = random.Random(seed)
        frames = [_random_batched_frame(rng)
                  for _ in range(rng.randint(1, 6))]
        stream = b"".join(encode_frame(f) for f in frames)
        dec = FrameDecoder()
        out, i = [], 0
        while i < len(stream):
            j = min(len(stream), i + rng.randint(1, 17))
            out.extend(dec.feed(stream[i:j]))
            i = j
        assert len(out) == len(frames)
        for got, want in zip(out, frames):
            # SleepWork instances pickle-roundtrip into equal-by-field
            # copies, not identical objects — compare the stable keys
            assert got["kind"] == want["kind"]
            assert {k: v for k, v in got.items() if k != "fn"} == \
                   {k: v for k, v in want.items() if k != "fn"}

    @given(seed=st.integers(0, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_poison_frame_mid_batch_stream_recovers(self, seed):
        import random
        import struct

        rng = random.Random(seed)
        before = [_random_batched_frame(rng)
                  for _ in range(rng.randint(1, 3))]
        after = [_random_batched_frame(rng)
                 for _ in range(rng.randint(1, 3))]
        poison = b"cno_such_module_xyz\nGhost\n."  # GLOBAL opcode, bad module
        stream = (b"".join(encode_frame(f) for f in before)
                  + struct.pack(">I", len(poison)) + poison
                  + b"".join(encode_frame(f) for f in after))
        dec = FrameDecoder()
        out, i = [], 0
        while i < len(stream):
            j = min(len(stream), i + rng.randint(1, 33))
            out.extend(dec.feed(stream[i:j]))
            i = j
        assert len(out) == len(before) + 1 + len(after)
        kinds = [f["kind"] for f in out]
        assert kinds[len(before)] == "undecodable"
        for got, want in zip(out[:len(before)] + out[len(before) + 1:],
                             before + after):
            assert got["kind"] == want["kind"]


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------
class TestLoopbackTransport:
    def test_pair_send_recv_by_reference(self):
        a, b = LoopbackTransport.pair()
        frame = {"kind": "hello", "obj": object()}  # not picklable, fine here
        a.send(frame)
        assert b.recv(timeout=1.0) is frame
        assert b.recv(timeout=0.01) is None

    def test_close_raises_on_both_ends(self):
        a, b = LoopbackTransport.pair()
        a.close()
        with pytest.raises(TransportClosed):
            b.recv(timeout=1.0)
        with pytest.raises(TransportClosed):
            b.send({"kind": "x"})
        with pytest.raises(TransportClosed):
            a.recv(timeout=0.01)


def socket_transport_pair():
    a, b = socket.socketpair()
    return SocketTransport(a), SocketTransport(b)


class TestSocketTransport:
    def test_frames_roundtrip_including_large(self):
        # the 1MB frame overflows the kernel socket buffer, so the sender
        # must run concurrently with the receiver (as it does in real use)
        a, b = socket_transport_pair()
        try:
            frames = [{"kind": "submit", "seq": 0, "chunk": Chunk(0, 4, "u")},
                      {"kind": "done", "seq": 0, "result": b"z" * 1_000_000}]
            sender = threading.Thread(
                target=lambda: [a.send(f) for f in frames], daemon=True)
            sender.start()
            got = [b.recv(timeout=10.0), b.recv(timeout=10.0)]
            sender.join(timeout=10.0)
            assert not sender.is_alive()
            assert got == frames
        finally:
            a.close()
            b.close()

    def test_recv_timeout_returns_none(self):
        a, b = socket_transport_pair()
        try:
            t0 = time.perf_counter()
            assert b.recv(timeout=0.05) is None
            assert time.perf_counter() - t0 < 2.0
        finally:
            a.close()
            b.close()

    def test_peer_close_raises_transport_closed(self):
        a, b = socket_transport_pair()
        a.close()
        with pytest.raises(TransportClosed):
            b.recv(timeout=5.0)

    def test_tcp_connect_against_worker_server(self):
        server = WorkerServer().start()
        try:
            tr = SocketTransport.connect(server.address, timeout=5.0)
            tr.send({"kind": "hello", "unit": "u0", "backend": "inline"})
            frame = tr.recv(timeout=5.0)
            assert frame == {"kind": "ready", "unit": "u0"}
            tr.close()
        finally:
            server.stop()


# ---------------------------------------------------------------------------
# RemoteUnit over loopback: an ordinary backend unit
# ---------------------------------------------------------------------------
class TestRemoteUnitLoopback:
    def _drive(self, unit, chunks, work_fn):
        bus = CompletionBus()
        unit.start(bus)
        try:
            recs = []
            for c in chunks:
                unit.submit(c, work_fn)
                assert bus.wait(timeout=10.0)
                recs.extend(bus.drain())
            return recs
        finally:
            unit.close()

    def test_submit_completes_with_result_and_latency_split(self):
        unit = loopback_unit("u0")
        recs = self._drive(
            unit, [Chunk(0, 4, "u0"), Chunk(4, 9, "u0")],
            lambda c: c.size * 10,
        )
        assert [r.result for r in recs] == [40, 50]
        assert all(r.error is None for r in recs)
        assert len(unit.dispatch_latencies) == 2
        assert len(unit.wire_latencies) == 2
        assert len(unit.local_queue_latencies) == 2
        for total, wire, local in zip(unit.dispatch_latencies,
                                      unit.wire_latencies,
                                      unit.local_queue_latencies):
            assert total >= 0 and wire >= 0 and local >= 0
            # the split re-composes (both components clamp at 0)
            assert total <= wire + local + 1e-6 or total >= 0

    def test_work_runs_on_the_worker_side_thread(self):
        unit = loopback_unit("u0")
        caller = threading.get_ident()
        recs = self._drive(unit, [Chunk(0, 1, "u0")],
                           lambda c: threading.get_ident())
        assert recs[0].result != caller

    def test_work_fn_error_crosses_the_transport(self):
        def boom(c):
            raise ValueError("remote kaput")

        recs = self._drive(loopback_unit("u0"), [Chunk(0, 1, "u0")], boom)
        assert isinstance(recs[0].error, ValueError)

    def test_parallel_for_mixed_remote_and_local(self):
        rec = Recorder(per_item_sleep=2e-5)
        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=rec,
                         backend=loopback_unit("r0"))
        rt.register_unit("r1", WorkerKind.CC, work_fn=rec,
                         backend=loopback_unit("r1"))
        rt.register_unit("cc0", WorkerKind.CC, work_fn=rec)
        rep = rt.parallel_for(num_items=300, policy="multidynamic",
                              engine="interrupt", acc_chunk=16)
        assert rep.items == 300
        assert_exact_tiling(rep.coverage, 300)
        rec.assert_exactly_once(300)
        # dispatch latency covers everyone; wire latency only remote units
        assert set(rep.dispatch_latency) == {"r0", "r1", "cc0"}
        assert set(rep.wire_latency) <= {"r0", "r1"}
        assert rep.wire_latency, "remote units must report a wire component"
        for u, wire in rep.wire_latency.items():
            assert 0.0 <= wire <= rep.dispatch_latency[u] + 1e-6

    def test_work_fn_error_fails_parallel_for(self):
        def boom(c):
            raise ValueError("chunk exploded remotely")

        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=boom,
                         backend=loopback_unit("r0"))
        with pytest.raises(ValueError, match="exploded remotely"):
            rt.parallel_for(num_items=50, engine="interrupt", acc_chunk=8)

    def test_long_chunk_is_not_mistaken_for_a_lost_worker(self):
        # execution time (400ms) far exceeds the retransmit budget
        # (5 x 10ms): the worker's busy answers must keep the unit alive —
        # the budget bounds silence, not work
        unit = loopback_unit("u0", retry_interval=0.01, max_retries=5)
        recs = self._drive(
            unit, [Chunk(0, 1, "u0")],
            lambda c: time.sleep(0.4) or 41 + c.size,
        )
        assert recs[0].error is None
        assert recs[0].result == 42

    def test_handshake_timeout_when_nobody_serves(self):
        client_end, _worker_end = LoopbackTransport.pair()  # no worker
        unit = RemoteUnit("u0", transport=client_end,
                          retry_interval=0.01, connect_timeout=0.2)
        with pytest.raises(TransportError, match="did not answer hello"):
            unit.start(CompletionBus())

    def test_elastic_leave_drains_remote_unit_gracefully(self):
        rec = Recorder(per_item_sleep=1e-4)
        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=rec,
                         backend=loopback_unit("r0"))
        rt.register_unit("cc0", WorkerKind.CC, work_fn=rec)
        rep = rt.parallel_for(
            num_items=150, policy="multidynamic", engine="interrupt",
            acc_chunk=8, elastic=ElasticSchedule().leave(0.004, "r0"),
        )
        assert rep.items == 150
        assert_exact_tiling(rep.coverage, 150)
        rec.assert_exactly_once(150)
        assert [e["action"] for e in rep.events] == ["leave"]
        # the drained unit stopped early; the survivor finished the space
        assert rep.per_worker_items["cc0"] > 0


# ---------------------------------------------------------------------------
# in-process TCP: late attach + sharded pinning validation
# ---------------------------------------------------------------------------
_TCP_LEDGER = Counter()
_TCP_LOCK = threading.Lock()


def _tcp_record(chunk):
    """Module-level so TCP pickling resolves it; in-process workers share
    this module's globals, so the ledger still observes side effects."""
    time.sleep(chunk.size * 5e-5)
    with _TCP_LOCK:
        _TCP_LEDGER.update(chunk.indices())


class TestTcpInProcess:
    def setup_method(self):
        with _TCP_LOCK:
            _TCP_LEDGER.clear()

    def test_remote_spec_through_register_unit(self):
        server = WorkerServer().start()
        try:
            rt = HeteroRuntime()
            rt.register_unit("r0", WorkerKind.CC, work_fn=_tcp_record,
                             backend=f"remote:{server.address}")
            rep = rt.parallel_for(num_items=120, engine="interrupt",
                                  acc_chunk=16)
            assert rep.items == 120
            assert_exact_tiling(rep.coverage, 120)
            with _TCP_LOCK:
                assert set(_TCP_LEDGER) == set(range(120))
                assert all(c == 1 for c in _TCP_LEDGER.values())
            assert set(rep.wire_latency) == {"r0"}
        finally:
            server.stop()

    def test_elastic_join_attaches_late_worker(self):
        # the worker is listening but no unit is attached until the join
        # event fires mid-run — "join = late worker attach"
        server = WorkerServer().start()
        try:
            rt = HeteroRuntime()
            rt.register_unit("cc0", WorkerKind.CC, work_fn=_tcp_record)
            rep = rt.parallel_for(
                _tcp_record, num_items=200, policy="multidynamic",
                engine="interrupt", acc_chunk=8,
                backend=f"remote:{server.address}",
                elastic=ElasticSchedule().join(0.002, "late", kind="cc"),
            )
            assert rep.items == 200
            assert_exact_tiling(rep.coverage, 200)
            with _TCP_LOCK:
                assert set(_TCP_LEDGER) == set(range(200))
                assert all(c == 1 for c in _TCP_LEDGER.values())
            assert rep.per_worker_items["late"] > 0
            assert [e["action"] for e in rep.events] == ["join"]
        finally:
            server.stop()

    def test_sharded_space_requires_pinning_remote_units(self):
        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=_tcp_record,
                         backend="remote:127.0.0.1:9")
        rt.register_unit("cc0", WorkerKind.CC, work_fn=_tcp_record)
        with pytest.raises(ValueError, match="pinned via placement"):
            rt.parallel_for(space=ShardedSpace(100, 2),
                            engine="interrupt")

    def test_sharded_space_rejects_call_level_remote_backend(self):
        rt = HeteroRuntime()
        rt.register_unit("cc0", WorkerKind.CC, work_fn=_tcp_record)
        rt.register_unit("cc1", WorkerKind.CC, work_fn=_tcp_record)
        with pytest.raises(ValueError, match="register per-unit remote"):
            rt.parallel_for(space=ShardedSpace(100, 2),
                            engine="interrupt",
                            backend="remote:127.0.0.1:9")


# ---------------------------------------------------------------------------
# make_backend: the remote spec form
# ---------------------------------------------------------------------------
class TestRemoteSpec:
    def test_remote_spec_builds_named_remote_unit(self):
        unit = make_backend("remote:127.0.0.1:12345", "acc0")
        assert isinstance(unit, RemoteUnit)
        assert unit.name == "acc0"
        assert unit.address == "127.0.0.1:12345"

    def test_remote_spec_without_address_rejected(self):
        with pytest.raises(ValueError, match="remote:<host:port>"):
            make_backend("remote:", "u0")

    def test_register_unit_accepts_remote_spec(self):
        rt = HeteroRuntime()
        spec = rt.register_unit("r0", WorkerKind.ACC, work_fn=lambda c: None,
                                backend="remote:127.0.0.1:12345")
        assert spec.backend == "remote:127.0.0.1:12345"

    def test_no_proxy_chains(self):
        with pytest.raises(ValueError, match="no proxy chains"):
            RemoteUnit("u0", address="127.0.0.1:1",
                       remote_backend="remote:127.0.0.1:2")


# ---------------------------------------------------------------------------
# worker loss: the medium dies, the run does not
# ---------------------------------------------------------------------------
class DropDoneTransport(FlakyTransport):
    """Drops every ``done``/``done_batch``/``busy`` frame: the
    worker→client channel is dead while submits still flow — retransmit
    exhaustion, deterministic."""

    def __init__(self, inner):
        super().__init__(inner, seed=0)

    def send(self, frame):
        if isinstance(frame, dict) and frame.get("kind") in (
                "done", "done_batch", "busy"):
            return
        self.inner.send(frame)


class TestWorkerLost:
    def test_connection_drop_requeues_inflight_to_survivors(self):
        # the work function itself severs the worker's transport after a
        # few chunks: the executed-but-unreported chunk must be requeued
        # (coverage exact-once) even though its side effects already
        # landed — the documented at-least-once boundary of worker loss
        client_end, worker_end = LoopbackTransport.pair()
        worker = RemoteWorker(worker_end, poll_interval=0.05)
        threading.Thread(target=worker.serve, daemon=True).start()

        seen, lock = set(), threading.Lock()
        state = {"executions": 0}

        def work(chunk):
            with lock:
                seen.update(chunk.indices())
                state["executions"] += 1
                if state["executions"] == 3:
                    worker_end.close()  # completion of this chunk is unsendable
            time.sleep(chunk.size * 1e-4)

        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=work,
                         backend=RemoteUnit("r0", transport=client_end,
                                            retry_interval=0.02,
                                            max_retries=25))
        rt.register_unit("cc0", WorkerKind.CC, work_fn=work)
        rt.register_unit("cc1", WorkerKind.CC, work_fn=work)
        rep = rt.parallel_for(num_items=240, policy="multidynamic",
                              engine="interrupt", acc_chunk=8)
        assert rep.items == 240
        assert_exact_tiling(rep.coverage, 240)
        assert set(range(240)) <= seen
        lost = [e for e in rep.events if e["action"] == "lost"]
        assert len(lost) == 1 and lost[0]["unit"] == "r0"
        assert lost[0]["requeued"] is not None

    def test_retransmit_exhaustion_is_a_lost_worker_not_a_hang(self):
        # completions never arrive (all done frames dropped): after
        # max_retries the unit posts WorkerLost and the survivor finishes
        client_end, worker_end = LoopbackTransport.pair()
        worker = RemoteWorker(DropDoneTransport(worker_end),
                              poll_interval=0.02)
        threading.Thread(target=worker.serve, daemon=True).start()

        rec = Recorder(per_item_sleep=1e-5)
        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=rec,
                         backend=RemoteUnit("r0", transport=client_end,
                                            retry_interval=0.01,
                                            max_retries=5))
        rt.register_unit("cc0", WorkerKind.CC, work_fn=rec)
        rep = rt.parallel_for(num_items=100, policy="multidynamic",
                              engine="interrupt", acc_chunk=8)
        assert rep.items == 100
        assert_exact_tiling(rep.coverage, 100)
        lost = [e for e in rep.events if e["action"] == "lost"]
        assert len(lost) == 1 and lost[0]["unit"] == "r0"
        # every index ran at least once; only the requeued span may repeat
        assert set(rec.counts) == set(range(100))

    def test_all_workers_lost_raises_stall_not_hang(self):
        client_end, worker_end = LoopbackTransport.pair()
        worker = RemoteWorker(DropDoneTransport(worker_end),
                              poll_interval=0.02)
        threading.Thread(target=worker.serve, daemon=True).start()
        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=lambda c: None,
                         backend=RemoteUnit("r0", transport=client_end,
                                            retry_interval=0.01,
                                            max_retries=5))
        with pytest.raises(RuntimeError, match="stalled"):
            rt.parallel_for(num_items=50, engine="interrupt", acc_chunk=8)


# ---------------------------------------------------------------------------
# the FlakyTransport battery (the ISSUE's headline)
# ---------------------------------------------------------------------------
def flaky_battery_run(seed):
    """One randomized multi-host run over faulty loopback transports."""
    import random

    rng = random.Random(seed)
    n_remote = rng.randint(2, 3)
    n_local = rng.randint(0, 1)
    n_items = rng.randint(80, 240)
    acc_chunk = rng.choice([4, 8, 16])
    policy = POLICIES[rng.randrange(3)]
    faults = dict(
        drop=rng.uniform(0.0, 0.25),
        duplicate=rng.uniform(0.0, 0.25),
        reorder=rng.uniform(0.0, 0.25),
        delay=rng.uniform(0.0, 0.3),
        max_delay=0.01,
    )
    # dispatch fast-path knobs ride the same battery: descriptor caching
    # and frame batching must preserve exact-once under every fault mix
    # (drop/dup/reorder now also hit register_fn / work_batch /
    # done_batch frames)
    batch_frames = rng.choice([1, 1, 2, 4])
    fn_cache = rng.random() < 0.75
    rec = Recorder(per_item_sleep=rng.uniform(0.5, 2.0) * 2e-5)
    rt = HeteroRuntime()
    for i in range(n_remote):
        rt.register_unit(
            f"r{i}", WorkerKind.CC, work_fn=rec,
            backend=loopback_unit(f"r{i}", flaky_seed=seed * 37 + i * 1000,
                                  batch_frames=batch_frames,
                                  fn_cache=fn_cache, **faults),
        )
    for i in range(n_local):
        rt.register_unit(f"cc{i}", WorkerKind.CC, work_fn=rec)

    elastic = None
    if n_remote + n_local >= 3 and rng.random() < 0.5:
        # drain one remote unit mid-run; survivors must still cover
        elastic = ElasticSchedule().leave(
            rng.uniform(0.0, 0.05), f"r{rng.randrange(n_remote)}")

    rep = rt.parallel_for(
        num_items=n_items, policy=policy, engine="interrupt",
        acc_chunk=acc_chunk, elastic=elastic,
    )
    return rep, rec, n_items


class TestFlakyBattery:
    """≥20 seeded drop/delay/duplicate/reorder schedules: exact-once."""

    @given(seed=st.integers(0, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_exact_once_under_faulty_medium(self, seed):
        rep, rec, n_items = flaky_battery_run(seed)
        assert rep.items == n_items
        assert rep.chunks == len(rep.coverage)
        assert_exact_tiling(rep.coverage, n_items)
        rec.assert_exactly_once(n_items)
        times = [e["t"] for e in (rep.events or [])]
        assert times == sorted(times), "events not monotone"

    def test_side_effect_parity_with_inline(self):
        # the same workload through a faulty transport and through the
        # inline backend must leave identical ledgers behind
        n_items = 180

        def run_remote():
            rec = Recorder(per_item_sleep=1e-5)
            rt = HeteroRuntime()
            for i in range(2):
                rt.register_unit(
                    f"r{i}", WorkerKind.CC, work_fn=rec,
                    backend=loopback_unit(f"r{i}", flaky_seed=1234 + i,
                                          drop=0.2, duplicate=0.2,
                                          reorder=0.2, delay=0.2,
                                          max_delay=0.01),
                )
            rep = rt.parallel_for(num_items=n_items, policy="static",
                                  engine="interrupt", acc_chunk=8)
            return rep, rec

        def run_inline():
            rec = Recorder()
            rt = HeteroRuntime()
            for i in range(2):
                rt.register_unit(f"r{i}", WorkerKind.CC, work_fn=rec,
                                 backend="inline")
            rep = rt.parallel_for(num_items=n_items, policy="static",
                                  engine="interrupt", acc_chunk=8)
            return rep, rec

        rep_r, rec_r = run_remote()
        rep_i, rec_i = run_inline()
        assert rec_r.counts == rec_i.counts, "side effects diverged"
        assert rep_r.items == rep_i.items == n_items
        assert_exact_tiling(rep_r.coverage, n_items)
        assert_exact_tiling(rep_i.coverage, n_items)


# ---------------------------------------------------------------------------
# dispatch fast path (ISSUE 8): session-cached work descriptors and
# chunk-batched frames — wire-shape, recovery, and accounting contracts
# ---------------------------------------------------------------------------
class _DropFirstRegistration(FrameTap):
    """Swallows the first register_fn frame (still recorded in .sent)."""

    def _forward(self, frame):
        if frame.get("kind") == "register_fn" and not getattr(
                self, "_dropped", False):
            self._dropped = True
            return
        self.inner.send(frame)


def _drive_direct(unit, chunks, work_fn):
    """Submit every chunk up-front (pipelined), wait for all completions."""
    bus = CompletionBus()
    unit.start(bus)
    try:
        for c in chunks:
            unit.submit(c, work_fn)
        unit.flush()
        recs = []
        deadline = time.perf_counter() + 30.0
        while len(recs) < len(chunks):
            assert time.perf_counter() < deadline, (
                f"only {len(recs)}/{len(chunks)} completions arrived")
            bus.wait(timeout=1.0)
            recs.extend(bus.drain())
        return recs
    finally:
        unit.close()


def _work_items(tap):
    """All work items the client ever put on the wire, batched or not."""
    items = []
    for f in tap.frames("submit"):
        items.append(f)
    for f in tap.frames("work_batch"):
        items.extend(f["items"])
    return items


class TestDescriptorCache:
    def test_fn_registered_once_per_session(self):
        unit, tap, _w = tapped_loopback_unit("u0")
        fn = SleepWork(0.0)
        recs = _drive_direct(
            unit, [Chunk(i, i + 1, "u0") for i in range(6)], fn)
        assert len(recs) == 6 and all(r.error is None for r in recs)
        assert len(tap.frames("register_fn")) == 1
        items = _work_items(tap)
        assert len(items) >= 6
        assert all("fn" not in it and "fn_ref" in it for it in items), (
            "work items must reference the cached descriptor, not inline it")

    def test_content_hash_shares_and_invalidates_registrations(self):
        unit, tap, _w = tapped_loopback_unit("u0")
        bus = CompletionBus()
        unit.start(bus)
        try:
            def one(chunk, fn):
                unit.submit(chunk, fn)
                unit.flush()
                while not bus.drain():
                    bus.wait(timeout=5.0)

            # two *distinct objects* with equal pickled content: one reg
            one(Chunk(0, 1, "u0"), SleepWork(0.0))
            one(Chunk(1, 2, "u0"), SleepWork(0.0))
            assert len(tap.frames("register_fn")) == 1
            # changed content hashes differently: re-registers
            one(Chunk(2, 3, "u0"), SleepWork(1e-9))
            regs = tap.frames("register_fn")
            assert len(regs) == 2
            assert regs[0]["fn_id"] != regs[1]["fn_id"]
            assert all(r["fn_id"].startswith("h:") for r in regs)
        finally:
            unit.close()

    def test_unpicklable_fn_falls_back_to_identity_id(self):
        # loopback lambdas/closures cannot be content-hashed; they get a
        # session-stable identity id and still ride the cache path
        unit, tap, _w = tapped_loopback_unit("u0")
        hits = []
        fn = lambda c: hits.append(c.start)  # noqa: E731
        recs = _drive_direct(
            unit, [Chunk(i, i + 1, "u0") for i in range(3)], fn)
        assert len(recs) == 3 and sorted(hits) == [0, 1, 2]
        regs = tap.frames("register_fn")
        assert len(regs) == 1 and regs[0]["fn_id"].startswith("r:")

    def test_fn_cache_off_inlines_the_fn(self):
        unit, tap, _w = tapped_loopback_unit("u0", fn_cache=False)
        recs = _drive_direct(
            unit, [Chunk(i, i + 1, "u0") for i in range(4)], SleepWork(0.0))
        assert len(recs) == 4
        assert not tap.frames("register_fn")
        items = _work_items(tap)
        assert all("fn" in it and "fn_ref" not in it for it in items)

    def test_dropped_registration_before_batched_work_recovers(self):
        # the ISSUE's directed case: register_fn lost, then a work_batch
        # arrives referencing it — the worker NACKs unknown_fn, the
        # client re-registers and retransmits, exact-once is preserved
        unit, tap, _w = tapped_loopback_unit(
            "u0", batch_frames=4, tap_cls=_DropFirstRegistration)
        rec = Recorder()
        chunks = [Chunk(i * 2, i * 2 + 2, "u0") for i in range(4)]
        recs = _drive_direct(unit, chunks, rec)
        assert len(recs) == 4 and all(r.error is None for r in recs)
        rec.assert_exactly_once(8)
        assert len(tap.frames("register_fn")) >= 2, (
            "client never re-registered after the unknown_fn NACK")
        assert tap.frames("work_batch"), "batching was not engaged"

    def test_worker_registry_loss_mid_session_recovers(self):
        # a worker that lost its per-session fn registry (restart) NACKs
        # the next cached reference; the client re-ships the descriptor
        unit, tap, worker = tapped_loopback_unit("u0")
        bus = CompletionBus()
        unit.start(bus)
        try:
            fn = SleepWork(0.0)
            unit.submit(Chunk(0, 2, "u0"), fn)
            unit.flush()
            while not bus.drain():
                bus.wait(timeout=5.0)
            with worker._lock:
                worker._fns.clear()  # simulate restart-shaped amnesia
            unit.submit(Chunk(2, 4, "u0"), fn)
            unit.flush()
            recs = []
            deadline = time.perf_counter() + 10.0
            while not recs:
                assert time.perf_counter() < deadline
                bus.wait(timeout=1.0)
                recs = bus.drain()
            assert recs[0].error is None
            assert len(tap.frames("register_fn")) == 2
        finally:
            unit.close()


class TestBatchedFrames:
    def test_full_batch_coalesces_into_one_work_batch(self):
        unit, tap, _w = tapped_loopback_unit("u0", batch_frames=4)
        rec = Recorder()
        chunks = [Chunk(i * 3, i * 3 + 3, "u0") for i in range(4)]
        recs = _drive_direct(unit, chunks, rec)
        assert len(recs) == 4
        rec.assert_exactly_once(12)
        batches = tap.frames("work_batch")
        assert len(batches) == 1 and len(batches[0]["items"]) == 4
        assert not tap.frames("submit"), (
            "chunks leaked out as singleton frames despite batching")

    def test_partial_batch_stays_buffered_until_flush(self):
        unit, tap, _w = tapped_loopback_unit("u0", batch_frames=8)
        bus = CompletionBus()
        unit.start(bus)
        try:
            for i in range(3):
                unit.submit(Chunk(i, i + 1, "u0"), SleepWork(0.0))
            assert not _work_items(tap), (
                "a partial batch went on the wire before flush()")
            unit.flush()
            recs = []
            deadline = time.perf_counter() + 10.0
            while len(recs) < 3:
                assert time.perf_counter() < deadline
                bus.wait(timeout=1.0)
                recs.extend(bus.drain())
            batches = tap.frames("work_batch")
            assert len(batches) == 1 and len(batches[0]["items"]) == 3
        finally:
            unit.close()

    def test_batch_frames_1_keeps_legacy_frame_shapes(self):
        # parity satellite: a batch_frames=1, fn_cache=off session must
        # put exactly the pre-fast-path frames on the wire...
        unit, tap, _w = tapped_loopback_unit("u0", fn_cache=False)
        rec_legacy = Recorder()
        chunks = [Chunk(i * 4, i * 4 + 4, "u0") for i in range(5)]
        _drive_direct(unit, chunks, rec_legacy)
        kinds = set(tap.kinds())
        assert kinds <= {"hello", "submit", "bye"}, f"new kinds leaked: {kinds}"
        for f in tap.frames("submit"):
            assert {"kind", "unit", "seq", "chunk", "fn",
                    "t_submit", "floor"} <= set(f)
        # ...and produce results identical to the batched+cached path
        unit2, _tap2, _w2 = tapped_loopback_unit(
            "u0", batch_frames=4, fn_cache=True)
        rec_fast = Recorder()
        _drive_direct(unit2, chunks, rec_fast)
        assert rec_fast.counts == rec_legacy.counts

    def test_batched_cached_exact_once_under_faults(self):
        # directed heavy-fault run with the fast path fully on: drops,
        # dups and reorders now hit register_fn/work_batch/done_batch
        rec = Recorder(per_item_sleep=1e-5)
        rt = HeteroRuntime()
        for i in range(2):
            rt.register_unit(
                f"r{i}", WorkerKind.CC, work_fn=rec,
                backend=loopback_unit(f"r{i}", flaky_seed=4242 + i,
                                      batch_frames=4, fn_cache=True,
                                      drop=0.25, duplicate=0.25,
                                      reorder=0.25, delay=0.2,
                                      max_delay=0.01),
            )
        rep = rt.parallel_for(num_items=160, policy="multidynamic",
                              engine="interrupt", acc_chunk=8)
        assert rep.items == 160
        assert_exact_tiling(rep.coverage, 160)
        rec.assert_exactly_once(160)
        assert rep.wire_latency is not None
        times = [e["t"] for e in (rep.events or [])]
        assert times == sorted(times)


class _NullSink:
    """Transport stub for white-box accounting tests: swallows sends."""

    closed = False

    def send(self, frame):
        pass

    def recv(self, timeout=None):
        return None

    def close(self):
        pass


class TestWireAccounting:
    """RunReport.wire_latency on synthetic done-frames: a batched frame's
    transit is attributed per chunk — counted once per frame, not once
    per chunk — with no clocks involved (fixed synthetic timestamps)."""

    @staticmethod
    def _unit_with_pending(batch_n, t_submit, t_sent):
        from repro.core.backends import BackendUnit

        unit = RemoteUnit("u0", transport=_NullSink(), batch_frames=batch_n)
        bus = CompletionBus()
        BackendUnit.start(unit, bus)  # skip handshake: frames are synthetic
        for seq in range(batch_n):
            unit._pending[seq] = {
                "seq": seq, "chunk": Chunk(seq * 4, seq * 4 + 4, "u0"),
                "fn": SleepWork(0.0), "t_submit": t_submit,
                "t_sent": t_sent, "sends": 1,
                "next_resend": float("inf"), "batch_n": batch_n,
            }
        return unit, bus

    def test_batched_transit_counted_once_across_the_frame(self):
        unit, bus = self._unit_with_pending(3, t_submit=100.0, t_sent=100.5)
        transit = 0.4  # t_accept - t_sent, shared by all 3 chunks
        queue_waits = [0.0, 0.1, 0.2]  # t_start - t_accept, per chunk
        unit._on_frame({"kind": "done_batch", "unit": "u0", "items": [
            {"seq": s, "chunk": Chunk(s * 4, s * 4 + 4, "u0"),
             "elapsed": 0.01, "t_accept": 100.5 + transit,
             "t_start": 100.5 + transit + queue_waits[s],
             "error": None, "result": None}
            for s in range(3)]})
        assert len(unit.wire_latencies) == 3
        # each chunk: 1/3 of the frame transit + its own queue wait
        for wire, qw in zip(unit.wire_latencies, queue_waits):
            assert wire == pytest.approx(transit / 3 + qw)
        # summed over the batch the transit appears exactly once
        assert sum(unit.wire_latencies) == pytest.approx(
            transit + sum(queue_waits))
        recs = bus.drain()
        assert [r.dispatch_latency for r in recs] == pytest.approx(
            [100.5 + transit + qw - 100.0 for qw in queue_waits])
        assert unit.local_queue_latencies == pytest.approx([0.5] * 3)

    def test_singleton_reduces_to_legacy_attribution(self):
        # batch_n == 1: wire == t_start - t_sent, exactly the pre-batching
        # definition (transit/1 + queue wait telescopes)
        unit, bus = self._unit_with_pending(1, t_submit=50.0, t_sent=50.2)
        unit._on_frame({"kind": "done", "unit": "u0", "seq": 0,
                        "chunk": Chunk(0, 4, "u0"), "elapsed": 0.01,
                        "t_accept": 50.6, "t_start": 50.9,
                        "error": None, "result": None})
        assert unit.wire_latencies == pytest.approx([50.9 - 50.2])
        assert len(bus.drain()) == 1

    def test_duplicate_done_items_do_not_double_count(self):
        unit, bus = self._unit_with_pending(2, t_submit=10.0, t_sent=10.1)
        frame = {"kind": "done_batch", "unit": "u0", "items": [
            {"seq": s, "chunk": Chunk(s * 4, s * 4 + 4, "u0"),
             "elapsed": 0.01, "t_accept": 10.3, "t_start": 10.3,
             "error": None, "result": None} for s in range(2)]}
        unit._on_frame(frame)
        unit._on_frame(frame)  # duplicated done_batch (flaky medium)
        assert len(unit.wire_latencies) == 2
        assert len(bus.drain()) == 2


class TestRemoteSpecKnobs:
    def test_spec_query_string_sets_fast_path_knobs(self):
        unit = make_backend("remote:127.0.0.1:9?batch_frames=4&fn_cache=0",
                            "r0")
        assert isinstance(unit, RemoteUnit)
        assert unit.batch_frames == 4 and unit.capacity == 4
        assert unit.fn_cache is False

    def test_spec_defaults_are_conservative(self):
        unit = make_backend("remote:127.0.0.1:9", "r0")
        assert unit.batch_frames == 1 and unit.capacity == 1
        assert unit.fn_cache is True

    def test_unknown_knob_rejected_with_listing(self):
        with pytest.raises(ValueError, match="batch_frames"):
            make_backend("remote:127.0.0.1:9?turbo=1", "r0")

    def test_non_integer_knob_value_rejected(self):
        with pytest.raises(ValueError):
            make_backend("remote:127.0.0.1:9?batch_frames=lots", "r0")

    def test_batch_frames_must_be_positive(self):
        with pytest.raises(ValueError, match="batch_frames"):
            make_backend("remote:127.0.0.1:9?batch_frames=0", "r0")

    def test_batch_frames_auto_spec(self):
        unit = make_backend("remote:127.0.0.1:9?batch_frames=auto", "r0")
        assert isinstance(unit, RemoteUnit)
        assert unit.auto_batch is True
        # adaptation starts narrow and only widens from latency evidence
        assert unit.batch_frames == 1 and unit.capacity == 1

    def test_auto_is_only_for_batch_frames(self):
        with pytest.raises(ValueError):
            make_backend("remote:127.0.0.1:9?fn_cache=auto", "r0")


# ---------------------------------------------------------------------------
# adaptive frame batching (ISSUE 9 tentpole): batch_frames="auto"
# ---------------------------------------------------------------------------
class TestAdaptiveFrameBatching:
    def _drive(self, unit, n_chunks, work_fn):
        """Pump chunks through the unit, windowed at its (live) capacity."""
        bus = CompletionBus()
        unit.start(bus)
        try:
            issued = done = 0
            while done < n_chunks:
                while issued < n_chunks and issued - done < unit.capacity:
                    unit.submit(Chunk(issued, issued + 1, unit.name), work_fn)
                    issued += 1
                unit.flush()
                assert bus.wait(timeout=30.0), (
                    f"completions stalled at {done}/{n_chunks}")
                for rec in bus.drain():
                    assert rec.error is None
                    done += 1
        finally:
            unit.close()

    def test_constructor_rejects_bad_string(self):
        client_end, _ = LoopbackTransport.pair()
        with pytest.raises(ValueError, match="batch_frames"):
            RemoteUnit("u0", transport=client_end, batch_frames="lots")

    def test_auto_widens_on_delayed_link(self):
        # every frame in both directions pays uniform(0, 8 ms): frame
        # transit dwarfs the near-zero service time, so the learned width
        # must open up from 1 — and exact-once execution must survive the
        # batching transitions
        client_end, worker_end = LoopbackTransport.pair()
        client_side = FlakyTransport(client_end, seed=11,
                                     delay=1.0, max_delay=0.008)
        worker_side = FlakyTransport(worker_end, seed=12,
                                     delay=1.0, max_delay=0.008)
        worker = RemoteWorker(worker_side, poll_interval=0.02)
        threading.Thread(target=worker.serve, daemon=True).start()
        unit = RemoteUnit("u0", transport=client_side, retry_interval=0.5,
                          max_retries=200, batch_frames="auto")
        rec = Recorder()
        self._drive(unit, 160, rec)
        rec.assert_exactly_once(160)
        assert unit.auto_batch
        assert 1 < unit.effective_batch_frames <= AUTO_BATCH_MAX
        # capacity tracks the live width so drivers can keep the pipe full
        assert unit.capacity == unit.effective_batch_frames

    def test_auto_stays_narrow_on_clean_link(self):
        # loopback transit is microseconds while each chunk takes ~2 ms of
        # service: batching would add latency for nothing, width stays 1
        unit = loopback_unit("u0", batch_frames="auto")
        rec = Recorder(per_item_sleep=2e-3)
        self._drive(unit, 30, rec)
        rec.assert_exactly_once(30)
        assert unit.effective_batch_frames == 1

    def test_runreport_carries_effective_width(self):
        rec = Recorder(per_item_sleep=2e-5)
        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=rec,
                         backend=loopback_unit("r0", batch_frames="auto"))
        rt.register_unit("cc0", WorkerKind.CC, work_fn=rec)
        rep = rt.parallel_for(num_items=200, policy="multidynamic",
                              engine="interrupt", acc_chunk=16)
        assert_exact_tiling(rep.coverage, 200)
        rec.assert_exactly_once(200)
        # only transport units report a frame width; local units have none
        assert rep.batch_frames is not None
        assert set(rep.batch_frames) == {"r0"}
        assert 1 <= rep.batch_frames["r0"] <= AUTO_BATCH_MAX

    def test_lost_pipelined_unit_requeues_all_outstanding(self):
        # capacity 3 (batch_frames=3): the unit dies holding three chunks.
        # Regression: abort used to surrender only the oldest in-flight
        # chunk, so two spans vanished and the run hung or under-covered;
        # now every dropped span requeues and the survivor finishes the
        # space exact-once.
        client_end, worker_end = LoopbackTransport.pair()
        worker = RemoteWorker(DropDoneTransport(worker_end),
                              poll_interval=0.02)
        threading.Thread(target=worker.serve, daemon=True).start()
        rec = Recorder(per_item_sleep=1e-5)
        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=rec,
                         backend=RemoteUnit("r0", transport=client_end,
                                            retry_interval=0.01,
                                            max_retries=5, batch_frames=3))
        rt.register_unit("cc0", WorkerKind.CC, work_fn=rec)
        rep = rt.parallel_for(num_items=120, policy="multidynamic",
                              engine="interrupt", acc_chunk=8)
        assert rep.items == 120
        assert_exact_tiling(rep.coverage, 120)
        lost = [e for e in rep.events if e["action"] == "lost"]
        assert len(lost) == 1 and lost[0]["unit"] == "r0"
        # every index ran at least once; requeued spans may legitimately
        # repeat (the worker executed them but the done frames were lost)
        assert set(rec.counts) == set(range(120))


# ---------------------------------------------------------------------------
# worker subprocesses over real TCP (the acceptance criterion)
# ---------------------------------------------------------------------------
def _index_bytes(i: int) -> bytes:
    return ((i * 2654435761) % 2**32).to_bytes(4, "big") * 4


class ChunkWriter:
    """Picklable work: one file per index (idempotent) + an append log."""

    def __init__(self, root):
        self.root = root

    def __call__(self, chunk):
        for i in chunk.indices():
            with open(os.path.join(self.root, f"{i:06d}.bin"), "wb") as f:
                f.write(_index_bytes(i))
        with open(os.path.join(self.root, "log.txt"), "a") as f:
            f.write(f"{chunk.start}:{chunk.stop}\n")


def _sleepy_noop(chunk):
    time.sleep(chunk.size * 2e-3)


def read_results(root) -> bytes:
    names = sorted(n for n in os.listdir(root) if n.endswith(".bin"))
    return b"".join(
        open(os.path.join(root, n), "rb").read() for n in names
    ), names


def read_log_spans(root):
    with open(os.path.join(root, "log.txt")) as f:
        return sorted(tuple(map(int, line.split(":"))) for line in f)


@pytest.fixture(scope="module")
def worker_pair():
    workers = [spawn_worker(), spawn_worker()]
    yield workers
    for w in workers:
        w.terminate()


class TestSubprocessWorkers:
    def test_sharded_remote_parity_with_inline(self, worker_pair, tmp_path):
        # THE acceptance line: one parallel_for over a ShardedSpace with
        # two RemoteUnits on SocketTransport worker subprocesses ==
        # byte-identical results + exact-once coverage vs backend="inline"
        n_items = 160
        w0, w1 = worker_pair

        def run(backend_for, root):
            os.makedirs(root, exist_ok=True)
            work = ChunkWriter(str(root))
            rt = HeteroRuntime()
            rt.register_unit("r0", WorkerKind.CC, work_fn=work,
                             backend=backend_for("r0", w0))
            rt.register_unit("r1", WorkerKind.CC, work_fn=work,
                             backend=backend_for("r1", w1))
            sp = ShardedSpace(n_items, 2, placement={"r0": 0, "r1": 1})
            return rt.parallel_for(space=sp, policy="multidynamic",
                                   engine="interrupt", acc_chunk=8)

        rep_remote = run(lambda name, w: f"remote:{w.address}",
                         tmp_path / "remote")
        rep_inline = run(lambda name, w: "inline", tmp_path / "inline")

        for rep, root in ((rep_remote, tmp_path / "remote"),
                          (rep_inline, tmp_path / "inline")):
            assert rep.items == n_items
            assert_exact_tiling(rep.coverage, n_items)
            # exact-once side effects *in the executing process*: the log
            # spans tile the space with no duplicates
            assert_exact_tiling(read_log_spans(root), n_items)

        blob_remote, names_remote = read_results(tmp_path / "remote")
        blob_inline, names_inline = read_results(tmp_path / "inline")
        assert names_remote == names_inline
        assert blob_remote == blob_inline, "remote results diverged from inline"

        # the dispatch-latency split is populated for the remote run only
        assert set(rep_remote.wire_latency) == {"s0/r0", "s1/r1"}
        assert rep_inline.wire_latency is None

    def test_killed_worker_subprocess_does_not_hang_the_run(self):
        handle = spawn_worker()
        try:
            rt = HeteroRuntime()
            rt.register_unit(
                "r0", WorkerKind.CC, work_fn=_sleepy_noop,
                backend=RemoteUnit("r0", address=handle.address,
                                   retry_interval=0.05, max_retries=20),
            )
            rt.register_unit("cc0", WorkerKind.CC, work_fn=_sleepy_noop)
            rt.register_unit("cc1", WorkerKind.CC, work_fn=_sleepy_noop)
            killer = threading.Timer(0.15, handle.kill)
            killer.start()
            try:
                rep = rt.parallel_for(num_items=300, policy="multidynamic",
                                      engine="interrupt", acc_chunk=8)
            finally:
                killer.cancel()
            assert rep.items == 300
            assert_exact_tiling(rep.coverage, 300)
            lost = [e for e in (rep.events or []) if e["action"] == "lost"]
            assert len(lost) <= 1  # at most one loss event for one worker
        finally:
            handle.terminate()


# ---------------------------------------------------------------------------
# heartbeat liveness (ISSUE 10): silence is detected, slowness is not
# ---------------------------------------------------------------------------
def heartbeat_loopback_unit(name, *, heartbeat=0.02, patience=3,
                            retry_interval=0.02, max_retries=600,
                            hb_seed=None, **hb_faults):
    """A loopback unit with heartbeat liveness; ``hb_faults`` (with
    ``hb_seed``) fault ONLY the worker's heartbeat frames — work and
    completion frames ride a clean medium."""
    client_end, worker_end = LoopbackTransport.pair()
    worker_side = worker_end
    if hb_seed is not None:
        worker_side = FlakyTransport(worker_end, seed=hb_seed,
                                     kinds=("heartbeat",), **hb_faults)
    worker = RemoteWorker(worker_side, poll_interval=0.02)
    threading.Thread(target=worker.serve, daemon=True).start()
    unit = RemoteUnit(name, transport=client_end,
                      retry_interval=retry_interval, max_retries=max_retries,
                      heartbeat=heartbeat, patience=patience)
    return unit, worker


class _PartitionOnWork:
    """Worker-side medium that goes dark the instant the first work frame
    arrives: the frame is swallowed *before* delivery and everything
    after it (heartbeats included) is silently dropped — a frozen
    process / network partition, as opposed to a visible EOF."""

    def __init__(self, inner):
        self.inner = inner
        self.dark = threading.Event()

    def send(self, frame):
        if self.dark.is_set():
            return
        self.inner.send(frame)

    def recv(self, timeout=None):
        frame = self.inner.recv(timeout)
        if frame is not None and frame.get("kind") in ("submit",
                                                       "work_batch"):
            self.dark.set()
        if self.dark.is_set():
            return None
        return frame

    def close(self):
        self.inner.close()

    @property
    def closed(self):
        return self.inner.closed


class TestHeartbeatLiveness:
    def test_heartbeat_spec_knobs_parse(self):
        unit = make_backend("remote:127.0.0.1:1?heartbeat=0.5&patience=5",
                            "r0")
        assert unit.heartbeat == 0.5
        assert unit.patience == 5

    def test_heartbeat_defaults_off(self):
        unit = make_backend("remote:127.0.0.1:1", "r0")
        assert unit.heartbeat is None

    def test_heartbeat_knob_must_be_numeric(self):
        with pytest.raises(ValueError, match="number of seconds"):
            make_backend("remote:127.0.0.1:1?heartbeat=fast", "r0")

    def test_heartbeat_knob_must_be_positive(self):
        with pytest.raises(ValueError, match="positive"):
            make_backend("remote:127.0.0.1:1?heartbeat=0", "r0")

    def test_patience_must_be_at_least_one(self):
        with pytest.raises(ValueError, match="patience"):
            RemoteUnit("r0", address="127.0.0.1:1", heartbeat=0.1,
                       patience=0)

    def test_worker_sends_heartbeats_with_queue_depth(self):
        unit, _worker = heartbeat_loopback_unit("r0", heartbeat=0.02)
        rec = Recorder(per_item_sleep=1e-4)
        bus = CompletionBus()
        unit.start(bus)
        try:
            for i in range(4):
                unit.submit(Chunk(i * 10, (i + 1) * 10, "r0"), rec)
            unit.flush()
            recs = []
            deadline = time.perf_counter() + 10.0
            # wait for all completions AND at least one liveness frame
            while (len(recs) < 4 or unit.last_heartbeat is None):
                assert time.perf_counter() < deadline, (
                    f"{len(recs)}/4 done, beat={unit.last_heartbeat}")
                bus.wait(timeout=0.2)
                recs.extend(bus.drain())
        finally:
            unit.close()
        assert len(recs) == 4 and not any(r.error for r in recs)
        beat = unit.last_heartbeat
        assert beat["unit"] == "r0"
        assert beat["queue_depth"] >= 0 and beat["inflight"] >= 0
        rec.assert_exactly_once(40)

    def test_silent_partition_is_convicted_dead_not_hung(self):
        # the worker freezes before executing anything: heartbeats stop,
        # the connection never drops.  Without conviction the client
        # would burn max_retries * retry_interval = 30s; with it, the
        # run ends in ~patience * heartbeat and the survivor covers the
        # space with STRICT exact-once side effects (the frozen worker
        # never ran its chunk).
        client_end, worker_end = LoopbackTransport.pair()
        worker = RemoteWorker(_PartitionOnWork(worker_end),
                              poll_interval=0.02)
        threading.Thread(target=worker.serve, daemon=True).start()

        rec = Recorder(per_item_sleep=1e-5)
        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=rec,
                         backend=RemoteUnit("r0", transport=client_end,
                                            retry_interval=0.05,
                                            max_retries=600,
                                            heartbeat=0.02, patience=3))
        rt.register_unit("cc0", WorkerKind.CC, work_fn=rec)
        t0 = time.perf_counter()
        rep = rt.parallel_for(num_items=120, policy="multidynamic",
                              engine="interrupt", acc_chunk=8)
        wall = time.perf_counter() - t0
        assert rep.items == 120
        assert_exact_tiling(rep.coverage, 120)
        rec.assert_exactly_once(120)  # strict: the dead unit ran nothing
        dead = [e for e in rep.events if e["action"] == "dead"]
        assert len(dead) == 1 and dead[0]["unit"] == "r0"
        assert wall < 10.0, (
            f"conviction took {wall:.1f}s — heartbeat liveness did not "
            "beat the retransmit budget"
        )

    def test_idle_conviction_posts_membership_event_without_chunk(self):
        # silence with nothing in flight: the conviction is a pure
        # membership event (chunk=None), not a requeue
        client_end, worker_end = LoopbackTransport.pair()
        worker = RemoteWorker(worker_end, poll_interval=0.02)
        threading.Thread(target=worker.serve, daemon=True).start()
        mute = _PartitionOnWork(client_end)
        unit = RemoteUnit("r0", transport=mute, retry_interval=0.05,
                          max_retries=600, heartbeat=0.02, patience=3)
        bus = CompletionBus()
        unit.start(bus)
        try:
            # freeze the medium with nothing submitted
            mute.dark.set()
            deadline = time.perf_counter() + 10.0
            recs = []
            while not recs and time.perf_counter() < deadline:
                bus.wait(timeout=0.2)
                recs = bus.drain()
            assert recs, "idle conviction never posted"
            from repro.core import WorkerDead
            assert isinstance(recs[0].error, WorkerDead)
            assert recs[0].chunk is None
        finally:
            unit.close()

    def test_slow_worker_is_not_convicted(self):
        # per-item work far slower than the heartbeat interval: the
        # heartbeats keep flowing, so patience never runs out — slowness
        # is the straggler layer's problem, not a liveness verdict
        unit, _worker = heartbeat_loopback_unit("r0", heartbeat=0.02,
                                                patience=3)
        rec = Recorder(per_item_sleep=2e-3)  # 20ms/chunk >> heartbeat
        recs = _drive_direct(unit, [Chunk(i * 10, (i + 1) * 10, "r0")
                                    for i in range(6)], rec)
        assert len(recs) == 6
        assert not any(r.error for r in recs)
        rec.assert_exactly_once(60)


def heartbeat_battery_run(seed):
    """One seeded run with faults injected ONLY into heartbeat frames
    (drop/delay), while a slow-but-alive remote unit works: no false
    conviction is allowed."""
    import random

    rng = random.Random(seed)
    n_items = rng.randint(60, 160)
    acc_chunk = rng.choice([4, 8])
    drop = rng.uniform(0.0, 0.3)
    delay = rng.uniform(0.0, 0.3)
    patience = rng.randint(8, 12)
    rec = Recorder(per_item_sleep=rng.uniform(0.5, 2.0) * 1e-4)
    rt = HeteroRuntime()
    unit, _worker = heartbeat_loopback_unit(
        "r0", heartbeat=0.02, patience=patience,
        hb_seed=seed * 31 + 7, drop=drop, delay=delay, max_delay=0.01)
    rt.register_unit("r0", WorkerKind.CC, work_fn=rec, backend=unit)
    rt.register_unit("cc0", WorkerKind.CC, work_fn=rec)
    rep = rt.parallel_for(num_items=n_items, policy="multidynamic",
                          engine="interrupt", acc_chunk=acc_chunk)
    return rep, rec, n_items


class TestHeartbeatFaultBattery:
    """≥20 seeded heartbeat-only fault schedules: dropped/delayed
    liveness frames must never convict a slow-but-alive worker, and a
    truly dead worker is always exact-once."""

    @given(seed=st.integers(0, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_no_false_conviction_under_heartbeat_faults(self, seed):
        rep, rec, n_items = heartbeat_battery_run(seed)
        assert rep.items == n_items
        assert_exact_tiling(rep.coverage, n_items)
        rec.assert_exactly_once(n_items)
        bad = [e for e in (rep.events or [])
               if e["action"] in ("dead", "lost")]
        assert not bad, f"false conviction of a live worker: {bad}"
        times = [e["t"] for e in (rep.events or [])]
        assert times == sorted(times), "events not monotone"

    @given(seed=st.integers(0, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_true_death_is_exact_once_every_seed(self, seed):
        import random

        rng = random.Random(seed)
        n_items = rng.randint(60, 160)
        acc_chunk = rng.choice([4, 8])
        client_end, worker_end = LoopbackTransport.pair()
        worker = RemoteWorker(_PartitionOnWork(worker_end),
                              poll_interval=0.02)
        threading.Thread(target=worker.serve, daemon=True).start()
        rec = Recorder(per_item_sleep=1e-5)
        rt = HeteroRuntime()
        rt.register_unit(
            "r0", WorkerKind.CC, work_fn=rec,
            backend=RemoteUnit("r0", transport=client_end,
                               retry_interval=0.05, max_retries=600,
                               heartbeat=0.02, patience=rng.randint(2, 5)))
        rt.register_unit("cc0", WorkerKind.CC, work_fn=rec)
        rep = rt.parallel_for(num_items=n_items, policy="multidynamic",
                              engine="interrupt", acc_chunk=acc_chunk)
        assert rep.items == n_items
        assert_exact_tiling(rep.coverage, n_items)
        rec.assert_exactly_once(n_items)  # strict: dead unit ran nothing
        dead = [e for e in rep.events if e["action"] == "dead"]
        assert len(dead) == 1 and dead[0]["unit"] == "r0"


# ---------------------------------------------------------------------------
# lifecycle bug batch: close() idempotence, bye warnings, pump resilience
# ---------------------------------------------------------------------------
class _ByeFailsTransport(FrameTap):
    """Raises on the graceful bye (a worker that died first)."""

    def _forward(self, frame):
        if frame.get("kind") == "bye":
            raise TransportError("injected: peer already gone")
        self.inner.send(frame)


class _FlakyPumpWorker(RemoteWorker):
    """First two completion-pump passes die with an unexpected error —
    the regression shape: an exception on the done-posting path."""

    _faults = 2

    def _pump_once(self):
        if self._faults > 0:
            self._faults -= 1
            raise RuntimeError("injected pump fault")
        super()._pump_once()


class _DoneSendRaises(FlakyTransport):
    """Worker-side medium whose send *raises* on completion frames —
    both the original and the stripped resend fail."""

    def __init__(self, inner):
        super().__init__(inner, seed=0)

    def send(self, frame):
        if isinstance(frame, dict) and frame.get("kind") in ("done",
                                                             "done_batch"):
            raise RuntimeError("injected send-path fault")
        self.inner.send(frame)


class TestLifecycleBugBatch:
    def test_close_is_idempotent(self):
        unit, tap, _worker = tapped_loopback_unit("r0")
        rec = Recorder()
        _drive_direct(unit, [Chunk(0, 8, "r0")], rec)  # closes once
        unit.close()
        unit.close()
        assert len(tap.frames("bye")) == 1, (
            "a second close() re-sent bye on a closed session"
        )

    def test_failed_bye_is_logged_not_swallowed(self, caplog):
        unit, _tap, _worker = tapped_loopback_unit(
            "r0", tap_cls=_ByeFailsTransport)
        rec = Recorder()
        bus = CompletionBus()
        unit.start(bus)
        unit.submit(Chunk(0, 8, "r0"), rec)
        deadline = time.perf_counter() + 10.0
        recs = []
        while not recs and time.perf_counter() < deadline:
            bus.wait(timeout=0.2)
            recs = bus.drain()
        assert recs and recs[0].error is None
        import logging
        with caplog.at_level(logging.WARNING, logger="repro.core.transport"):
            unit.close()
        assert any("graceful bye failed" in r.message for r in caplog.records)
        unit.close()  # still idempotent after the failure path

    def test_pump_exception_does_not_drop_completions(self):
        # the pump's first passes die; the completion must still arrive
        # (guard keeps the thread alive; the done cache makes the item
        # recoverable) instead of the old silent-stall behavior
        client_end, worker_end = LoopbackTransport.pair()
        worker = _FlakyPumpWorker(worker_end, poll_interval=0.02)
        threading.Thread(target=worker.serve, daemon=True).start()
        unit = RemoteUnit("r0", transport=client_end,
                          retry_interval=0.05, max_retries=100)
        rec = Recorder()
        recs = _drive_direct(unit, [Chunk(i * 8, (i + 1) * 8, "r0")
                                    for i in range(5)], rec)
        assert len(recs) == 5
        assert not any(r.error for r in recs)
        rec.assert_exactly_once(40)

    def test_done_send_failure_ends_session_deliberately(self):
        # when even the stripped completion cannot be sent, the worker
        # must end the session (definitive EOF -> WorkerLost -> requeue)
        # instead of leaving a half-dead session that answers busy
        # probes forever while never delivering a completion
        client_end, worker_end = LoopbackTransport.pair()
        worker = RemoteWorker(_DoneSendRaises(worker_end),
                              poll_interval=0.02)
        threading.Thread(target=worker.serve, daemon=True).start()
        rec = Recorder(per_item_sleep=1e-5)
        rt = HeteroRuntime()
        rt.register_unit("r0", WorkerKind.CC, work_fn=rec,
                         backend=RemoteUnit("r0", transport=client_end,
                                            retry_interval=0.05,
                                            max_retries=600))
        rt.register_unit("cc0", WorkerKind.CC, work_fn=rec)
        t0 = time.perf_counter()
        rep = rt.parallel_for(num_items=100, policy="multidynamic",
                              engine="interrupt", acc_chunk=8)
        wall = time.perf_counter() - t0
        assert rep.items == 100
        assert_exact_tiling(rep.coverage, 100)
        assert set(rec.counts) == set(range(100))  # at-least-once boundary
        lost = [e for e in rep.events if e["action"] == "lost"]
        assert len(lost) == 1 and lost[0]["unit"] == "r0"
        assert wall < 15.0, (
            f"run took {wall:.1f}s — the dead session was not ended "
            "deliberately"
        )

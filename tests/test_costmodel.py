"""Online cost model + learned policy + straggler quarantine (ISSUE 7).

The contracts under test:

* **Convergence battery** (the tentpole's acceptance): on randomized
  heterogeneous unit fleets under :class:`SimulatedClock`, one cold
  ``policy="learned"`` warmup run teaches the attached
  :class:`CostModel` each unit's true speed, and the second learned run
  pre-splits within 10% of ``policy="oracle"`` — with exact-once
  coverage and monotone events on every seed.
* **Straggler quarantine**: a ThreadUnit that turns slow mid-run trips
  the detector only after its configured consecutive breaches — never
  on a single slow chunk — and the quarantine retire preserves
  exact-once side effects under WallClock.  The last active unit is
  never quarantined.
* **Cost store round-trip**: save/load reproduces identical learned
  splits; corrupted or version-mismatched JSON cold-starts with a
  :class:`CostModelWarning` instead of raising.
* **Shard merge**: ``s{k}/`` prefixed per-shard report keys fold onto
  the physical unit name — one unit never fragments into phantom
  entries, for throughput and for dispatch latency alike.
"""

import json
import random
import threading
import time
import warnings

import pytest

from repro.core import (
    CostEntry,
    CostModel,
    CostModelWarning,
    HeteroRuntime,
    ShardedSpace,
    SimulatedClock,
    StragglerDetector,
    WorkerKind,
)
from repro.core.costmodel import STORE_SCHEMA, base_unit_name
from repro.core.runtime import POLICIES
from repro.core.scheduler import latency_aware_split, proportional_split


def assert_exact_tiling(spans, n_items):
    assert spans, "no chunks completed"
    assert spans[0][0] == 0
    assert spans[-1][1] == n_items
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c, f"gap or overlap at {b}:{c}"


def assert_monotone_events(report):
    ts = [e["t"] for e in (report.events or [])]
    assert ts == sorted(ts), f"events out of order: {ts}"


def make_sim_runtime(speeds, kinds=None, model=None):
    rt = HeteroRuntime(clock=SimulatedClock(), cost_model=model)
    for name, speed in speeds.items():
        kind = (kinds or {}).get(name, WorkerKind.CC)
        rt.register_unit(name, kind, speed=speed)
    return rt


# ---------------------------------------------------------------------------
# cost model unit behaviour
# ---------------------------------------------------------------------------
class TestCostModelUnit:
    def test_first_observation_sets_throughput_exactly(self):
        m = CostModel()
        tp = m.observe("u0", "spmm", items=100, elapsed=2.0)
        assert tp == pytest.approx(50.0)
        entry = m.lookup("u0", "spmm")
        assert entry.samples == 1 and entry.items == 100

    def test_ewma_blends_subsequent_observations(self):
        m = CostModel(alpha=0.5)
        m.observe("u0", "k", items=100, elapsed=1.0)   # 100/s
        tp = m.observe("u0", "k", items=200, elapsed=1.0)  # 200/s
        assert tp == pytest.approx(150.0)

    def test_lookup_returns_copy(self):
        m = CostModel()
        m.observe("u0", "k", items=10, elapsed=1.0)
        m.lookup("u0", "k").throughput = 1e9
        assert m.lookup("u0", "k").throughput == pytest.approx(10.0)

    def test_kernels_are_independent(self):
        m = CostModel()
        m.observe("u0", "spmm", items=100, elapsed=1.0)
        m.observe("u0", "hotspot", items=10, elapsed=1.0)
        assert m.throughput("u0", "spmm") == pytest.approx(100.0)
        assert m.throughput("u0", "hotspot") == pytest.approx(10.0)
        assert m.kernels() == ["hotspot", "spmm"]

    def test_speeds_and_coverage(self):
        m = CostModel()
        m.observe("u0", "k", items=50, elapsed=1.0)
        assert m.speeds(["u0", "u1"], "k") == {"u0": pytest.approx(50.0)}
        assert not m.coverage(["u0", "u1"], "k")
        m.observe("u1", "k", items=25, elapsed=1.0)
        assert m.coverage(["u0", "u1"], "k")

    def test_fleet_throughput_mean(self):
        m = CostModel()
        assert m.fleet_throughput("k") is None
        m.observe("u0", "k", items=100, elapsed=1.0)
        m.observe("u1", "k", items=300, elapsed=1.0)
        assert m.fleet_throughput("k") == pytest.approx(200.0)

    def test_forget(self):
        m = CostModel()
        m.observe("u0", "a", items=10, elapsed=1.0)
        m.observe("u0", "b", items=10, elapsed=1.0)
        m.forget("u0", "a")
        assert m.lookup("u0", "a") is None
        assert m.lookup("u0", "b") is not None
        m.forget("u0")
        assert len(m) == 0

    def test_invalid_inputs(self):
        with pytest.raises(ValueError, match="alpha"):
            CostModel(alpha=0.0)
        m = CostModel()
        with pytest.raises(ValueError, match="items"):
            m.observe("u0", "k", items=0, elapsed=1.0)
        with pytest.raises(ValueError, match="path"):
            m.save()

    def test_base_unit_name(self):
        assert base_unit_name("s0/acc0") == "acc0"
        assert base_unit_name("s12/cc3") == "cc3"
        assert base_unit_name("acc0") == "acc0"
        # only the shard namespace is stripped, nothing else
        assert base_unit_name("shard/acc0") == "shard/acc0"
        assert base_unit_name("s1x/acc0") == "s1x/acc0"


class TestProportionalSplit:
    def test_tiles_exactly(self):
        sizes = proportional_split(1001, {"a": 3.0, "b": 1.0, "c": 1.0})
        assert sum(sizes.values()) == 1001
        assert sizes["a"] > sizes["b"]

    def test_rejects_degenerate(self):
        with pytest.raises(ValueError):
            proportional_split(10, {})
        with pytest.raises(ValueError):
            proportional_split(10, {"a": 0.0})


# ---------------------------------------------------------------------------
# completion-time prediction (ISSUE 9: dispatch+wire folded into the entry)
# ---------------------------------------------------------------------------
class TestCostEntryPredict:
    def test_overhead_is_max_not_sum(self):
        # dispatch_latency already *contains* the wire component for
        # remote units; adding them would double-count the medium
        e = CostEntry(unit="u", kernel="k",
                      dispatch_latency=0.004, wire_latency=0.003)
        assert e.overhead() == pytest.approx(0.004)

    def test_overhead_cold_entry_is_zero(self):
        assert CostEntry(unit="u", kernel="k").overhead() == 0.0
        assert CostEntry(unit="u", kernel="k",
                         wire_latency=0.002).overhead() == pytest.approx(0.002)

    def test_predict_adds_per_chunk_overhead(self):
        e = CostEntry(unit="u", kernel="k", throughput=100.0,
                      dispatch_latency=0.01)
        assert e.predict(200) == pytest.approx(2.01)
        assert e.predict(200, chunks=5) == pytest.approx(2.05)
        assert e.predict(200, chunks=0) == pytest.approx(2.0)

    def test_predict_cold_returns_none(self):
        assert CostEntry(unit="u", kernel="k").predict(100) is None

    def test_overheads_default_to_zero_for_unknown_units(self):
        m = CostModel()
        m.observe_latency("a", "k", dispatch=0.02)
        out = m.overheads(["a", "b"], "k")
        assert out["a"] == pytest.approx(0.02)
        assert out["b"] == 0.0

    def test_fleet_throughput_counts_measured_zero(self, tmp_path):
        # a stalled unit's measured 0.0 is an observation; the old
        # truthiness filter silently dropped it from the fleet mean
        store = tmp_path / "cost.json"
        store.write_text(json.dumps({
            "schema": STORE_SCHEMA,
            "entries": [
                {"unit": "u0", "kernel": "k", "throughput": 0.0},
                {"unit": "u1", "kernel": "k", "throughput": 100.0},
            ],
        }))
        m = CostModel(str(store))
        assert m.fleet_throughput("k") == pytest.approx(50.0)

    def test_fleet_throughput_all_zero_is_floored(self, tmp_path):
        store = tmp_path / "cost.json"
        store.write_text(json.dumps({
            "schema": STORE_SCHEMA,
            "entries": [{"unit": "u0", "kernel": "k", "throughput": 0.0}],
        }))
        m = CostModel(str(store))
        fleet = m.fleet_throughput("k")
        # an observation, not None — and floored so callers can divide
        assert fleet is not None and fleet > 0.0


# ---------------------------------------------------------------------------
# learned policy consults the latency-aware split (ISSUE 9 tentpole)
# ---------------------------------------------------------------------------
class TestLatencyAwarePlan:
    def test_learned_plan_penalizes_high_latency_unit(self):
        model = CostModel()
        rt = make_sim_runtime({"a": 1.0, "b": 1.0}, model=model)
        for u in ("a", "b"):
            model.observe(u, "default", items=1000, elapsed=1.0)
        model.observe_latency("b", "default", dispatch=0.05)
        # equal speeds: throughput-only would split 150/150; the learned
        # 50 ms dispatch on "b" is 50 items' worth at 1000 items/s, and
        # the water-fill level lands at (300 + 50)/2000 = 0.175 s
        plan = rt.plan(300, policy="learned")
        assert plan["a"] == (0, 175)
        assert plan["b"] == (175, 300)

    def test_plan_matches_latency_aware_split(self):
        model = CostModel()
        rt = make_sim_runtime({"a": 1.0, "b": 1.0, "c": 1.0}, model=model)
        speeds = {"a": 400.0, "b": 100.0, "c": 250.0}
        for u, tp in speeds.items():
            model.observe(u, "default", items=int(tp), elapsed=1.0)
        model.observe_latency("c", "default", dispatch=0.03, wire=0.01)
        plan = rt.plan(900, policy="learned")
        sizes = latency_aware_split(
            900, speeds, model.overheads(list(speeds), "default"))
        assert {u: b - a for u, (a, b) in plan.items()} == sizes

    def test_learned_run_with_latency_still_tiles(self):
        model = CostModel()
        rt = make_sim_runtime({"a": 100.0, "b": 100.0}, model=model)
        rt.parallel_for(num_items=500, policy="learned", acc_chunk=16)
        model.observe_latency("b", "default", dispatch=0.5)
        rep = rt.parallel_for(num_items=500, policy="learned", acc_chunk=16)
        assert_exact_tiling(rep.coverage, 500)
        assert rep.per_worker_items["b"] < rep.per_worker_items["a"]


# ---------------------------------------------------------------------------
# store loading: only real load errors cold-start
# ---------------------------------------------------------------------------
def test_load_keyboard_interrupt_propagates(tmp_path, monkeypatch):
    # regression: `except BaseException` used to swallow a Ctrl-C during
    # store load into a silent cold start
    store = tmp_path / "cost.json"
    m = CostModel()
    m.observe("u0", "k", items=10, elapsed=1.0)
    m.save(str(store))

    def interrupted(*a, **kw):
        raise KeyboardInterrupt

    monkeypatch.setattr(json, "load", interrupted)
    with pytest.raises(KeyboardInterrupt):
        CostModel(str(store))


def test_learned_is_last_policy():
    # property batteries elsewhere draw from POLICIES[pick % 3]; the three
    # cost-free policies must keep their indices
    assert POLICIES[:3] == ("multidynamic", "static", "oracle")
    assert POLICIES[-1] == "learned"


# ---------------------------------------------------------------------------
# the tentpole: seeded convergence battery
# ---------------------------------------------------------------------------
class TestLearnedConvergenceBattery:
    """>=30 seeds: learned within 10% of oracle after one warmup run."""

    N_SEEDS = 32
    N_ITEMS = 2048

    def _fleet(self, rng):
        n_units = rng.randrange(2, 6)
        speeds, kinds = {}, {}
        for i in range(n_units):
            acc = rng.random() < 0.5
            name = f"{'acc' if acc else 'cc'}{i}"
            kinds[name] = WorkerKind.ACC if acc else WorkerKind.CC
            speeds[name] = (rng.uniform(40.0, 400.0) if acc
                            else rng.uniform(5.0, 50.0))
        return speeds, kinds

    @pytest.mark.parametrize("seed", range(N_SEEDS))
    def test_learned_converges_to_oracle(self, seed):
        rng = random.Random(seed)
        speeds, kinds = self._fleet(rng)
        model = CostModel()
        rt = make_sim_runtime(speeds, kinds, model=model)

        warmup = rt.parallel_for(num_items=self.N_ITEMS, policy="learned",
                                 acc_chunk=64)
        learned = rt.parallel_for(num_items=self.N_ITEMS, policy="learned",
                                  acc_chunk=64)
        oracle = rt.parallel_for(num_items=self.N_ITEMS, policy="oracle",
                                 acc_chunk=64)

        for rep in (warmup, learned, oracle):
            assert rep.items == self.N_ITEMS
            assert_exact_tiling(rep.coverage, self.N_ITEMS)
            assert_monotone_events(rep)
        # the acceptance number: within 10% of oracle after one warmup
        assert learned.makespan <= 1.10 * oracle.makespan, (
            f"seed {seed}: learned {learned.makespan:.4f} vs "
            f"oracle {oracle.makespan:.4f}"
        )
        # the warm run is a pre-split: at most one chunk per unit
        assert learned.chunks <= len(speeds)
        # under SimulatedClock items/busy IS the registered speed, so the
        # model must have recovered ground truth
        for name, speed in speeds.items():
            assert model.throughput(name, "default") == pytest.approx(
                speed, rel=1e-6
            ), f"seed {seed}: model missed {name}"

    def test_cold_learned_run_completes_without_model(self):
        # no cost model attached: learned degrades to the adaptive policy
        rt = make_sim_runtime({"a": 50.0, "b": 10.0})
        rep = rt.parallel_for(num_items=500, policy="learned", acc_chunk=16)
        assert rep.items == 500
        assert_exact_tiling(rep.coverage, 500)

    def test_learned_ignores_registered_speeds(self):
        # deliberately wrong priors: the learned split must follow the
        # *measured* speeds, not the registered ones
        model = CostModel()
        rt = HeteroRuntime(clock=SimulatedClock(), cost_model=model)
        rt.register_unit("a", WorkerKind.CC, speed=100.0)
        rt.register_unit("b", WorkerKind.CC, speed=100.0)
        # teach the model a 3:1 reality that contradicts the 1:1 priors
        model.observe("a", "default", items=300, elapsed=1.0)
        model.observe("b", "default", items=100, elapsed=1.0)
        plan = rt.plan(400, policy="learned")
        assert plan["a"] == (0, 300)
        assert plan["b"] == (300, 400)

    def test_partial_coverage_falls_back_to_adaptive(self):
        model = CostModel()
        rt = make_sim_runtime({"a": 50.0, "b": 10.0}, model=model)
        model.observe("a", "default", items=100, elapsed=1.0)  # only one unit
        rep = rt.parallel_for(num_items=500, policy="learned", acc_chunk=16)
        assert rep.items == 500
        assert_exact_tiling(rep.coverage, 500)
        # adaptive fallback issues many chunks, not a pre-split
        assert rep.chunks > 2

    def test_kernel_keys_select_independent_models(self):
        model = CostModel()
        rt = make_sim_runtime({"a": 80.0, "b": 20.0}, model=model)
        rt.parallel_for(num_items=1000, policy="learned", acc_chunk=32,
                        kernel="spmm")
        # a different kernel is still cold -> adaptive, same kernel is warm
        warm = rt.parallel_for(num_items=1000, policy="learned", acc_chunk=32,
                               kernel="spmm")
        cold = rt.parallel_for(num_items=1000, policy="learned", acc_chunk=32,
                               kernel="hotspot")
        assert warm.chunks <= 2
        assert cold.chunks > 2


# ---------------------------------------------------------------------------
# wall-clock noise tolerance (ISSUE 9 tentpole): the SimulatedClock battery
# above proves convergence on a noiseless clock; this one re-runs the
# learned-vs-oracle comparison over real ThreadUnits whose work functions
# jitter +/-15% per chunk, and gates the gap on a tolerance *band* (max and
# mean across seeds) instead of the simulated battery's tight 10%.
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestWallClockNoiseTolerance:
    N_SEEDS = 20
    N_ITEMS = 360
    # calibrated on an idle machine: observed max ~1.20, mean ~1.02 over
    # 20 seeds; the band leaves headroom for loaded CI runners
    TOL_MAX = 1.35
    TOL_MEAN = 1.15

    def _run_one(self, seed):
        rng = random.Random(1000 + seed)

        def jittered(per_item):
            def fn(chunk):
                time.sleep(chunk.size * per_item * rng.uniform(0.85, 1.15))
            return fn

        model = CostModel()
        rt = HeteroRuntime(cost_model=model)
        # registered speeds are the jitter-free ground truth the oracle
        # splits on; the learned policy has to recover them from noisy
        # wall-clock completions
        rt.register_unit("acc0", WorkerKind.ACC, speed=2500.0,
                         work_fn=jittered(4e-4))
        rt.register_unit("acc1", WorkerKind.ACC, speed=2500.0,
                         work_fn=jittered(4e-4))
        rt.register_unit("cc0", WorkerKind.CC, speed=625.0,
                         work_fn=jittered(1.6e-3))
        rt.register_unit("cc1", WorkerKind.CC, speed=625.0,
                         work_fn=jittered(1.6e-3))
        kw = dict(acc_chunk=24, engine="interrupt")
        rt.parallel_for(num_items=self.N_ITEMS, policy="learned", **kw)
        learned = rt.parallel_for(num_items=self.N_ITEMS,
                                  policy="learned", **kw)
        oracle = rt.parallel_for(num_items=self.N_ITEMS,
                                 policy="oracle", **kw)
        for rep in (learned, oracle):
            assert rep.items == self.N_ITEMS
            assert_exact_tiling(rep.coverage, self.N_ITEMS)
        return learned.makespan / oracle.makespan

    def test_learned_tracks_oracle_under_jitter(self):
        ratios = [self._run_one(seed) for seed in range(self.N_SEEDS)]
        worst = max(ratios)
        mean = sum(ratios) / len(ratios)
        assert worst <= self.TOL_MAX, (
            f"worst learned/oracle ratio {worst:.3f} > {self.TOL_MAX} "
            f"(ratios: {[round(r, 3) for r in ratios]})")
        assert mean <= self.TOL_MEAN, (
            f"mean learned/oracle ratio {mean:.3f} > {self.TOL_MEAN} "
            f"(ratios: {[round(r, 3) for r in ratios]})")


# ---------------------------------------------------------------------------
# straggler quarantine (wall clock, real threads)
# ---------------------------------------------------------------------------
class Recorder:
    """Exact-once side-effect recorder shared across worker threads."""

    def __init__(self):
        self.lock = threading.Lock()
        self.done = {}
        self.chunk_counts = {}

    def work(self, per_item_fast, slow_unit, per_item_slow, slow_after):
        def fn(chunk):
            with self.lock:
                self.chunk_counts[chunk.worker] = (
                    self.chunk_counts.get(chunk.worker, 0) + 1)
                k = self.chunk_counts[chunk.worker]
            per_item = per_item_fast
            if chunk.worker == slow_unit and k > slow_after:
                per_item = per_item_slow
            time.sleep(per_item * chunk.size)
            with self.lock:
                for i in chunk.indices():
                    self.done[i] = self.done.get(i, 0) + 1
        return fn

    def assert_exact_once(self, n_items):
        assert sorted(self.done) == list(range(n_items))
        assert all(v == 1 for v in self.done.values())


class TestStragglerQuarantine:
    N_ITEMS = 2000

    def _run(self, work_fn, detector, n_items=N_ITEMS):
        rt = HeteroRuntime()
        for n in ("u0", "u1", "u2"):
            rt.register_unit(n, WorkerKind.CC)
        return rt.parallel_for(
            work_fn, num_items=n_items, policy="multidynamic", acc_chunk=8,
            scheduler_kwargs=dict(min_cc_chunk=8, max_cc_chunk=8),
            straggler=detector,
        )

    def test_sustained_slowdown_trips_after_patience(self):
        rec = Recorder()
        # u2 turns 20x slow after 2 warm chunks; alpha=0.6/threshold=6/
        # patience=3 convicts on its 3rd consecutive slow completion
        det = StragglerDetector(alpha=0.6, threshold=6.0, patience=3)
        rep = self._run(rec.work(0.0003, "u2", 0.006, slow_after=2), det)
        straggled = [e for e in (rep.events or [])
                     if e["action"] == "straggler"]
        assert [e["unit"] for e in straggled] == ["u2"]
        assert straggled[0]["ratio"] > 6.0
        # conviction needed patience consecutive breaches: 2 warm + 3 slow
        assert rep.per_worker_chunks["u2"] == 5
        rec.assert_exact_once(self.N_ITEMS)
        assert_exact_tiling(rep.coverage, self.N_ITEMS)
        assert_monotone_events(rep)
        # quarantined unit does no further work; survivors cover the rest
        assert rep.per_worker_items["u0"] > rep.per_worker_items["u2"]

    def test_single_slow_chunk_never_trips(self):
        rec = Recorder()
        det = StragglerDetector(alpha=0.6, threshold=6.0, patience=3)

        fast = rec.work(0.0003, "none", 0.0003, slow_after=0)

        def one_spike(chunk):
            with rec.lock:
                k = rec.chunk_counts.get(chunk.worker, 0)
            if chunk.worker == "u2" and k == 2:
                time.sleep(0.006 * chunk.size)  # exactly one slow chunk
                with rec.lock:
                    rec.chunk_counts[chunk.worker] = k + 1
                with rec.lock:
                    for i in chunk.indices():
                        rec.done[i] = rec.done.get(i, 0) + 1
                return
            fast(chunk)

        rep = self._run(one_spike, det, n_items=1200)
        assert not [e for e in (rep.events or [])
                    if e["action"] == "straggler"]
        rec.assert_exact_once(1200)
        # the spiked unit kept working after its one bad chunk
        assert rep.per_worker_chunks["u2"] > 3

    def test_last_active_unit_is_never_quarantined(self):
        # a single unit is always "slow" relative to itself with a
        # sub-1.0 threshold, but quarantining it would stall the run
        rt = HeteroRuntime()
        rt.register_unit("only", WorkerKind.CC)
        det = StragglerDetector(alpha=0.6, threshold=0.5, patience=1)
        rec = Recorder()
        rep = rt.parallel_for(
            rec.work(0.0002, "none", 0.0002, slow_after=0),
            num_items=200, policy="multidynamic", acc_chunk=8,
            scheduler_kwargs=dict(min_cc_chunk=8, max_cc_chunk=8),
            straggler=det,
        )
        assert not [e for e in (rep.events or [])
                    if e["action"] == "straggler"]
        rec.assert_exact_once(200)

    def test_detector_forgotten_unit_stops_skewing_median(self):
        det = StragglerDetector(alpha=1.0, threshold=2.0, patience=1)
        det.observe({"slow": 10.0})
        det.observe({"a": 1.0})
        det.observe({"b": 1.0})
        det.forget("slow")
        rep = det.observe({"a": 1.0})
        assert rep.median_step_time == pytest.approx(1.0)
        assert "slow" not in rep.ratios

    def test_breaches_count_only_observed_groups(self):
        # other units completing must not advance a slow unit's breach
        # count while it is idle: conviction needs patience *of its own*
        # observations
        det = StragglerDetector(alpha=1.0, threshold=2.0, patience=3)
        det.observe({"fast1": 1.0})
        det.observe({"fast2": 1.0})
        det.observe({"slow": 10.0})  # breach 1
        for _ in range(10):          # idle slow unit; fast units churn
            assert det.observe({"fast1": 1.0}).stragglers == []
        det.observe({"slow": 10.0})  # breach 2
        assert det.observe({"slow": 10.0}).stragglers == ["slow"]  # breach 3

    def test_straggler_rejected_off_interrupt_engine(self):
        det = StragglerDetector()
        rt = make_sim_runtime({"a": 10.0, "b": 10.0})
        with pytest.raises(ValueError, match="SimulatedClock"):
            rt.parallel_for(num_items=100, policy="multidynamic",
                            acc_chunk=8, straggler=det)
        wall = HeteroRuntime()
        wall.register_unit("a", WorkerKind.CC)
        with pytest.raises(ValueError, match="interrupt"):
            wall.parallel_for(lambda c: None, num_items=100,
                              engine="inline", straggler=det)

    def test_straggler_rejected_on_sharded_space(self):
        det = StragglerDetector()
        rt = HeteroRuntime()
        rt.register_unit("a", WorkerKind.CC)
        rt.register_unit("b", WorkerKind.CC)
        with pytest.raises(ValueError, match="shard"):
            rt.parallel_for(lambda c: None, space=ShardedSpace(100, 2),
                            engine="interrupt", straggler=det)


# ---------------------------------------------------------------------------
# persistence: versioned store round-trip + corruption fallback
# ---------------------------------------------------------------------------
class TestCostStore:
    SPEEDS = {"acc0": 120.0, "cc0": 15.0, "cc1": 45.0}

    def _warm_model(self, path=None):
        model = CostModel(path=path)
        rt = make_sim_runtime(self.SPEEDS, model=model)
        rt.parallel_for(num_items=1024, policy="learned", acc_chunk=32,
                        kernel="spmm")
        return model

    def test_round_trip_reproduces_identical_splits(self, tmp_path):
        store = tmp_path / "cost.json"
        model = self._warm_model(str(store))
        model.save()

        rt_live = make_sim_runtime(self.SPEEDS, model=model)
        rt_loaded = make_sim_runtime(self.SPEEDS,
                                     model=CostModel(str(store)))
        kwargs = dict(policy="learned", acc_chunk=32, kernel="spmm")
        assert rt_live.plan(4096, **kwargs) == rt_loaded.plan(4096, **kwargs)

    def test_loaded_model_presplits_immediately(self, tmp_path):
        store = tmp_path / "cost.json"
        self._warm_model(str(store)).save()
        rt = make_sim_runtime(self.SPEEDS, model=CostModel(str(store)))
        rep = rt.parallel_for(num_items=4096, policy="learned", acc_chunk=32,
                              kernel="spmm")
        assert rep.chunks <= len(self.SPEEDS)  # warm across runs, no re-warmup
        assert_exact_tiling(rep.coverage, 4096)

    def test_save_is_versioned_and_sorted(self, tmp_path):
        store = tmp_path / "cost.json"
        model = self._warm_model()
        model.save(str(store))
        doc = json.loads(store.read_text())
        assert doc["schema"] == STORE_SCHEMA
        units = [e["unit"] for e in doc["entries"]]
        assert units == sorted(units)
        assert not any(u.startswith("s0/") for u in units)

    def test_corrupted_store_warns_and_cold_starts(self, tmp_path):
        store = tmp_path / "cost.json"
        store.write_text("{ this is not json")
        with pytest.warns(CostModelWarning, match="cold-start"):
            model = CostModel(str(store))
        assert len(model) == 0
        # cold model still runs (adaptive fallback), then learns normally
        rt = make_sim_runtime(self.SPEEDS, model=model)
        rep = rt.parallel_for(num_items=512, policy="learned", acc_chunk=32)
        assert rep.items == 512
        assert model.coverage(list(self.SPEEDS), "default")

    def test_version_mismatch_warns_and_cold_starts(self, tmp_path):
        store = tmp_path / "cost.json"
        store.write_text(json.dumps({
            "schema": "costmodel/v0",
            "entries": [{"unit": "acc0", "kernel": "k", "throughput": 10.0}],
        }))
        with pytest.warns(CostModelWarning, match="costmodel/v0"):
            model = CostModel(str(store))
        assert len(model) == 0

    def test_missing_store_is_silent_cold_start(self, tmp_path):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            model = CostModel(str(tmp_path / "absent.json"))
        assert len(model) == 0

    def test_save_then_load_preserves_latency_fields(self, tmp_path):
        store = tmp_path / "cost.json"
        model = CostModel(str(store))
        model.observe("u0", "k", items=10, elapsed=1.0)
        model.observe_latency("u0", "k", dispatch=0.002, wire=0.001)
        model.save()
        loaded = CostModel(str(store)).lookup("u0", "k")
        assert loaded.dispatch_latency == pytest.approx(0.002)
        assert loaded.wire_latency == pytest.approx(0.001)


# ---------------------------------------------------------------------------
# shard-prefix merge: one physical unit, never k phantom entries
# ---------------------------------------------------------------------------
class TestShardMerge:
    def test_simulated_sharded_run_learns_unprefixed_units(self):
        speeds = {"acc0": 100.0, "cc0": 20.0}
        model = CostModel()
        rt = make_sim_runtime(speeds, model=model)
        rep = rt.parallel_for(num_items=0, space=ShardedSpace(2000, 2),
                              policy="multidynamic", acc_chunk=32)
        # the report itself is shard-prefixed ...
        assert any(k.startswith("s0/") for k in rep.per_worker_items)
        # ... but the model keys are physical units, and each unit's
        # learned throughput is its true speed (items and busy summed
        # across shards before the ratio)
        assert {e.unit for e in model.entries()} == set(speeds)
        for name, speed in speeds.items():
            assert model.throughput(name, "default") == pytest.approx(
                speed, rel=1e-6)

    def test_wall_sharded_run_merges_dispatch_latency_unprefixed(self):
        model = CostModel()
        rt = HeteroRuntime(cost_model=model)
        for n in ("u0", "u1"):
            rt.register_unit(n, WorkerKind.CC,
                             work_fn=lambda c: time.sleep(0.0002 * c.size))
        rep = rt.parallel_for(num_items=0, space=ShardedSpace(240, 2),
                              policy="multidynamic", acc_chunk=8,
                              engine="interrupt", backend="threads")
        assert any(k.startswith("s") for k in (rep.dispatch_latency or {}))
        entries = {e.unit: e for e in model.entries()}
        assert set(entries) == {"u0", "u1"}
        for e in entries.values():
            assert e.dispatch_latency is not None and e.dispatch_latency >= 0

    def test_observe_report_merges_prefixed_maps_directly(self):
        class FakeReport:
            per_worker_items = {"s0/acc0": 100, "s1/acc0": 300, "s1/cc0": 50}
            per_worker_busy = {"s0/acc0": 1.0, "s1/acc0": 3.0, "s1/cc0": 5.0}
            dispatch_latency = {"s0/acc0": 0.004, "s1/acc0": 0.002}
            wire_latency = None
            events = None

        model = CostModel()
        model.observe_report(FakeReport(), kernel="k")
        assert {e.unit for e in model.entries()} == {"acc0", "cc0"}
        # (100 + 300) items over (1 + 3) seconds, one observation
        assert model.throughput("acc0", "k") == pytest.approx(100.0)
        assert model.throughput("cc0", "k") == pytest.approx(10.0)
        # latencies average across the shard replicas that sampled
        assert model.lookup("acc0", "k").dispatch_latency == pytest.approx(
            0.003)
        assert model.lookup("cc0", "k").dispatch_latency is None

"""Backend units + the event-driven wall-clock engine (ISSUE 4).

The contract under test: real backend units (dedicated threads, process
pools, jax device streams) give *genuine* asynchronous dispatch — work
overlaps on real threads — while the scheduler invariants survive real
concurrency:

* completed chunks tile the space exactly (no index lost or duplicated),
* work-function side effects fire exactly once per index, even across
  randomized WallClock elastic join/leave schedules (a leave retires the
  unit: its in-flight chunk completes and counts; pre-split leftovers
  are requeued to survivors under the tracked scheduler's lock),
* ``RunReport.events`` is monotone in time and ``dispatch_latency`` is
  populated by the backend layer,
* kernels driven through ``parallel_for(space=TiledSpace,
  backend="threads")`` produce bit-exact results — thread dispatch can
  never silently reorder or corrupt tile writes,
* ``JaxDeviceUnit`` degrades cleanly to thread execution when jax is
  absent.

Everything here runs on a real WallClock with microsecond-scale sleeps,
so the whole module stays fast; the heavy randomized sweeps are marked
``slow`` per ``pytest.ini``.
"""

import threading
import time
from collections import Counter

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI container has no hypothesis; use the vendored shim
    from _propcheck import given, settings, strategies as st

import repro.core.backends as backends_mod
from repro.core import (
    CompletionBus,
    CompletionRecord,
    ElasticEvent,
    ElasticSchedule,
    HeteroRuntime,
    InlineUnit,
    JaxDeviceUnit,
    ProcessPoolUnit,
    ShardedSpace,
    ThreadUnit,
    TiledSpace,
    WorkerKind,
)
from repro.core.backends import make_backend
from repro.core.runtime import POLICIES
from repro.core.scheduler import Chunk


def assert_exact_tiling(spans, n_items):
    assert spans, "no chunks completed"
    assert spans[0][0] == 0
    assert spans[-1][1] == n_items
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c, f"gap or overlap at {b}:{c}"


class Recorder:
    """Thread-safe exact-once ledger the work functions write into."""

    def __init__(self, per_item_sleep=0.0):
        self.lock = threading.Lock()
        self.counts = Counter()
        self.per_item_sleep = per_item_sleep

    def __call__(self, chunk):
        if self.per_item_sleep:
            time.sleep(chunk.size * self.per_item_sleep)
        with self.lock:
            self.counts.update(chunk.indices())

    def assert_exactly_once(self, n_items):
        assert set(self.counts) == set(range(n_items)), (
            f"missing {sorted(set(range(n_items)) - set(self.counts))[:5]}..."
        )
        dupes = {i: c for i, c in self.counts.items() if c != 1}
        assert not dupes, f"indices executed more than once: {dupes}"


# ---------------------------------------------------------------------------
# individual backend units
# ---------------------------------------------------------------------------
class TestUnits:
    def _drive(self, unit, chunks, work_fn):
        bus = CompletionBus()
        unit.start(bus)
        try:
            recs = []
            for c in chunks:
                unit.submit(c, work_fn)
                assert bus.wait(timeout=10.0)
                recs.extend(bus.drain())
            return recs
        finally:
            unit.close()

    @pytest.mark.parametrize("cls", [InlineUnit, ThreadUnit])
    def test_submit_completes_with_result_and_latency(self, cls):
        unit = cls("u0")
        recs = self._drive(
            unit, [Chunk(0, 4, "u0"), Chunk(4, 9, "u0")],
            lambda c: c.size * 10,
        )
        assert [r.result for r in recs] == [40, 50]
        assert all(r.error is None for r in recs)
        assert all(r.dispatch_latency >= 0 for r in recs)
        assert len(unit.dispatch_latencies) == 2

    def test_thread_unit_runs_off_the_caller_thread(self):
        unit = ThreadUnit("u0")
        caller = threading.get_ident()
        recs = self._drive(
            unit, [Chunk(0, 1, "u0")], lambda c: threading.get_ident()
        )
        assert recs[0].result != caller

    def test_inline_unit_runs_on_the_caller_thread(self):
        unit = InlineUnit("u0")
        recs = self._drive(
            unit, [Chunk(0, 1, "u0")], lambda c: threading.get_ident()
        )
        assert recs[0].result == threading.get_ident()

    def test_error_is_captured_not_raised(self):
        def boom(c):
            raise RuntimeError("kaput")

        recs = self._drive(ThreadUnit("u0"), [Chunk(0, 1, "u0")], boom)
        assert isinstance(recs[0].error, RuntimeError)

    def test_thread_unit_restartable_across_runs(self):
        unit = ThreadUnit("u0")
        r1 = self._drive(unit, [Chunk(0, 2, "u0")], lambda c: c.size)
        r2 = self._drive(unit, [Chunk(2, 5, "u0")], lambda c: c.size)
        assert (r1[0].result, r2[0].result) == (2, 3)

    def test_process_unit_executes_in_worker(self):
        unit = ProcessPoolUnit("p0")
        recs = self._drive(
            unit, [Chunk(0, 10, "p0")], _sum_indices
        )
        if unit.degraded:  # sandbox without process support: thread fallback
            pytest.skip("process pool unavailable; degraded to thread")
        assert recs[0].result == sum(range(10))
        assert recs[0].error is None

    def test_jax_unit_dispatches_jitted_work(self):
        jax = pytest.importorskip("jax")
        import jax.numpy as jnp

        f = jax.jit(lambda x: (x * 2.0).sum())
        unit = JaxDeviceUnit("d0")
        recs = self._drive(
            unit, [Chunk(0, 8, "d0")],
            lambda c: f(jnp.arange(c.size, dtype=jnp.float32)),
        )
        assert not unit.degraded
        assert float(recs[0].result) == float(sum(2.0 * i for i in range(8)))

    def test_jax_unit_degrades_cleanly_without_jax(self, monkeypatch):
        # the ISSUE acceptance: no jax -> ThreadUnit semantics, not a crash
        monkeypatch.setattr(backends_mod, "_jax_module", lambda: None)
        unit = JaxDeviceUnit("d0")
        recs = self._drive(unit, [Chunk(0, 6, "d0")], lambda c: c.size)
        assert unit.degraded
        assert recs[0].result == 6 and recs[0].error is None
        assert len(unit.dispatch_latencies) == 1

    def test_unknown_backend_spec_rejected(self):
        rt = HeteroRuntime()
        with pytest.raises(ValueError, match="unknown backend"):
            rt.register_unit("a", WorkerKind.CC, backend="gpu-go-brrr")

    def test_instance_name_must_match_unit_name(self):
        # completions are routed by unit name: a mismatched (or shared)
        # instance would post completions the scheduler cannot attribute
        rt = HeteroRuntime()
        with pytest.raises(ValueError, match="names must match"):
            rt.register_unit("cc0", WorkerKind.CC, work_fn=lambda c: None,
                             backend=ThreadUnit("mine"))
        rt2 = HeteroRuntime()
        rt2.register_unit("cc0", WorkerKind.CC, work_fn=lambda c: None)
        with pytest.raises(ValueError, match="names must match"):
            rt2.parallel_for(num_items=10, engine="interrupt",
                             backend=ThreadUnit("other"))
        # a shared instance cannot back two units: the second unit's name
        # can never match too
        shared = ThreadUnit("u0")
        rt3 = HeteroRuntime()
        rt3.register_unit("u0", WorkerKind.CC, work_fn=lambda c: None,
                          backend=shared)
        with pytest.raises(ValueError, match="names must match"):
            rt3.register_unit("u1", WorkerKind.CC, work_fn=lambda c: None,
                              backend=shared)

    def test_matching_instance_backend_works(self):
        rec = Recorder()
        rt = HeteroRuntime()
        rt.register_unit("cc0", WorkerKind.CC, work_fn=rec,
                         backend=ThreadUnit("cc0"))
        rep = rt.parallel_for(num_items=50, engine="interrupt", acc_chunk=8)
        assert rep.items == 50
        rec.assert_exactly_once(50)


def _sum_indices(chunk):
    """Module-level so ProcessPoolUnit can pickle it."""
    return sum(range(chunk.start, chunk.stop))


def _raise_in_pool(chunk):
    """Module-level so ProcessPoolUnit can pickle it; always fails."""
    raise ValueError(f"pool boom at {chunk.start}")


# ---------------------------------------------------------------------------
# ProcessPoolUnit error paths (ISSUE 5 satellite): a raising work_fn must
# surface through the CompletionBus and fail parallel_for cleanly — never
# hang the dispatcher waiting on a completion that was swallowed
# ---------------------------------------------------------------------------
class TestProcessPoolErrors:
    def test_pool_exception_surfaces_on_the_bus(self):
        unit = ProcessPoolUnit("p0")
        bus = CompletionBus()
        unit.start(bus)
        try:
            unit.submit(Chunk(3, 7, "p0"), _raise_in_pool)
            assert bus.wait(timeout=60.0), "no completion posted for the error"
            recs = bus.drain()
            assert len(recs) == 1
            assert isinstance(recs[0].error, ValueError)
            assert "pool boom at 3" in str(recs[0].error)
            assert recs[0].result is None
        finally:
            unit.close()

    def test_pool_exception_fails_parallel_for_cleanly(self):
        rt = HeteroRuntime()
        rt.register_unit("p0", WorkerKind.CC, work_fn=_raise_in_pool,
                         backend="process")
        rt.register_unit("p1", WorkerKind.CC, work_fn=_raise_in_pool,
                         backend="process")
        with pytest.raises(ValueError, match="pool boom"):
            rt.parallel_for(num_items=64, engine="interrupt", acc_chunk=8)

    def test_pool_error_then_unit_still_usable(self):
        # an error completion must not wedge the pool: the same unit keeps
        # serving submissions afterwards
        unit = ProcessPoolUnit("p0")
        bus = CompletionBus()
        unit.start(bus)
        try:
            unit.submit(Chunk(0, 2, "p0"), _raise_in_pool)
            assert bus.wait(timeout=60.0)
            assert isinstance(bus.drain()[0].error, ValueError)
            unit.submit(Chunk(0, 4, "p0"), _sum_indices)
            assert bus.wait(timeout=60.0)
            rec = bus.drain()[0]
            assert rec.error is None and rec.result == sum(range(4))
        finally:
            unit.close()


# ---------------------------------------------------------------------------
# JaxDeviceUnit degradation (ISSUE 5 satellite): without jax, behaviour is
# bit-identical to a ThreadUnit — same coverage, same report fields, same
# exact-once side effects
# ---------------------------------------------------------------------------
class TestJaxDegradationParity:
    def _run(self, backend_spec):
        rec = Recorder()
        rt = HeteroRuntime()
        rt.register_unit("u0", WorkerKind.CC, work_fn=rec,
                         backend=backend_spec)
        # a fixed pre-split makes the run fully deterministic, so the two
        # backends can be compared field-for-field, not just in aggregate
        rep = rt.parallel_for(num_items=96, policy={"u0": (0, 96)},
                              engine="interrupt")
        return rep, rec

    def test_no_jax_degrades_bit_identically_to_thread(self, monkeypatch):
        monkeypatch.setattr(backends_mod, "_jax_module", lambda: None)
        probe = JaxDeviceUnit("probe")
        probe.start(CompletionBus())
        assert probe.degraded, "monkeypatched import must trigger degradation"
        probe.close()

        rep_jax, rec_jax = self._run("jax")
        rep_thr, rec_thr = self._run("thread")
        assert rec_jax.counts == rec_thr.counts
        for field in ("items", "chunks", "coverage", "per_worker_items",
                      "per_worker_chunks"):
            assert getattr(rep_jax, field) == getattr(rep_thr, field), field
        assert set(rep_jax.dispatch_latency) == set(rep_thr.dispatch_latency)
        # neither path has a transport in it
        assert rep_jax.wire_latency is None and rep_thr.wire_latency is None


# ---------------------------------------------------------------------------
# make_backend negatives (ISSUE 5 satellite): an unknown spec must teach
# the caller every valid spec, including the remote: form
# ---------------------------------------------------------------------------
class TestBackendSpecErrors:
    @pytest.mark.parametrize("bad", ["gpu-go-brrr", "remote", "threadz", ""])
    def test_unknown_spec_lists_all_valid_specs(self, bad):
        with pytest.raises(ValueError, match="unknown backend") as ei:
            make_backend(bad, "u0")
        message = str(ei.value)
        for expected in ("'inline'", "'thread'/'threads'",
                         "'process'/'processes'", "'jax'",
                         "'remote:<host:port>'", "BackendUnit instance"):
            assert expected in message, f"error does not teach {expected}"

    def test_register_unit_propagates_the_listing(self):
        rt = HeteroRuntime()
        with pytest.raises(ValueError, match="remote:<host:port>"):
            rt.register_unit("a", WorkerKind.CC, backend="gpu-go-brrr")


# ---------------------------------------------------------------------------
# CompletionBus under concurrent posters (ISSUE 5 satellite): N producer
# threads x M records each — no record lost, none duplicated, regardless
# of how posts interleave with waits/drains
# ---------------------------------------------------------------------------
class TestCompletionBusProperty:
    @given(n_threads=st.integers(2, 6), per_thread=st.integers(5, 40),
           seed=st.integers(0, 10**6))
    @settings(max_examples=25, deadline=None)
    def test_no_lost_or_duplicated_records(self, n_threads, per_thread, seed):
        import random

        bus = CompletionBus()
        barrier = threading.Barrier(n_threads)

        def producer(t):
            rng = random.Random(seed * 1009 + t)
            barrier.wait()
            for k in range(per_thread):
                if rng.random() < 0.25:
                    time.sleep(rng.uniform(0.0, 1e-4))
                bus.post(CompletionRecord(
                    unit=f"u{t}", chunk=Chunk(k, k + 1, f"u{t}"),
                    elapsed=0.0, dispatch_latency=0.0,
                ))

        threads = [threading.Thread(target=producer, args=(t,), daemon=True)
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        total = n_threads * per_thread
        collected = []
        deadline = time.perf_counter() + 30.0
        while len(collected) < total and time.perf_counter() < deadline:
            bus.wait(timeout=1.0)
            collected.extend(bus.drain())
        for t in threads:
            t.join(timeout=10.0)
        collected.extend(bus.drain())
        assert len(collected) == total
        tally = Counter((r.unit, r.chunk.start) for r in collected)
        assert all(c == 1 for c in tally.values()), (
            f"duplicated records: {[k for k, c in tally.items() if c != 1]}"
        )
        assert set(tally) == {(f"u{t}", k)
                              for t in range(n_threads)
                              for k in range(per_thread)}


# ---------------------------------------------------------------------------
# sharded CompletionBus (ISSUE 8): per-unit slots + a single notify event
# replace the global-lock scan; same API, so the contracts get harder —
# N producers x M registered slots, and wait() may never miss a notify
# ---------------------------------------------------------------------------
class TestCompletionBusSharded:
    @given(n_threads=st.integers(2, 8), n_units=st.integers(1, 5),
           per_thread=st.integers(10, 60), seed=st.integers(0, 10**6))
    @settings(max_examples=15, deadline=None)
    def test_n_producers_m_unit_slots_no_loss(self, n_threads, n_units,
                                              per_thread, seed):
        import random

        bus = CompletionBus()
        for m in range(n_units):
            bus.register(f"u{m}")  # dedicated slots (the fast path)
        # one unregistered unit exercises the default slot alongside them
        names = [f"u{m}" for m in range(n_units)] + ["ghost"]
        barrier = threading.Barrier(n_threads)

        def producer(t):
            rng = random.Random(seed * 7919 + t)
            barrier.wait()
            for k in range(per_thread):
                if rng.random() < 0.2:
                    time.sleep(rng.uniform(0.0, 1e-4))
                unit = names[rng.randrange(len(names))]
                bus.post(CompletionRecord(
                    unit=unit, chunk=Chunk(t * per_thread + k,
                                           t * per_thread + k + 1, unit),
                    elapsed=0.0, dispatch_latency=0.0,
                ))

        producers = [threading.Thread(target=producer, args=(t,), daemon=True)
                     for t in range(n_threads)]
        collected, clock = [], threading.Lock()
        stop = threading.Event()

        def consumer():
            while not stop.is_set():
                bus.wait(timeout=0.2)
                got = bus.drain()
                if got:
                    with clock:
                        collected.extend(got)

        consumers = [threading.Thread(target=consumer, daemon=True)
                     for _ in range(2)]
        for t in producers + consumers:
            t.start()
        for t in producers:
            t.join(timeout=30.0)
        total = n_threads * per_thread
        deadline = time.perf_counter() + 30.0
        while time.perf_counter() < deadline:
            with clock:
                if len(collected) >= total:
                    break
            time.sleep(1e-3)
        stop.set()
        for t in consumers:
            t.join(timeout=10.0)
        collected.extend(bus.drain())
        assert len(collected) == total
        tally = Counter(r.chunk.start for r in collected)
        dupes = {k for k, c in tally.items() if c != 1}
        assert not dupes, f"lost or duplicated completions: {sorted(dupes)}"
        assert set(tally) == set(range(total))

    def test_wait_never_misses_a_notify_ping_pong(self):
        # strict alternation: every post must wake exactly one wait();
        # a lost wakeup shows up as a timed-out round
        bus = CompletionBus()
        bus.register("u0")
        ack = threading.Event()
        rounds = 400

        def producer():
            for k in range(rounds):
                bus.post(CompletionRecord(
                    unit="u0", chunk=Chunk(k, k + 1, "u0"),
                    elapsed=0.0, dispatch_latency=0.0,
                ))
                assert ack.wait(timeout=10.0)
                ack.clear()

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        seen = 0
        for _ in range(rounds):
            assert bus.wait(timeout=10.0), (
                f"wait() missed the notify after {seen} records")
            got = bus.drain()
            assert len(got) == 1
            assert got[0].chunk.start == seen
            seen += 1
            ack.set()
        t.join(timeout=10.0)
        assert seen == rounds

    def test_register_is_idempotent_and_preserves_queued_records(self):
        bus = CompletionBus()
        bus.post(CompletionRecord(unit="u0", chunk=Chunk(0, 1, "u0"),
                                  elapsed=0.0, dispatch_latency=0.0))
        bus.register("u0")
        bus.register("u0")
        bus.post(CompletionRecord(unit="u0", chunk=Chunk(1, 2, "u0"),
                                  elapsed=0.0, dispatch_latency=0.0))
        got = bus.drain()
        assert sorted(r.chunk.start for r in got) == [0, 1]


# ---------------------------------------------------------------------------
# engine pipelining (ISSUE 8): a unit advertising capacity > 1 gets that
# many chunks in flight before the per-dispatch flush() fires
# ---------------------------------------------------------------------------
class BatchingProbeUnit(backends_mod.BackendUnit):
    """Pipelined fake: buffers submits, executes on flush, records depths."""

    def __init__(self, name, capacity):
        super().__init__(name)
        self.capacity = capacity
        self._buf = []
        self.flush_batches = []

    def submit(self, chunk, work_fn):
        self._buf.append((chunk, work_fn, time.perf_counter()))

    def flush(self):
        batch, self._buf = self._buf, []
        if not batch:
            return
        self.flush_batches.append(len(batch))
        for chunk, fn, t0 in batch:
            self._execute(chunk, fn, t0)


class TestEnginePipelining:
    def _run(self, capacity, n_items=64, acc_chunk=4):
        rec = Recorder()
        rt = HeteroRuntime()
        probe = BatchingProbeUnit("b0", capacity=capacity)
        rt.register_unit("b0", WorkerKind.CC, work_fn=rec, backend=probe)
        rep = rt.parallel_for(num_items=n_items, policy="multidynamic",
                              engine="interrupt", acc_chunk=acc_chunk)
        return rep, rec, probe

    def test_capacity_fills_before_flush(self):
        rep, rec, probe = self._run(capacity=4)
        assert rep.items == 64
        assert_exact_tiling(rep.coverage, 64)
        rec.assert_exactly_once(64)
        assert sum(probe.flush_batches) == rep.chunks  # all went via flush
        assert max(probe.flush_batches) >= 2, (
            "engine never pipelined past one in-flight chunk "
            f"(flush depths: {probe.flush_batches})")
        assert probe.flush_batches[0] == 4, (
            "first dispatch must fill the advertised capacity")

    def test_capacity_one_keeps_strict_alternation(self):
        rep, rec, probe = self._run(capacity=1)
        assert rep.items == 64
        rec.assert_exactly_once(64)
        assert probe.flush_batches == [1] * rep.chunks


# ---------------------------------------------------------------------------
# the event-driven engine through parallel_for
# ---------------------------------------------------------------------------
def make_wall_runtime(work_fn, n_units=3, backend=None):
    rt = HeteroRuntime()
    for i in range(n_units):
        rt.register_unit(f"cc{i}", WorkerKind.CC, work_fn=work_fn,
                         backend=backend)
    return rt


class TestWallEngine:
    def test_three_thread_units_cover_exactly_once(self):
        rec = Recorder(per_item_sleep=2e-5)
        rep = make_wall_runtime(rec).parallel_for(
            num_items=400, policy="multidynamic", engine="interrupt",
            acc_chunk=16,
        )
        assert rep.items == 400
        assert_exact_tiling(rep.coverage, 400)
        rec.assert_exactly_once(400)
        # every unit got work and the backend layer measured dispatch
        assert all(v > 0 for v in rep.per_worker_items.values())
        assert set(rep.dispatch_latency) == set(rep.per_worker_items)
        assert all(v >= 0 for v in rep.dispatch_latency.values())

    def test_work_overlaps_on_real_threads(self):
        # with per-chunk sleeps, N threads must beat the serial sum;
        # inline execution (same engine, no overlap) is the control
        def run(backend):
            rec = Recorder(per_item_sleep=1e-4)
            t0 = time.perf_counter()
            make_wall_runtime(rec, n_units=4, backend=backend).parallel_for(
                num_items=600, policy="static", engine="interrupt",
            )
            return time.perf_counter() - t0

        wall_threads = run("threads")
        wall_inline = run("inline")
        # 4-way overlap over 15ms/unit sleeps vs a 60ms serial sweep: even
        # with scheduler/thread overhead the ratio sits near 0.3
        assert wall_threads < wall_inline * 0.7, (wall_threads, wall_inline)

    def test_error_in_work_fn_propagates(self):
        def boom(c):
            raise ValueError("chunk exploded")

        with pytest.raises(ValueError, match="chunk exploded"):
            make_wall_runtime(boom).parallel_for(
                num_items=100, engine="interrupt", acc_chunk=8
            )

    @pytest.mark.parametrize("policy", POLICIES)
    def test_every_policy_exact_once_on_threads(self, policy):
        rec = Recorder(per_item_sleep=1e-5)
        rep = make_wall_runtime(rec).parallel_for(
            num_items=331, policy=policy, engine="interrupt", acc_chunk=16,
        )
        assert rep.items == 331
        assert_exact_tiling(rep.coverage, 331)
        rec.assert_exactly_once(331)

    def test_process_backend_through_parallel_for(self):
        rt = HeteroRuntime()
        rt.register_unit("p0", WorkerKind.CC, work_fn=_sum_indices,
                         backend="process")
        rt.register_unit("p1", WorkerKind.CC, work_fn=_sum_indices,
                         backend="process")
        rep = rt.parallel_for(num_items=64, engine="interrupt", acc_chunk=8)
        assert rep.items == 64
        assert_exact_tiling(rep.coverage, 64)

    def test_sharded_wall_run_with_placement(self):
        rec = Recorder(per_item_sleep=1e-5)
        rt = HeteroRuntime()
        for i in range(2):
            rt.register_unit(f"acc{i}", WorkerKind.ACC, work_fn=rec)
            rt.register_unit(f"cc{i}", WorkerKind.CC, work_fn=rec)
        sp = ShardedSpace(300, 2, placement={"acc0": 0, "acc1": 1})
        rep = rt.parallel_for(space=sp, policy="multidynamic",
                              engine="interrupt", acc_chunk=16)
        assert rep.items == 300
        assert_exact_tiling(rep.coverage, 300)
        rec.assert_exactly_once(300)
        # pinned units appear only on their shard; cc units replicate
        keys = set(rep.per_worker_items)
        assert "s0/acc0" in keys and "s1/acc1" in keys
        assert "s1/acc0" not in keys and "s0/acc1" not in keys
        assert {"s0/cc0", "s0/cc1", "s1/cc0", "s1/cc1"} <= keys

    def test_placement_validation(self):
        with pytest.raises(ValueError, match="nonexistent"):
            ShardedSpace(100, 2, placement={"acc0": 5})
        rt = HeteroRuntime()
        rt.register_unit("a", WorkerKind.ACC, work_fn=lambda c: None)
        with pytest.raises(ValueError, match="unknown units"):
            rt.parallel_for(space=ShardedSpace(100, 2,
                                               placement={"ghost": 0}),
                            engine="inline")
        # a placement that strands a shard with no units is rejected
        with pytest.raises(ValueError, match="without any units"):
            rt.parallel_for(space=ShardedSpace(100, 2, placement={"a": 0}),
                            engine="inline")


# ---------------------------------------------------------------------------
# WallClock elasticity: thread-safe membership in the event engine
# ---------------------------------------------------------------------------
class TestWallElastic:
    def test_leave_and_join_exact_once(self):
        rec = Recorder(per_item_sleep=5e-5)
        rep = make_wall_runtime(rec).parallel_for(
            rec, num_items=400, policy="multidynamic", engine="interrupt",
            acc_chunk=8,
            elastic=(ElasticSchedule()
                     .leave(0.002, "cc0")
                     .join(0.004, "cc_new", kind="cc")),
        )
        assert rep.items == 400
        assert_exact_tiling(rep.coverage, 400)
        rec.assert_exactly_once(400)
        assert [e["action"] for e in rep.events] == ["leave", "join"]
        assert rep.per_worker_items["cc_new"] > 0
        # retired unit stopped early: it did less than the survivors
        assert (rep.per_worker_items["cc0"]
                < max(rep.per_worker_items.values()))

    def test_leave_retires_but_inflight_chunk_counts(self):
        # wall-clock semantics: real work cannot be recalled — the leave
        # event is recorded with requeued=None and coverage stays exact
        rec = Recorder(per_item_sleep=2e-4)
        rep = make_wall_runtime(rec).parallel_for(
            num_items=120, policy="multidynamic", engine="interrupt",
            acc_chunk=4, elastic=ElasticSchedule().leave(0.003, "cc1"),
        )
        assert rep.items == 120
        rec.assert_exactly_once(120)
        assert rep.events[0]["requeued"] is None

    def test_presplit_leftovers_requeued_to_survivors(self):
        # a leave due at t=0 lands before the unit's first dispatch, so its
        # entire never-issued static assignment must travel through the
        # requeue buffer to the survivors — the exact-once requeue path
        # under real concurrency
        rec = Recorder(per_item_sleep=2e-4)
        rep = make_wall_runtime(rec).parallel_for(
            num_items=300, policy="static", engine="interrupt",
            elastic=ElasticSchedule().leave(0.0, "cc2"),
        )
        assert rep.items == 300
        assert_exact_tiling(rep.coverage, 300)
        rec.assert_exactly_once(300)
        assert rep.per_worker_items["cc2"] == 0  # never dispatched
        survivors = {"cc0", "cc1"}
        assert sum(rep.per_worker_items[u] for u in survivors) == 300

    def test_all_units_leave_raises_stall(self):
        rec = Recorder(per_item_sleep=1e-3)
        with pytest.raises(RuntimeError, match="stalled"):
            make_wall_runtime(rec, n_units=2).parallel_for(
                num_items=500, policy="multidynamic", engine="interrupt",
                acc_chunk=4,
                elastic=ElasticSchedule().leave(0.004, "cc0").leave(0.004, "cc1"),
            )

    def test_rescue_join_after_total_departure(self):
        rec = Recorder(per_item_sleep=1e-4)
        rep = make_wall_runtime(rec, n_units=2).parallel_for(
            rec, num_items=100, policy="multidynamic", engine="interrupt",
            acc_chunk=4,
            elastic=(ElasticSchedule()
                     .leave(0.002, "cc0").leave(0.002, "cc1")
                     .join(0.01, "fresh", kind="cc")),
        )
        assert rep.items == 100
        rec.assert_exactly_once(100)
        assert rep.per_worker_items["fresh"] > 0

    def test_late_events_are_dropped(self):
        rec = Recorder()
        rep = make_wall_runtime(rec).parallel_for(
            num_items=60, policy="multidynamic", engine="interrupt",
            acc_chunk=8, elastic=ElasticSchedule().leave(30.0, "cc0"),
        )
        assert rep.items == 60
        assert not rep.events
        # and, critically, the run did not wait 30 seconds for the event
        # (parallel_for returned — reaching this line is the assertion)

    def test_events_are_monotone_and_run_relative(self):
        rec = Recorder(per_item_sleep=1e-4)
        sched = (ElasticSchedule()
                 .leave(0.002, "cc0")
                 .join(0.004, "j0", kind="cc")
                 .leave(0.006, "cc1")
                 .join(0.008, "j1", kind="cc"))
        rep = make_wall_runtime(rec, n_units=4).parallel_for(
            rec, num_items=600, policy="multidynamic", engine="interrupt",
            acc_chunk=8, elastic=sched,
        )
        times = [e["t"] for e in rep.events]
        assert times == sorted(times), "events not monotone in time"
        assert all(0.0 <= t <= rep.makespan + 0.5 for t in times)
        assert [e["unit"] for e in rep.events] == ["cc0", "j0", "cc1", "j1"]


# ---------------------------------------------------------------------------
# the randomized concurrency battery (the ISSUE's headline)
# ---------------------------------------------------------------------------
def random_elastic_battery(seed, n_items_max, sleep_scale):
    """One randomized WallClock elastic run; returns (report, recorder, n)."""
    import random

    rng = random.Random(seed)
    n_units = rng.randint(3, 5)
    n_items = rng.randint(60, n_items_max)
    acc_chunk = rng.choice([2, 4, 8, 16, 32])
    policy = POLICIES[rng.randrange(3)]
    rec = Recorder(per_item_sleep=rng.uniform(0.5, 2.0) * sleep_scale)
    rt = make_wall_runtime(rec, n_units=n_units)

    sched = ElasticSchedule()
    # leave at most n_units - 1 so the run can always finish (joins may
    # rescue, but must not be required to)
    for i, unit in enumerate(rng.sample(range(n_units), rng.randint(0, n_units - 1))):
        sched.leave(rng.uniform(0.0, 0.02), f"cc{unit}")
    for j in range(rng.randint(0, 2)):
        sched.join(rng.uniform(0.0, 0.03), f"joiner{j}", kind="cc")

    rep = rt.parallel_for(
        rec, num_items=n_items, policy=policy, engine="interrupt",
        acc_chunk=acc_chunk, elastic=sched,
    )
    return rep, rec, n_items


class TestConcurrencyBattery:
    """≥20 random WallClock elastic schedules: zero lost/duplicated items."""

    @given(seed=st.integers(0, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_exact_once_under_random_churn(self, seed):
        rep, rec, n_items = random_elastic_battery(
            seed, n_items_max=200, sleep_scale=2e-5
        )
        assert rep.items == n_items
        assert rep.chunks == len(rep.coverage)
        assert_exact_tiling(rep.coverage, n_items)
        rec.assert_exactly_once(n_items)
        times = [e["t"] for e in (rep.events or [])]
        assert times == sorted(times), "events not monotone"

    @pytest.mark.slow
    @given(seed=st.integers(0, 10**6))
    @settings(max_examples=30, deadline=None)
    def test_exact_once_under_random_churn_heavy(self, seed):
        rep, rec, n_items = random_elastic_battery(
            seed + 7_777_777, n_items_max=1200, sleep_scale=5e-5
        )
        assert rep.items == n_items
        assert_exact_tiling(rep.coverage, n_items)
        rec.assert_exactly_once(n_items)
        times = [e["t"] for e in (rep.events or [])]
        assert times == sorted(times)


# ---------------------------------------------------------------------------
# kernels through the runtime: bit-exact under real-thread dispatch
# ---------------------------------------------------------------------------
class TestKernelRuntimeParity:
    def test_spmm_tiles_bit_exact_through_threads(self):
        np = pytest.importorskip("numpy")
        jnp = pytest.importorskip("jax.numpy")
        from repro.kernels.spmm.ref import make_problem, spmm_ell_ref

        R, C, N = 64, 96, 16
        p = make_problem(R, C, N, nnz_mean=6.0, seed=3)
        vals, cols, rhs = (jnp.asarray(p.vals), jnp.asarray(p.cols),
                           jnp.asarray(p.rhs))
        expect = np.asarray(spmm_ell_ref(vals, cols, rhs))

        space = TiledSpace(grid=(R, N), tile=(8, N))  # one tile = 8 rows
        out = np.zeros((R, N), np.float32)

        def work(chunk):
            for rs, _cs in space.chunk_slices(chunk):
                out[rs] = np.asarray(
                    spmm_ell_ref(vals[rs], cols[rs], rhs)
                )  # disjoint row bands: thread writes cannot collide

        rt = HeteroRuntime()
        for i in range(3):
            rt.register_unit(f"cc{i}", WorkerKind.CC, work_fn=work)
        rep = rt.parallel_for(space=space, policy="multidynamic",
                              engine="interrupt", acc_chunk=2,
                              backend="threads")
        assert rep.items == space.num_items
        assert_exact_tiling(rep.coverage, space.num_items)
        assert np.array_equal(out, expect), "thread dispatch corrupted tiles"

    def test_hotspot_tiles_bit_exact_through_threads(self):
        np = pytest.importorskip("numpy")
        jnp = pytest.importorskip("jax.numpy")
        from repro.configs.paper_eneac import HotspotConfig
        from repro.kernels.hotspot.ops import hotspot_step_banded
        from repro.kernels.hotspot.ref import hotspot_step_ref

        R = C = 64
        band = 8
        cfg = HotspotConfig(grid=R, iterations=1)
        rng = np.random.default_rng(0)
        t = jnp.asarray(80.0 + 10 * rng.random((R, C), np.float32))
        pw = jnp.asarray(rng.random((R, C), np.float32))
        expect = np.asarray(hotspot_step_ref(t, pw, cfg))

        space = TiledSpace(grid=(R, C), tile=(band, C))
        out = np.zeros((R, C), np.float32)

        def work(chunk):
            for rs, _cs in space.chunk_slices(chunk):
                lo = max(rs.start - 1, 0)     # one halo row each side
                hi = min(rs.stop + 1, R)
                res = np.asarray(
                    hotspot_step_banded(t[lo:hi], pw[lo:hi], cfg, (R, C))
                )
                out[rs] = res[rs.start - lo: rs.start - lo + (rs.stop - rs.start)]

        rt = HeteroRuntime()
        for i in range(3):
            rt.register_unit(f"cc{i}", WorkerKind.CC, work_fn=work)
        rep = rt.parallel_for(space=space, policy="multidynamic",
                              engine="interrupt", acc_chunk=2,
                              backend="threads")
        assert rep.items == space.num_items
        assert np.array_equal(out, expect), "banded stencil diverged from ref"

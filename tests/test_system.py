"""End-to-end system behaviour: training convergence, checkpoint-restart
equivalence, hetero microbatching integration, hybrid executor adaptation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.parallel_for import HybridExecutor
from repro.launch.train import TrainLoopConfig, run_training
from repro.models import make_model
from repro.optim import AdamW
from repro.parallel.mesh_rules import MeshRules
from repro.launch.steps import make_train_step


class TestTrainingLoop:
    def test_loss_decreases_on_learnable_data(self, tmp_path):
        out = run_training(TrainLoopConfig(
            arch="tinyllama-1.1b", steps=40, global_batch=8, seq_len=64,
            lr=3e-3, ckpt_dir=str(tmp_path), ckpt_every=20,
        ))
        assert out["steps"] == 40
        assert out["final_loss"] < out["first_loss"]

    def test_checkpoint_restart_resumes(self, tmp_path):
        run_training(TrainLoopConfig(
            arch="tinyllama-1.1b", steps=10, global_batch=4, seq_len=32,
            ckpt_dir=str(tmp_path), ckpt_every=10,
        ))
        out = run_training(TrainLoopConfig(
            arch="tinyllama-1.1b", steps=14, global_batch=4, seq_len=32,
            ckpt_dir=str(tmp_path), ckpt_every=100, resume=True,
        ))
        assert out["steps"] == 4  # resumed from step 10

    def test_microbatched_step_matches_monolithic(self):
        """Grad accumulation is numerically equivalent to one big batch."""
        cfg = get_config("tinyllama-1.1b").smoke()
        model = make_model(cfg)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = MeshRules(mesh, cfg.parallel)
        shape = InputShape("t", 32, 8, "train")
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW()
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 33), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                 "mask": jnp.ones((8, 32), jnp.float32)}
        outs = {}
        with mesh:
            for mb in (1, 4):
                bundle = make_train_step(model, opt, rules, shape,
                                         microbatches=mb, loss_chunk=0)
                p2, _, metrics = bundle.jit()(
                    jax.tree.map(jnp.copy, params), opt.init(params), batch)
                outs[mb] = (float(metrics["loss"]), p2)
        assert outs[1][0] == pytest.approx(outs[4][0], rel=1e-3)
        diffs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            outs[1][1], outs[4][1])
        assert max(jax.tree.leaves(diffs)) < 5e-2


class TestHybridExecutor:
    def test_split_converges_to_balance(self):
        import time as _t

        def dense(n):  # 10x faster per item
            _t.sleep(n * 1e-5)
            return n

        def sparse(n):
            _t.sleep(n * 1e-4)
            return n

        ex = HybridExecutor(dense, sparse, lambda a, b: (a, b), num_items=1000,
                            mode="parallel", dense_quantum=1)
        dec = ex.converge(rounds=6)
        # balance point: n_d/t_d == n_s/t_s ⇒ dense fraction ≈ 10/11 ≈ 0.91
        assert 0.75 < dec.dense_fraction <= 1.0

    def test_serial_mode_picks_faster_path(self):
        ex = HybridExecutor(lambda n: n, lambda n: n, lambda a, b: (a, b),
                            num_items=100, mode="serial",
                            init_dense_throughput=10.0,
                            init_sparse_throughput=1.0, dense_quantum=1)
        dec = ex.decide()
        assert dec.n_dense == 100


class TestShardingRules:
    def test_grok_expert_premise(self):
        """8 experts % 16 ≠ 0 ⇒ the rules fall back to TP over expert ff."""
        cfg = get_config("grok-1-314b")
        assert cfg.num_experts % 16 != 0 and cfg.moe_d_ff % 16 == 0

    def test_qwen3_moe_ep_premise(self):
        cfg = get_config("qwen3-moe-30b-a3b")
        assert cfg.num_experts % 16 == 0  # true expert parallelism

    def test_fused_head_dims_divide_model_axis(self):
        """The fused-QKV layout divides 16 for EVERY assigned arch — the
        property that makes qwen3's 40 heads shardable."""
        from repro.configs import all_configs
        for cfg in all_configs().values():
            if cfg.num_heads:
                assert cfg.q_dim % 16 == 0, cfg.name
                assert cfg.kv_dim % 16 == 0, cfg.name
            assert cfg.padded_vocab % 16 == 0, cfg.name

"""Compressed psum == exact psum within quantization tolerance (subprocess:
needs multiple devices)."""

import subprocess
import sys
import textwrap
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"

PROGRAM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.parallel.collectives import compressed_psum
    from repro.parallel.compat import shard_map

    mesh = jax.make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32)) * 0.01

    def f(xb):
        exact = jax.lax.psum(xb, "data")
        comp = compressed_psum(xb, "data")
        rel = jnp.max(jnp.abs(comp - exact)) / jnp.maximum(jnp.max(jnp.abs(exact)), 1e-9)
        return rel

    with mesh:
        rel = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(),
                        check_vma=False)(x)
    rel = float(rel)
    assert rel < 0.02, rel
    print("COMPRESSED_PSUM_OK", rel)
""") % str(SRC)


def test_compressed_psum_accuracy():
    res = subprocess.run(
        [sys.executable, "-c", PROGRAM],
        capture_output=True, text=True, timeout=300,
    )
    assert "COMPRESSED_PSUM_OK" in res.stdout, res.stdout + res.stderr

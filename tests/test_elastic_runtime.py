"""Elastic unit join/leave mid-run: the exact-once requeue invariant.

The contract under test (ISSUE 3 acceptance): every index of the
iteration space is covered exactly once even when a unit leaves mid-run
(its in-flight chunk requeued to survivors) and another joins (stealing
immediately), across all three engines under SimulatedClock — and the
elasticity events are recorded in the RunReport.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI container has no hypothesis; use the vendored shim
    from _propcheck import given, settings, strategies as st

from repro.core import (
    ElasticMeshManager,
    ElasticSchedule,
    ElasticEvent,
    HeteroRuntime,
    ShardedSpace,
    SimulatedClock,
    WorkerKind,
)
from repro.core.runtime import ENGINES, POLICIES


def make_runtime(n_acc=2, n_cc=2, acc_speed=8e3, cc_speed=1e3):
    rt = HeteroRuntime(clock=SimulatedClock())
    for i in range(n_acc):
        rt.register_unit(f"acc{i}", WorkerKind.ACC, speed=acc_speed)
    for i in range(n_cc):
        rt.register_unit(f"cc{i}", WorkerKind.CC, speed=cc_speed)
    return rt


def assert_exact_tiling(spans, n_items):
    assert spans, "no chunks completed"
    assert spans[0][0] == 0
    assert spans[-1][1] == n_items
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c, f"gap or overlap at {b}:{c}"


def leave_then_join(t_leave=0.05, t_join=0.08):
    return (ElasticSchedule()
            .leave(t_leave, "cc0")
            .join(t_join, "cc_new", kind="cc", speed=2e3))


class TestRequeueInvariant:
    """The ISSUE's satellite: exact-once across all three engines."""

    @pytest.mark.parametrize("engine", ENGINES)
    def test_leave_and_join_exact_once(self, engine):
        rep = make_runtime().parallel_for(
            num_items=2000, policy="multidynamic", engine=engine,
            acc_chunk=64, elastic=leave_then_join(),
        )
        assert rep.items == 2000
        assert_exact_tiling(rep.coverage, 2000)
        # events recorded, in order, with the join attributed
        assert [e["action"] for e in rep.events] == ["leave", "join"]
        assert rep.per_worker_items["cc_new"] > 0
        # the departed unit stops at the leave: it did less than its twin
        assert rep.per_worker_items["cc0"] <= rep.per_worker_items["cc1"]

    @pytest.mark.parametrize("engine", ENGINES)
    @pytest.mark.parametrize("policy", POLICIES)
    def test_leave_exact_once_every_policy(self, engine, policy):
        # pre-split policies must requeue the departed unit's uncollected
        # assignment, not just its in-flight chunk
        rep = make_runtime().parallel_for(
            num_items=1501, policy=policy, engine=engine, acc_chunk=64,
            elastic=ElasticSchedule().leave(0.05, "cc0"),
        )
        assert rep.items == 1501
        assert_exact_tiling(rep.coverage, 1501)

    def test_interrupt_leave_requeues_inflight_chunk(self):
        # slow unit, long chunk: the leave lands mid-chunk and the exact
        # span goes back to the pool
        rt = HeteroRuntime(clock=SimulatedClock())
        rt.register_unit("fast", WorkerKind.ACC, speed=1e3)
        rt.register_unit("slow", WorkerKind.CC, speed=10.0)
        rep = rt.parallel_for(
            num_items=500, policy="multidynamic", engine="interrupt",
            acc_chunk=50, elastic=ElasticSchedule().leave(0.1, "slow"),
        )
        assert rep.items == 500
        assert_exact_tiling(rep.coverage, 500)
        leave = rep.events[0]
        assert leave["action"] == "leave" and leave["requeued"] is not None
        a, b = leave["requeued"]
        assert 0 <= a < b <= 500
        # the requeued span was completed by the survivor
        assert (a, b) in rep.coverage or any(
            s <= a and b <= e for s, e in rep.coverage)

    def test_join_steals_immediately(self):
        base = make_runtime(n_acc=1, n_cc=1, acc_speed=1e3, cc_speed=1e3)
        rep0 = base.parallel_for(
            num_items=4000, policy="multidynamic", engine="interrupt",
            acc_chunk=64,
        )
        joined = make_runtime(n_acc=1, n_cc=1, acc_speed=1e3, cc_speed=1e3)
        rep1 = joined.parallel_for(
            num_items=4000, policy="multidynamic", engine="interrupt",
            acc_chunk=64,
            elastic=ElasticSchedule().join(0.0, "acc9", kind="acc", speed=1e3),
        )
        assert rep1.per_worker_items["acc9"] > 0
        assert rep1.makespan < rep0.makespan

    def test_all_units_leave_without_replacement_raises(self):
        rt = HeteroRuntime(clock=SimulatedClock())
        rt.register_unit("a", WorkerKind.ACC, speed=10.0)
        with pytest.raises(RuntimeError, match="stalled"):
            rt.parallel_for(
                num_items=100, policy="multidynamic", engine="interrupt",
                acc_chunk=8, elastic=ElasticSchedule().leave(1.0, "a"),
            )

    def test_rescue_join_after_total_departure(self):
        rt = HeteroRuntime(clock=SimulatedClock())
        rt.register_unit("a", WorkerKind.ACC, speed=10.0)
        rep = rt.parallel_for(
            num_items=100, policy="multidynamic", engine="interrupt",
            acc_chunk=8,
            elastic=(ElasticSchedule()
                     .leave(1.0, "a")
                     .join(3.0, "b", kind="acc", speed=10.0)),
        )
        assert rep.items == 100
        assert_exact_tiling(rep.coverage, 100)

    def test_event_times_are_run_relative(self):
        # a reused runtime whose clock already advanced must replay the
        # same schedule identically (events fire mid-run, not at t=0)
        rt = make_runtime()
        first = rt.parallel_for(
            num_items=2000, policy="multidynamic", engine="interrupt",
            acc_chunk=64, elastic=leave_then_join(),
        )
        assert rt.clock.now() > 0.05
        second = rt.parallel_for(
            num_items=2000, policy="multidynamic", engine="interrupt",
            acc_chunk=64, elastic=leave_then_join(),
        )
        assert second.per_worker_items == first.per_worker_items
        # recorded times are run-relative (up to float rebasing noise)
        for e1, e2 in zip(first.events, second.events):
            assert (e1["action"], e1["unit"], e1["requeued"]) == (
                e2["action"], e2["unit"], e2["requeued"])
            assert e2["t"] == pytest.approx(e1["t"], abs=1e-9)
        assert second.per_worker_items["cc0"] > 0  # worked until the leave

    @pytest.mark.parametrize("engine", ENGINES)
    def test_late_events_do_not_stretch_makespan(self, engine):
        # an event timed after full coverage is dropped, not waited for
        def run(elastic=None):
            rt = HeteroRuntime(clock=SimulatedClock())
            rt.register_unit("a", WorkerKind.ACC, speed=1e3)
            return rt.parallel_for(
                num_items=100, policy="multidynamic", engine=engine,
                acc_chunk=16, elastic=elastic,
            )
        base = run()
        late = run(ElasticSchedule().join(50.0, "late", kind="acc", speed=1e3))
        assert late.makespan == base.makespan
        assert not late.events  # never part of the run
        assert late.per_worker_items.get("late", 0) == 0

    def test_requeued_chunk_side_effects_exactly_once(self):
        # the work_fn runs at completion: a chunk aborted by a leave is
        # recorded only by the survivor that finally completes it
        from collections import Counter

        counts = Counter()

        def record(chunk):
            counts.update(chunk.indices())

        rt = HeteroRuntime(clock=SimulatedClock())
        rt.register_unit("fast", WorkerKind.ACC, speed=1e3)
        rt.register_unit("slow", WorkerKind.CC, speed=10.0)
        rep = rt.parallel_for(
            record, num_items=500, policy="multidynamic", engine="interrupt",
            acc_chunk=50, elastic=ElasticSchedule().leave(0.1, "slow"),
        )
        assert rep.events[0]["requeued"] is not None  # leave was mid-chunk
        assert set(counts) == set(range(500))
        assert set(counts.values()) == {1}, "some index recorded twice"

    def test_elastic_runs_are_deterministic(self):
        def run():
            return make_runtime().parallel_for(
                num_items=3000, policy="multidynamic", engine="interrupt",
                acc_chunk=64, elastic=leave_then_join(),
            )
        r1, r2 = run(), run()
        assert r1.makespan == r2.makespan
        assert r1.coverage == r2.coverage
        assert r1.events == r2.events

    @given(
        n_items=st.integers(64, 4000),
        acc_chunk=st.integers(1, 256),
        t_leave=st.floats(0.0, 0.5),
        dt_join=st.floats(0.0, 0.5),
        pick=st.integers(0, 8),
    )
    @settings(max_examples=40, deadline=None)
    def test_exact_once_property(self, n_items, acc_chunk, t_leave, dt_join, pick):
        rep = make_runtime().parallel_for(
            num_items=n_items, policy=POLICIES[pick % 3],
            engine=ENGINES[pick // 3], acc_chunk=acc_chunk,
            elastic=(ElasticSchedule()
                     .leave(t_leave, "cc0")
                     .join(t_leave + dt_join, "cc_new", kind="cc", speed=2e3)),
        )
        assert rep.items == n_items
        assert_exact_tiling(rep.coverage, n_items)


class TestElasticValidation:
    def test_wall_clock_serial_engines_rejected(self):
        # WallClock elasticity is the event-driven engine's feature (see
        # tests/test_backends.py); the serial drivers cannot observe
        # membership changes mid-chunk and must refuse the schedule.
        for engine in ("polling", "inline"):
            rt = HeteroRuntime()
            rt.register_unit("a", WorkerKind.ACC, work_fn=lambda c: None)
            with pytest.raises(ValueError, match="interrupt"):
                rt.parallel_for(num_items=10, engine=engine,
                                elastic=ElasticSchedule().leave(1.0, "a"))

    def test_wall_clock_join_needs_work_fn(self):
        rt = HeteroRuntime()
        rt.register_unit("a", WorkerKind.ACC, work_fn=lambda c: None)
        with pytest.raises(ValueError, match="work_fn"):
            rt.parallel_for(num_items=10,
                            elastic=ElasticSchedule().join(0.1, "b"))

    def test_leave_of_unknown_unit_rejected(self):
        rt = make_runtime()
        with pytest.raises(ValueError, match="unknown"):
            rt.parallel_for(num_items=10,
                            elastic=ElasticSchedule().leave(1.0, "ghost"))

    def test_join_reusing_live_name_rejected(self):
        rt = make_runtime()
        with pytest.raises(ValueError, match="reuses"):
            rt.parallel_for(num_items=10,
                            elastic=ElasticSchedule().join(1.0, "cc0"))

    def test_double_leave_rejected_up_front(self):
        rt = make_runtime()
        with pytest.raises(ValueError, match="already-departed"):
            rt.parallel_for(
                num_items=10,
                elastic=ElasticSchedule().leave(0.05, "cc0").leave(0.1, "cc0"),
            )

    def test_join_reusing_departed_name_rejected(self):
        rt = make_runtime()
        with pytest.raises(ValueError, match="reuses"):
            rt.parallel_for(
                num_items=10,
                elastic=ElasticSchedule().leave(0.05, "cc0").join(0.1, "cc0"),
            )

    def test_bad_event_fields_rejected(self):
        with pytest.raises(ValueError):
            ElasticEvent(t=1.0, action="explode", unit="a")
        with pytest.raises(ValueError):
            ElasticEvent(t=-1.0, action="leave", unit="a")

    def test_events_accepted_as_plain_sequence(self):
        rep = make_runtime().parallel_for(
            num_items=500, policy="multidynamic", engine="inline",
            acc_chunk=32,
            elastic=[ElasticEvent(t=0.05, action="leave", unit="cc0")],
        )
        assert rep.items == 500
        assert_exact_tiling(rep.coverage, 500)


class TestElasticSharded:
    def test_schedule_applies_per_shard(self):
        rep = make_runtime().parallel_for(
            space=ShardedSpace(4000, 2), policy="multidynamic",
            engine="interrupt", acc_chunk=64, elastic=leave_then_join(),
        )
        assert rep.items == 4000
        assert_exact_tiling(rep.coverage, 4000)
        # each shard's unit replica set saw the same leave+join
        assert len(rep.events) == 4
        assert {e["unit"] for e in rep.events} == {
            "s0/cc0", "s0/cc_new", "s1/cc0", "s1/cc_new"}


class TestMeshHook:
    def test_mesh_failures_become_unit_leaves(self):
        mesh = ElasticMeshManager((2, 4), ("host", "model"), host_size=4)
        schedule = ElasticSchedule.from_mesh(
            mesh,
            bindings={"acc0": 0, "cc0": 1, "cc1": 1},
            faults=[(0.5, 5)],          # device 5 -> host 1 dies
        )
        assert [(e.action, e.unit) for e in schedule.events] == [
            ("leave", "cc0"), ("leave", "cc1")]
        assert mesh.lost_ids == [4, 5, 6, 7]

    def test_mesh_driven_run_keeps_exact_once(self):
        mesh = ElasticMeshManager((2, 4), ("host", "model"), host_size=4)
        schedule = ElasticSchedule.from_mesh(
            mesh,
            bindings={"cc0": 1, "cc1": 1},
            faults=[(0.05, 4)],
            joins=[ElasticEvent(t=0.1, action="join", unit="cc9",
                                kind="cc", speed=2e3)],
        )
        rep = make_runtime().parallel_for(
            num_items=2000, policy="multidynamic", engine="interrupt",
            acc_chunk=64, elastic=schedule,
        )
        assert rep.items == 2000
        assert_exact_tiling(rep.coverage, 2000)
        assert rep.per_worker_items["cc9"] > 0

    def test_repeat_fault_same_host_no_duplicate_leaves(self):
        mesh = ElasticMeshManager((2, 4), ("host", "model"), host_size=4)
        schedule = ElasticSchedule.from_mesh(
            mesh, bindings={"cc0": 1}, faults=[(0.5, 5), (0.6, 6)],
        )
        assert len(schedule.events) == 1

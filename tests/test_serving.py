"""Serving tier: admission policies, backpressure, loadgen, and the
ServingEngine prefill/sampling bug batch (errored/timeout prefills,
fixed-seed determinism across batch compositions, stable report schema)."""

import threading
import time

import numpy as np
import pytest

from repro.serving import Request
from repro.serving.admission import (
    AdmissionVerdict,
    CostAwarePolicy,
    DeadlinePolicy,
    FIFOPolicy,
    PriorityPolicy,
    make_policy,
)
from repro.serving.loadgen import (
    METRIC_KEYS,
    LoadgenScenario,
    make_trace,
    run_trace,
    summarize,
)


def _req(rid, plen=4, mx=4, **kw):
    return Request(rid=rid, prompt=np.zeros(plen, np.int32),
                   max_new_tokens=mx, **kw)


# ---------------------------------------------------------------------------
# policies: pure-python, no model
# ---------------------------------------------------------------------------
class TestAdmissionPolicies:
    def test_make_policy_names_and_errors(self):
        assert isinstance(make_policy("fifo"), FIFOPolicy)
        assert isinstance(make_policy(None), FIFOPolicy)
        assert isinstance(make_policy("priority"), PriorityPolicy)
        assert isinstance(make_policy("deadline"), DeadlinePolicy)
        assert isinstance(make_policy("cost"), CostAwarePolicy)
        with pytest.raises(ValueError, match="unknown admission policy"):
            make_policy("lifo")
        # instance passthrough + bound installation
        p = FIFOPolicy()
        assert make_policy(p, max_queue=3) is p and p.max_queue == 3
        with pytest.raises(ValueError, match="conflicting"):
            make_policy(FIFOPolicy(max_queue=2), max_queue=3)

    def test_bounded_queue_sheds(self):
        p = make_policy("fifo", max_queue=2)
        assert p.admit(_req(0), queue_depth=1, now=0.0)
        verdict = p.admit(_req(1), queue_depth=2, now=0.0)
        assert not verdict and verdict.reason == "queue_full"
        assert isinstance(verdict, AdmissionVerdict)

    def test_fifo_order_is_identity(self):
        reqs = [_req(i) for i in (3, 1, 2)]
        assert [r.rid for r in FIFOPolicy().order(reqs)] == [3, 1, 2]

    def test_priority_order_stable_within_class(self):
        reqs = [_req(0, priority=0), _req(1, priority=2),
                _req(2, priority=0), _req(3, priority=2)]
        assert [r.rid for r in PriorityPolicy().order(reqs)] == [1, 3, 0, 2]

    def test_deadline_edf_and_expired_shed(self):
        reqs = [
            _req(0),                                        # no SLO: last
            _req(1, deadline=0.5, submitted_at=10.0),       # abs 10.5
            _req(2, deadline=5.0, submitted_at=4.0),        # abs 9.0
        ]
        p = DeadlinePolicy()
        assert [r.rid for r in p.order(reqs, now=0.0)] == [2, 1, 0]
        verdict = p.admit(_req(9, deadline=0.0), queue_depth=0, now=0.0)
        assert not verdict and verdict.reason == "expired"
        assert p.admit(_req(9, deadline=1.0), queue_depth=0, now=0.0)

    def test_cost_aware_learns_from_observations(self):
        p = CostAwarePolicy()
        reqs = [_req(0, plen=32), _req(1, plen=2), _req(2, plen=8)]
        # default prediction = prompt_len: shortest-prompt-first
        assert [r.rid for r in p.order(reqs)] == [1, 2, 0]
        p.observe_prefill("slot0", tokens=100, elapsed=1.0)
        assert p.predicted_cost(_req(9, plen=50)) == pytest.approx(0.5, rel=0.2)
        assert [r.rid for r in p.order(reqs)] == [1, 2, 0]

    def test_cost_aware_straggler_report(self):
        p = CostAwarePolicy()
        for _ in range(5):
            p.observe_prefill("slot0", tokens=10, elapsed=0.01)
            p.observe_prefill("slot1", tokens=10, elapsed=1.0)
        rep = p.straggler_report
        assert rep is not None and "slot1" in rep.stragglers


# ---------------------------------------------------------------------------
# loadgen traces: pure numpy, no model
# ---------------------------------------------------------------------------
class TestLoadgenTraces:
    def test_seeded_trace_is_deterministic(self):
        a = make_trace(seed=3, n=16, arrival="bursty")
        b = make_trace(seed=3, n=16, arrival="bursty")
        assert [t.at for t in a] == [t.at for t in b]
        assert [t.request.max_new_tokens for t in a] == \
               [t.request.max_new_tokens for t in b]
        assert all(np.array_equal(x.request.prompt, y.request.prompt)
                   for x, y in zip(a, b))
        c = make_trace(seed=4, n=16, arrival="bursty")
        assert [t.at for t in a] != [t.at for t in c]

    @pytest.mark.parametrize("arrival", ["poisson", "bursty", "uniform"])
    def test_arrivals_monotone_and_lengths_bounded(self, arrival):
        sc = LoadgenScenario(seed=1, n=64, rate=100.0, arrival=arrival,
                             prompt_lens=(2, 9), gen_lens=(3, 7))
        trace = make_trace(sc)
        ats = [t.at for t in trace]
        assert ats == sorted(ats) and ats[0] > 0
        assert all(2 <= len(t.request.prompt) <= 9 for t in trace)
        assert all(3 <= t.request.max_new_tokens <= 7 for t in trace)

    def test_unknown_arrival_rejected(self):
        with pytest.raises(ValueError, match="unknown arrival"):
            make_trace(seed=0, n=4, arrival="lunar")

    def test_deadlines_and_priorities_assigned(self):
        trace = make_trace(seed=0, n=8, deadline_base=1.0,
                           deadline_per_token=0.5, priorities=(0, 7))
        for i, t in enumerate(trace):
            assert t.request.deadline == pytest.approx(
                1.0 + 0.5 * t.request.max_new_tokens)
            assert t.request.priority == (0, 7)[i % 2]


# ---------------------------------------------------------------------------
# engine-level behaviour (needs a real smoke model)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served(request):
    import jax
    from repro.configs import get_config
    from repro.models import make_model

    cfg = get_config("tinyllama-1.1b").smoke()
    m = make_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _requests(cfg, n=6, seed=0, mx=(2, 10), **kw):
    rng = np.random.default_rng(seed)
    return [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(2, 8))).astype(np.int32),
                max_new_tokens=int(rng.integers(*mx)), **kw)
        for i in range(n)
    ]


@pytest.mark.slow
class TestEngineAdmission:
    def test_submit_returns_verdict_and_sheds(self, served):
        from repro.serving import ServingEngine

        cfg, m, params = served
        eng = ServingEngine(m, params, slots=2, max_len=48, max_queue=2)
        reqs = _requests(cfg, n=4)
        verdicts = [eng.submit(r) for r in reqs]
        assert [bool(v) for v in verdicts] == [True, True, False, False]
        assert verdicts[2].reason == "queue_full"
        assert set(eng.shed) == {2, 3}
        res = eng.run()
        assert set(res) == {0, 1}          # shed requests never ran
        rep = eng.throughput_report()
        assert rep["shed"] == 2 and rep["completed"] == 2

    def test_priority_policy_orders_completions(self, served):
        from repro.serving import ServingEngine

        cfg, m, params = served
        eng = ServingEngine(m, params, slots=1, max_len=48, policy="priority")
        reqs = _requests(cfg, n=4, mx=(3, 4))
        for pr, r in zip((0, 5, 0, 9), reqs):
            r.priority = pr
            eng.submit(r)
        res = eng.run()
        finished = sorted(res.values(), key=lambda r: r.finish_time)
        assert [r.rid for r in finished] == [3, 1, 0, 2]

    def test_deadline_policy_edf_through_engine(self, served):
        from repro.serving import ServingEngine

        cfg, m, params = served
        eng = ServingEngine(m, params, slots=1, max_len=48, policy="deadline")
        reqs = _requests(cfg, n=3, mx=(3, 4))
        for dl, r in zip((9.0, 100.0, 1.0), reqs):
            r.deadline = dl
            eng.submit(r)
        res = eng.run()
        finished = sorted(res.values(), key=lambda r: r.finish_time)
        assert [r.rid for r in finished] == [2, 0, 1]
        assert all(r.deadline is not None for r in res.values())

    def test_throughput_report_schema_stable(self, served):
        from repro.serving import ServingEngine

        cfg, m, params = served
        eng = ServingEngine(m, params, slots=2, max_len=48)
        empty = eng.throughput_report()
        for r in _requests(cfg, n=3):
            eng.submit(r)
        eng.run()
        full = eng.throughput_report()
        assert set(empty) == set(full)      # same keys before/after
        for key in ("mean_latency", "p50_latency", "p95_latency",
                    "p99_latency", "mean_ttft", "goodput_tokens"):
            assert key in empty
        assert empty["mean_latency"] == 0.0
        assert full["completed"] == 3 and full["mean_latency"] > 0
        assert full["p99_latency"] >= full["p50_latency"] > 0
        assert all(r.ttft is not None and r.ttft <= r.latency
                   for r in eng.results.values())


@pytest.mark.slow
class TestEngineFailures:
    def test_errored_async_prefill_fails_request_not_batch(self, served):
        from repro.serving import ServingEngine

        cfg, m, params = served
        eng = ServingEngine(m, params, slots=2, max_len=48, backend="threads")
        real = eng._prefill

        def flaky(req):
            if req.rid == 1:
                raise RuntimeError("injected prefill failure")
            return real(req)

        eng._prefill = flaky
        reqs = _requests(cfg, n=5)
        for r in reqs:
            eng.submit(r)
        res = eng.run()                      # must not raise or hang
        assert set(res) == {0, 1, 2, 3, 4}
        assert res[1].error is not None and "injected" in res[1].error
        assert res[1].tokens == [] and not res[1].ok
        for rid in (0, 2, 3, 4):
            assert res[rid].ok
            assert len(res[rid].tokens) == reqs[rid].max_new_tokens
        # batch accounting closed every chunk despite the failure
        assert eng.last_run_report is not None
        assert eng.last_run_report.items == 5
        rep = eng.throughput_report()
        assert rep["failed"] == 1 and rep["completed"] == 4

    def test_errored_inline_prefill_fails_request_not_batch(self, served):
        from repro.serving import ServingEngine

        cfg, m, params = served
        eng = ServingEngine(m, params, slots=2, max_len=48)
        real = eng._prefill
        eng._prefill = lambda req: (_ for _ in ()).throw(
            ValueError("poisoned")) if req.rid == 0 else real(req)
        for r in _requests(cfg, n=3):
            eng.submit(r)
        res = eng.run()
        assert res[0].error is not None and res[1].ok and res[2].ok
        assert eng.last_run_report.items == 3

    def test_dead_prefill_unit_raises_instead_of_spinning(self, served):
        from repro.serving import ServingEngine

        cfg, m, params = served
        eng = ServingEngine(m, params, slots=2, max_len=48,
                            backend="threads", prefill_timeout=0.2)
        # unit 0's submits vanish: nothing ever posts to the bus for it
        eng._prefill_units[0].submit = lambda chunk, work: None
        for r in _requests(cfg, n=2):
            eng.submit(r)
        t0 = time.perf_counter()
        with pytest.raises(TimeoutError, match="slot0"):
            eng.run()
        assert time.perf_counter() - t0 < 30.0   # not a 60s-per-iter spin


@pytest.mark.slow
class TestSamplingDeterminism:
    TEMP = 0.8

    def _run(self, served, reqs, *, slots, seed=7):
        from repro.serving import ServingEngine

        cfg, m, params = served
        eng = ServingEngine(m, params, slots=slots, max_len=48,
                            temperature=self.TEMP, seed=seed)
        for r in reqs:
            eng.submit(r)
        return {rid: tuple(res.tokens) for rid, res in eng.run().items()}

    def test_streams_identical_regardless_of_batch_composition(self, served):
        cfg, _, _ = served
        reqs = _requests(cfg, n=4, seed=5, mx=(4, 9))
        together = self._run(served, reqs, slots=4)
        alone = self._run(served, [reqs[0]], slots=4)
        assert alone[0] == together[0]
        # different co-runners, same slot count: r0's stream is unchanged
        partial = self._run(served, [reqs[0], reqs[3]], slots=4)
        assert partial[0] == together[0] and partial[3] == together[3]

    def test_streams_identical_regardless_of_submit_order(self, served):
        cfg, _, _ = served
        reqs = _requests(cfg, n=4, seed=6, mx=(4, 9))
        fwd = self._run(served, reqs, slots=2)
        rev = self._run(served, list(reversed(reqs)), slots=2)
        assert fwd == rev

    def test_temperature_zero_still_greedy_deterministic(self, served):
        from repro.serving import ServingEngine

        cfg, m, params = served
        outs = []
        for _ in range(2):
            eng = ServingEngine(m, params, slots=2, max_len=48)
            for r in _requests(cfg, n=3, seed=2):
                eng.submit(r)
            outs.append({k: tuple(v.tokens) for k, v in eng.run().items()})
        assert outs[0] == outs[1]

    def test_first_token_honours_temperature(self, served):
        """With a temperature set, the first sampled token is from the
        tempered distribution, not hard-coded greedy: across seeds the
        first token varies, while greedy engines always agree."""
        cfg, _, _ = served
        req = _requests(cfg, n=1, seed=9, mx=(2, 3))[0]
        firsts = {
            self._run(served, [Request(rid=0, prompt=req.prompt,
                                       max_new_tokens=2)],
                      slots=2, seed=s)[0][0]
            for s in range(8)
        }
        assert len(firsts) > 1


@pytest.mark.slow
class TestLoadgenSmoke:
    def test_open_loop_run_reports_stable_schema(self, served):
        from repro.serving import ServingEngine

        cfg, m, params = served
        trace = make_trace(seed=0, n=6, rate=200.0, arrival="poisson",
                           vocab_size=cfg.vocab_size, prompt_lens=(2, 8),
                           gen_lens=(2, 8), deadline_base=60.0)
        eng = ServingEngine(m, params, slots=2, max_len=48)
        metrics = run_trace(eng, trace)
        assert set(metrics) == set(METRIC_KEYS)
        assert metrics["completed"] == 6 and metrics["failed"] == 0
        assert metrics["goodput_tokens"] == metrics["tokens"] > 0
        assert metrics["p99_latency_s"] >= metrics["p50_latency_s"] > 0
        assert metrics["deadline_hit_rate"] == 1.0

    def test_mid_run_submissions_are_served(self, served):
        """submit() racing run(): every admitted request completes
        exactly once (the queue-snapshot lock)."""
        from repro.serving import ServingEngine

        cfg, m, params = served
        eng = ServingEngine(m, params, slots=2, max_len=48)
        reqs = _requests(cfg, n=8, mx=(2, 4))
        for r in reqs[:2]:
            eng.submit(r)

        def late():
            for r in reqs[2:]:
                time.sleep(0.02)
                eng.submit(r)

        th = threading.Thread(target=late)
        th.start()
        while th.is_alive() or eng.has_work:
            if eng.has_work:
                eng.run()
            else:
                time.sleep(0.005)
        th.join()
        assert set(eng.results) == {r.rid for r in reqs}

    def test_summarize_counts_shed_and_failed(self, served):
        from repro.serving import ServingEngine

        cfg, m, params = served
        eng = ServingEngine(m, params, slots=1, max_len=48, max_queue=2)
        for r in _requests(cfg, n=4, mx=(2, 3)):
            eng.submit(r)
        eng.run()
        metrics = summarize(eng, wall=1.0, offered=4)
        assert metrics["shed"] == 2 and metrics["completed"] == 2

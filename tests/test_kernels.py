"""Per-kernel validation: shape/dtype sweeps vs the ref.py jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_eneac import HotspotConfig
from repro.kernels.flash_attention.ops import flash_attention, kernel_hbm_bytes
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.hotspot.ops import hotspot
from repro.kernels.hotspot.ref import hotspot_ref
from repro.kernels.spmm.ops import make_hybrid_executor, pad_rhs, spmm_cc
from repro.kernels.spmm.ref import (
    make_problem,
    spmm_dense_ref,
    spmm_ell_ref,
    to_block_ell,
)
from repro.kernels.spmm.spmm import BlockEllArrays, spmm_block_ell_pallas

KEY = jax.random.PRNGKey(0)


class TestHotspot:
    @pytest.mark.parametrize("grid,steps", [(32, 1), (64, 4), (128, 2)])
    @pytest.mark.parametrize("mode", ["hp", "hpc"])
    def test_kernel_matches_oracle(self, grid, steps, mode):
        cfg = HotspotConfig(grid=grid, iterations=grid)
        t0 = 80.0 + 10 * jax.random.uniform(KEY, (grid, grid))
        p = jax.random.uniform(jax.random.PRNGKey(1), (grid, grid))
        ref = hotspot_ref(t0, p, cfg, steps)
        out = hotspot(t0, p, cfg, steps, mode=mode)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)

    def test_cc_is_oracle(self):
        cfg = HotspotConfig(grid=32, iterations=32)
        t0 = jnp.full((32, 32), 80.0)
        p = jnp.zeros((32, 32))
        out = hotspot(t0, p, cfg, 3, mode="cc")
        ref = hotspot_ref(t0, p, cfg, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


class TestSpmm:
    @pytest.mark.parametrize("rows,cols,n", [(40, 256, 16), (64, 384, 32),
                                             (17, 128, 8)])
    @pytest.mark.parametrize("nnz_mean", [2.0, 8.0])
    def test_block_ell_kernel_matches_dense_oracle(self, rows, cols, n, nnz_mean):
        p = make_problem(rows, cols, n, nnz_mean=nnz_mean, seed=rows + n)
        ref = spmm_dense_ref(p)
        be = to_block_ell(p)
        out = spmm_block_ell_pallas(BlockEllArrays(be), jnp.asarray(pad_rhs(p)))
        np.testing.assert_allclose(np.asarray(out[:rows, :n]), ref,
                                   rtol=1e-4, atol=1e-4)

    def test_gather_path_matches_dense_oracle(self):
        p = make_problem(32, 128, 8, nnz_mean=4.0, seed=7)
        ref = spmm_dense_ref(p)
        out = spmm_cc(jnp.asarray(p.vals), jnp.asarray(p.cols), jnp.asarray(p.rhs))
        np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)

    def test_hybrid_executor_exact_any_split(self):
        p = make_problem(48, 256, 16, nnz_mean=6.0, seed=3)
        ref = spmm_dense_ref(p)
        ex, order = make_hybrid_executor(p)
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order))
        from repro.core.parallel_for import SplitDecision
        for nd in (0, 16, 32, 48):
            res, _ = ex.run(SplitDecision(n_dense=nd, n_sparse=48 - nd,
                                          predicted_time=0.0))
            np.testing.assert_allclose(np.asarray(res)[inv], ref,
                                       rtol=1e-4, atol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,sq,h,kvh,d,causal,window",
        [
            (2, 128, 4, 2, 32, True, 0),
            (1, 256, 8, 1, 16, True, 0),     # MQA
            (2, 128, 4, 4, 64, False, 0),    # MHA non-causal
            (1, 256, 4, 2, 32, True, 64),    # local window
            (1, 128, 2, 2, 128, True, 0),    # wide head
        ],
    )
    def test_matches_oracle(self, b, sq, h, kvh, d, causal, window):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, sq, kvh, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, sq, kvh, d), jnp.float32)
        ref = mha_ref(q, k, v, causal=causal, window=window)
        out = flash_attention(q, k, v, causal=causal, window=window,
                              q_block=64, kv_block=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 32)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 128, 2, 32)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 128, 2, 32)).astype(dtype)
        ref = mha_ref(q, k, v)
        out = flash_attention(q, k, v, q_block=64, kv_block=64)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol)

    def test_traffic_model_is_qkvo_linear(self):
        fwd = kernel_hbm_bytes(1, 4096, 4096, 32, 8, 128)
        # Q+O = 2·S·H·D·2, K+V = 2·S·KVH·D·2
        expect = 2 * (4096 * 32 * 128 * 2) + 2 * (4096 * 8 * 128 * 2)
        assert fwd == expect

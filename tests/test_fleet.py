"""Fleet membership (ISSUE 10): discovery, liveness, autoscaling, recovery.

The contracts under test:

* :class:`~repro.core.fleet.HeartbeatBook` convicts exactly the silent —
  crashes are always noticed, slow-but-alive members never are
  (patience-gated, mirroring the straggler detector), late beats do not
  resurrect, and the event log is time-monotone by construction;
* :class:`~repro.core.fleet.Autoscaler` sizes from observed queue depth
  and *learned* per-unit throughput, scales up whole-gap / drains one at
  a time under a cooldown, and never scales on a model with no data;
* :func:`~repro.core.fleet.simulate_fleet` — the CI battery: ≥30 seeded
  join/leave/crash/slow churn traces over ≥100 virtual units, each
  asserting zero false convictions, zero missed crashes, exact-once
  coverage through the real engine, and monotone events;
* :func:`~repro.checkpoint.coverage.checkpointed_parallel_for` resumes
  a dead run from its last coverage bitmap — only the remainder is
  recomputed, through the verifying restore path;
* :class:`~repro.core.fleet.FleetManager` (``slow`` tier): real
  ``spawn_worker`` subprocesses joined/drained/killed -9 mid-run, with
  the lost chunk requeued exact-once to the survivors.

CI's ``fleet`` job runs this module under ``tools/run_with_timeout.py``.
"""

import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _propcheck import given, settings, strategies as st

from repro.core import (
    Autoscaler,
    ElasticSchedule,
    FailureTrace,
    FleetManager,
    HeartbeatBook,
    HeteroRuntime,
    SimulatedClock,
    TraceEvent,
    WorkerKind,
    simulate_fleet,
)
from repro.core.costmodel import CostModel
from repro.checkpoint import (
    Checkpointer,
    CoverageMap,
    checkpointed_parallel_for,
    load_coverage,
    save_coverage,
)


def assert_exact_tiling(spans, n_items):
    assert spans, "no chunks completed"
    assert spans[0][0] == 0
    assert spans[-1][1] == n_items
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c, f"gap or overlap at {b}:{c}"


# ---------------------------------------------------------------------------
# membership ledger
# ---------------------------------------------------------------------------
class TestHeartbeatBook:
    def test_silence_convicts_after_patience(self):
        book = HeartbeatBook(heartbeat=0.1, patience=3)
        book.join(0.0, "a")
        book.join(0.0, "b")
        for t in (0.1, 0.2, 0.3):
            book.beat(t, "a")
        assert book.sweep(0.3) == []          # b silent 0.3 <= limit
        assert book.sweep(0.31) == ["b"]      # b silent 0.31 > limit
        assert book.members == ["a"]
        assert [e["action"] for e in book.events] == ["join", "join", "dead"]

    def test_slow_beats_within_patience_survive(self):
        book = HeartbeatBook(heartbeat=0.1, patience=3)
        book.join(0.0, "slow")
        t = 0.0
        while t < 2.0:                        # 2.5x stretched, still alive
            t += 0.25
            book.beat(t, "slow")
            assert book.sweep(t) == []
        assert book.members == ["slow"]

    def test_late_beat_does_not_resurrect(self):
        book = HeartbeatBook(heartbeat=0.1, patience=2)
        book.join(0.0, "a")
        book.join(0.0, "b")
        book.beat(0.5, "a")
        assert book.sweep(0.5) == ["b"]
        book.beat(0.6, "b")                   # in-flight beat after verdict
        assert "b" not in book
        assert book.members == ["a"]

    def test_graceful_leave_is_not_a_conviction(self):
        book = HeartbeatBook(heartbeat=0.1, patience=3)
        book.join(0.0, "a")
        book.leave(0.2, "a")
        assert book.sweep(9.9) == []
        assert [e["action"] for e in book.events] == ["join", "leave"]

    def test_time_travel_raises(self):
        book = HeartbeatBook(heartbeat=0.1, patience=3)
        book.join(1.0, "a")
        with pytest.raises(ValueError, match="backwards"):
            book.beat(0.5, "a")

    def test_duplicate_join_and_unknown_leave_raise(self):
        book = HeartbeatBook(heartbeat=0.1, patience=3)
        book.join(0.0, "a")
        with pytest.raises(ValueError, match="already a member"):
            book.join(0.1, "a")
        with pytest.raises(ValueError, match="not a member"):
            book.leave(0.2, "ghost")

    def test_queue_depth_and_deadline(self):
        book = HeartbeatBook(heartbeat=0.5, patience=4)
        book.join(0.0, "a")
        book.beat(1.0, "a", queue_depth=7, inflight=2)
        assert book.queue_depth() == 7
        assert book.deadline("a") == pytest.approx(3.0)
        with pytest.raises(KeyError):
            book.deadline("ghost")

    def test_validation(self):
        with pytest.raises(ValueError, match="heartbeat"):
            HeartbeatBook(heartbeat=0.0)
        with pytest.raises(ValueError, match="patience"):
            HeartbeatBook(heartbeat=0.1, patience=0)


# ---------------------------------------------------------------------------
# autoscaler
# ---------------------------------------------------------------------------
def _model(items_per_s=100.0, kernel="k"):
    cm = CostModel()
    cm.observe("u0", kernel, items=int(items_per_s), elapsed=1.0)
    cm.observe("u1", kernel, items=int(items_per_s), elapsed=1.0)
    return cm


class TestAutoscaler:
    def test_target_sizes_from_learned_throughput(self):
        a = Autoscaler(_model(), kernel="k", horizon=1.0, max_units=16)
        # 500 items / (100 items/s * 1s horizon) -> 5 units
        assert a.target(500) == 5
        assert a.target(0) == a.min_units

    def test_scale_up_closes_whole_gap(self):
        a = Autoscaler(_model(), kernel="k", horizon=1.0, max_units=16)
        assert a.decide(0.0, queue_depth=500, n_units=2) == 3

    def test_scale_down_drains_one_per_cooldown(self):
        a = Autoscaler(_model(), kernel="k", horizon=1.0, max_units=16,
                       cooldown_s=1.0)
        assert a.decide(0.0, queue_depth=0, n_units=5) == -1
        assert a.decide(0.5, queue_depth=0, n_units=4) == 0   # cooling down
        assert a.decide(1.1, queue_depth=0, n_units=4) == -1

    def test_no_data_never_scales(self):
        a = Autoscaler(_model(), kernel="never-observed")
        assert a.decide(0.0, queue_depth=10_000, n_units=1) == 0
        assert Autoscaler(None).decide(0.0, queue_depth=10, n_units=1) == 0

    def test_clamped_to_bounds(self):
        a = Autoscaler(_model(), kernel="k", horizon=0.01, max_units=4)
        assert a.target(10_000) == 4
        a2 = Autoscaler(_model(), kernel="k", horizon=100.0, min_units=2)
        assert a2.target(1) == 2

    def test_validation(self):
        with pytest.raises(ValueError, match="horizon"):
            Autoscaler(None, horizon=0)
        with pytest.raises(ValueError, match="min_units"):
            Autoscaler(None, min_units=0)
        with pytest.raises(ValueError, match="max_units"):
            Autoscaler(None, min_units=4, max_units=2)


# ---------------------------------------------------------------------------
# seeded churn traces
# ---------------------------------------------------------------------------
class TestFailureTrace:
    def test_same_seed_same_trace(self):
        a = FailureTrace.generate(7, num_units=50)
        b = FailureTrace.generate(7, num_units=50)
        assert a.events == b.events
        assert a.initial_units == b.initial_units

    def test_different_seeds_differ(self):
        a = FailureTrace.generate(1, num_units=50)
        b = FailureTrace.generate(2, num_units=50)
        assert a.events != b.events

    def test_survivor_majority_enforced(self):
        with pytest.raises(ValueError, match="majority"):
            FailureTrace.generate(0, num_units=20, crash_frac=0.4,
                                  leave_frac=0.3)

    def test_event_validation(self):
        with pytest.raises(ValueError, match="unknown trace action"):
            TraceEvent(t=1.0, action="explode", unit="u0")


# ---------------------------------------------------------------------------
# the simulation battery (the ISSUE's headline): >=30 seeds, >=100 units
# ---------------------------------------------------------------------------
class TestFleetSimulationBattery:
    @given(seed=st.integers(0, 10**6))
    @settings(max_examples=30, deadline=None)
    def test_churn_replay_every_seed(self, seed):
        res = simulate_fleet(seed, num_units=100, heartbeat=0.05,
                             patience=3, horizon=10.0)
        # liveness verdicts match the trace's ground truth
        assert res.false_convictions == [], (
            f"seed {seed}: convicted live units {res.false_convictions}")
        assert res.missed_crashes == [], (
            f"seed {seed}: crashes never noticed {res.missed_crashes}")
        assert set(res.convicted) == set(res.trace.crashed)
        # conviction is prompt: within patience x heartbeat + one
        # (possibly slow-stretched) beat interval of the crash
        for unit, delay in res.conviction_delay.items():
            assert delay <= 3 * 0.05 + 0.05 * 2.5 + 1e-6, (
                f"seed {seed}: {unit} convicted {delay:.3f}s after crash")
        # the membership timeline preserved exact-once coverage
        rep = res.report
        assert rep.items == 100 * 6
        assert_exact_tiling(rep.coverage, 100 * 6)
        # both event logs are time-monotone
        ts = [e["t"] for e in res.book_events]
        assert ts == sorted(ts)
        ts = [e["t"] for e in (rep.events or [])]
        assert ts == sorted(ts)

    def test_losses_and_joins_land_in_report(self):
        res = simulate_fleet(3, num_units=100)
        actions = {e["action"] for e in (res.report.events or [])}
        # seeded churn produces real membership traffic in the report
        assert "leave" in actions
        assert "join" in actions

    def test_detection_latency_is_modeled(self):
        # crashes leave at *conviction* time, not the instant of death
        res = simulate_fleet(5, num_units=100)
        crash_t = {e.unit: e.t for e in res.trace.events
                   if e.action == "crash"}
        for ev in res.schedule.events:
            if ev.unit in crash_t and ev.action == "leave":
                assert ev.t > crash_t[ev.unit]


# ---------------------------------------------------------------------------
# elastic merge + drain prediction (plumbing the fleet layer rides on)
# ---------------------------------------------------------------------------
class TestFleetPlumbing:
    def test_elastic_merge_is_time_sorted_union(self):
        a = ElasticSchedule().leave(0.5, "u0").leave(2.0, "u1")
        b = ElasticSchedule().join(1.0, "j0", kind="cc", speed=2.0)
        merged = a.merge(b)
        assert [(e.t, e.action, e.unit) for e in merged] == [
            (0.5, "leave", "u0"), (1.0, "join", "j0"), (2.0, "leave", "u1")]
        assert len(a) == 2 and len(b) == 1  # inputs untouched

    def test_predict_drain(self):
        cm = _model(100.0)
        assert cm.predict_drain("k", 500, 2) == pytest.approx(2.5)
        assert cm.predict_drain("k", 0, 2) == 0.0
        assert cm.predict_drain("k", 500, 0) == float("inf")
        assert cm.predict_drain("unknown", 500, 2) is None


# ---------------------------------------------------------------------------
# checkpoint-backed recovery
# ---------------------------------------------------------------------------
class _Ledger:
    def __init__(self):
        self.lock = threading.Lock()
        self.ids = []

    def __call__(self, chunk):
        with self.lock:
            self.ids.extend(chunk.indices())


def _sim_runtime(n=4):
    rt = HeteroRuntime(clock=SimulatedClock())
    for i in range(n):
        rt.register_unit(f"cc{i}", WorkerKind.CC, speed=1.0)
    return rt


class TestCoverageMap:
    def test_mark_and_remaining_spans(self):
        cov = CoverageMap(100)
        cov.mark(0, 40)
        cov.mark(60, 70)
        assert cov.remaining_spans() == [(40, 60), (70, 100)]
        assert cov.items_done == 50
        assert not cov.complete
        cov.mark(40, 60)
        cov.mark(70, 100)
        assert cov.complete and cov.remaining_spans() == []

    def test_bitmap_shape_is_fixed(self):
        cov = CoverageMap(64)
        cov.mark(0, 63)
        assert cov.tree()["coverage_done"].shape == (64,)
        with pytest.raises(ValueError, match="shape"):
            CoverageMap(64, done=np.zeros(32, dtype=bool))

    def test_roundtrip_through_checkpointer(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        cov = CoverageMap(128)
        cov.mark(0, 100)
        save_coverage(ckpt, cov.items_done, cov, blocking=True)
        ckpt.wait_all()
        loaded, step = load_coverage(ckpt, 128)
        assert step == 100
        assert np.array_equal(loaded.done, cov.done)

    def test_wrong_space_size_fails_loudly(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        save_coverage(ckpt, 1, CoverageMap(128), blocking=True)
        ckpt.wait_all()
        with pytest.raises(ValueError):
            load_coverage(ckpt, 256)


class TestCheckpointedParallelFor:
    def test_fresh_run_covers_exactly_once(self, tmp_path):
        led = _Ledger()
        run = checkpointed_parallel_for(
            _sim_runtime(), led, 1000, checkpointer=Checkpointer(tmp_path),
            policy="multidynamic", acc_chunk=16)
        assert run.items_run == 1000 and run.rounds == 4
        assert not run.resumed
        assert sorted(led.ids) == list(range(1000))

    def test_dead_run_resumes_from_bitmap(self, tmp_path):
        # simulate a mid-run death: a partial bitmap is on disk, nothing
        # else survives.  The restart must execute ONLY the remainder.
        ckpt = Checkpointer(tmp_path)
        cov = CoverageMap(1000)
        cov.mark(0, 700)
        cov.mark(800, 900)
        save_coverage(ckpt, cov.items_done, cov, blocking=True)
        ckpt.wait_all()

        led = _Ledger()
        run = checkpointed_parallel_for(
            _sim_runtime(), led, 1000, checkpointer=ckpt,
            policy="multidynamic", acc_chunk=16)
        assert run.resumed and run.resumed_items_done == 800
        assert run.items_run == 200
        assert sorted(led.ids) == list(range(700, 800)) + list(range(900, 1000))

    def test_complete_run_resumes_to_noop(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        led = _Ledger()
        checkpointed_parallel_for(_sim_runtime(), led, 500,
                                  checkpointer=ckpt, policy="multidynamic",
                                  acc_chunk=16)
        led2 = _Ledger()
        run = checkpointed_parallel_for(_sim_runtime(), led2, 500,
                                        checkpointer=ckpt,
                                        policy="multidynamic", acc_chunk=16)
        assert run.items_run == 0 and led2.ids == []

    def test_resume_false_recomputes(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        checkpointed_parallel_for(_sim_runtime(), _Ledger(), 300,
                                  checkpointer=ckpt, policy="multidynamic",
                                  acc_chunk=16)
        led = _Ledger()
        run = checkpointed_parallel_for(_sim_runtime(), led, 300,
                                        checkpointer=ckpt, resume=False,
                                        policy="multidynamic", acc_chunk=16)
        assert run.items_run == 300
        assert sorted(led.ids) == list(range(300))

    def test_item_cost_remaps_onto_remainder(self, tmp_path):
        ckpt = Checkpointer(tmp_path)
        cov = CoverageMap(100)
        cov.mark(0, 90)
        save_coverage(ckpt, 90, cov, blocking=True)
        ckpt.wait_all()
        cost = [1.0] * 90 + [5.0] * 10
        run = checkpointed_parallel_for(
            _sim_runtime(2), _Ledger(), 100, checkpointer=ckpt,
            policy="multidynamic", acc_chunk=4, item_cost=cost)
        assert run.items_run == 10

    def test_rejected_kwargs(self, tmp_path):
        with pytest.raises(ValueError, match="elastic"):
            checkpointed_parallel_for(
                _sim_runtime(), _Ledger(), 10,
                checkpointer=Checkpointer(tmp_path),
                elastic=ElasticSchedule())


# ---------------------------------------------------------------------------
# wall-clock fleet manager (policy plumbing on fake workers)
# ---------------------------------------------------------------------------
class _FakeHandle:
    _port = 40000

    def __init__(self):
        _FakeHandle._port += 1
        self.address = f"127.0.0.1:{_FakeHandle._port}"
        self.alive = True
        self.killed = False
        self.terminated = False

    def terminate(self, timeout=10.0):
        self.alive = False
        self.terminated = True

    def kill(self):
        self.alive = False
        self.killed = True


class TestFleetManagerPolicy:
    def test_spawn_registers_heartbeat_spec(self):
        rt = HeteroRuntime()
        fm = FleetManager(rt, heartbeat=0.25, patience=4, spawn=_FakeHandle)
        name = fm.spawn_unit()
        assert name in rt.units
        spec = rt.units[name].backend
        assert "heartbeat=0.25" in spec and "patience=4" in spec
        fm.shutdown()
        assert name not in rt.units

    def test_scale_to_and_drain_order(self):
        rt = HeteroRuntime()
        fm = FleetManager(rt, spawn=_FakeHandle)
        names = fm.scale_to(3)
        assert len(fm) == 3 and sorted(names) == fm.members
        handles = {n: fm.handle(n) for n in fm.members}
        fm.scale_to(1)
        assert len(fm) == 1
        # newest members drained first; deregistered before termination
        assert fm.members == ["fleet0"]
        assert handles["fleet2"].terminated and handles["fleet1"].terminated
        assert set(rt.units) == {"fleet0"}
        fm.shutdown()

    def test_kill_keeps_registration_until_reaped(self):
        rt = HeteroRuntime()
        fm = FleetManager(rt, spawn=_FakeHandle)
        fm.scale_to(2)
        fm.kill_unit("fleet1")
        assert "fleet1" in rt.units     # crash is the engine's to detect
        assert fm.reap() == ["fleet1"]
        assert "fleet1" not in rt.units
        assert [e["action"] for e in fm.events][-2:] == ["kill", "dead"]
        fm.shutdown()

    def test_autoscale_step_applies_policy(self):
        rt = HeteroRuntime()
        scaler = Autoscaler(_model(), kernel="k", horizon=1.0,
                            max_units=8, cooldown_s=0.0)
        fm = FleetManager(rt, autoscaler=scaler, spawn=_FakeHandle)
        fm.scale_to(1)
        assert fm.autoscale_step(500, now=0.0) == 4   # 500/(100*1) -> 5
        assert len(fm) == 5
        assert fm.autoscale_step(0, now=1.0) == -1    # drain one
        assert len(fm) == 4
        fm.shutdown()

    def test_failed_registration_terminates_the_orphan(self):
        rt = HeteroRuntime()
        fm = FleetManager(rt, spawn=_FakeHandle)
        rt.register_unit("fleet0", WorkerKind.CC,
                         work_fn=lambda c: None)   # name collision ahead
        with pytest.raises(ValueError, match="duplicate"):
            fm.spawn_unit()
        assert len(fm) == 0   # no half-joined member left behind


# ---------------------------------------------------------------------------
# real subprocess fleet (slow tier; CI fleet job runs it wall-clock)
# ---------------------------------------------------------------------------
class _SharedSleep:
    """Picklable slow work (executes worker-side; effects client-side
    are irrelevant — coverage is asserted from the report)."""

    def __call__(self, chunk):
        time.sleep(chunk.size * 2e-4)


_shared_sleep = _SharedSleep()


@pytest.mark.slow
class TestSubprocessFleet:
    def test_spawn_run_drain(self):
        rt = HeteroRuntime()
        with FleetManager(rt, heartbeat=0.2, patience=5) as fm:
            fm.scale_to(2)
            assert len(fm) == 2
            rep = rt.parallel_for(_shared_sleep, num_items=200,
                                  policy="multidynamic", engine="interrupt",
                                  acc_chunk=8)
            assert rep.items == 200
            assert_exact_tiling(rep.coverage, 200)
            assert not [e for e in (rep.events or [])
                        if e["action"] in ("lost", "dead")]
            fm.drain_unit(fm.members[-1])
            assert len(fm) == 1
        assert len(fm) == 0

    def test_kill_dash_nine_mid_run_requeues_exact_once(self):
        # the acceptance line: a worker SIGKILLed mid-run is detected
        # (EOF or heartbeat silence), retired, and its chunk requeued —
        # the run completes with exact coverage on the survivors
        rt = HeteroRuntime()
        with FleetManager(rt, heartbeat=0.2, patience=5) as fm:
            fm.scale_to(3)
            victim = fm.members[-1]
            killer = threading.Timer(0.3, fm.handle(victim).kill)
            killer.start()
            try:
                rep = rt.parallel_for(_shared_sleep, num_items=400,
                                      policy="multidynamic",
                                      engine="interrupt", acc_chunk=8)
            finally:
                killer.cancel()
            assert rep.items == 400
            assert_exact_tiling(rep.coverage, 400)
            losses = [e for e in (rep.events or [])
                      if e["action"] in ("lost", "dead")]
            assert len(losses) <= 1
            if losses:
                assert losses[0]["unit"] == victim
            assert fm.reap() in ([victim], [])

"""The documentation's python snippets must execute (CI `docs` job locally)."""

import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = ["docs/architecture.md", "docs/runtime_api.md", "README.md"]


def test_doc_files_exist():
    for f in DOC_FILES:
        assert (ROOT / f).is_file(), f"{f} missing"


def test_doc_snippets_execute():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "tools/check_docs.py", *DOC_FILES],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == 0, f"doc snippets failed:\n{proc.stdout}\n{proc.stderr}"


def test_extractor_separates_languages(tmp_path):
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        from check_docs import extract_blocks
    finally:
        sys.path.pop(0)
    md = "\n".join([
        "# t", "```python", "x = 1", "```", "", "```bash", "rm -rf /", "```",
        "```", "plain", "```", "```python", "y = x + 1", "```",
    ])
    blocks = extract_blocks(md)
    assert len(blocks) == 2
    assert blocks[0][1] == "x = 1" and blocks[1][1] == "y = x + 1"

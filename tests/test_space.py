"""Iteration spaces: flat / tiled / sharded, and the sharded merge step.

Everything runs under SimulatedClock except the explicit wall-clock
sharding smoke test, so runs are deterministic and fast.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI container has no hypothesis; use the vendored shim
    from _propcheck import given, settings, strategies as st

from repro.core import (
    FlatSpace,
    HeteroRuntime,
    ShardedSpace,
    SimulatedClock,
    TiledSpace,
    WorkerKind,
)
from repro.core.runtime import ENGINES, POLICIES
from repro.core.space import as_space


def make_runtime(n_acc=2, n_cc=2, acc_speed=8e3, cc_speed=1e3, clock=None):
    rt = HeteroRuntime(clock=clock if clock is not None else SimulatedClock())
    for i in range(n_acc):
        rt.register_unit(f"acc{i}", WorkerKind.ACC, speed=acc_speed)
    for i in range(n_cc):
        rt.register_unit(f"cc{i}", WorkerKind.CC, speed=cc_speed)
    return rt


def assert_exact_tiling(spans, n_items):
    assert spans, "no chunks completed"
    assert spans[0][0] == 0
    assert spans[-1][1] == n_items
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c, f"gap or overlap at {b}:{c}"


class TestSpaceConstruction:
    def test_flat_space(self):
        assert len(FlatSpace(10)) == 10
        with pytest.raises(ValueError):
            FlatSpace(0)

    def test_as_space_normalization(self):
        assert isinstance(as_space(None, 5), FlatSpace)
        assert as_space(7, 0).num_items == 7
        sp = TiledSpace((4, 4), (2, 2))
        assert as_space(sp, 0) is sp
        with pytest.raises(ValueError):
            as_space(sp, 99)  # contradictory num_items
        with pytest.raises(TypeError):
            as_space("nope", 0)

    def test_tiled_edge_clipping(self):
        sp = TiledSpace((100, 90), (32, 32))
        assert sp.tiles == (4, 3)
        assert sp.num_items == 12
        # last tile is clipped to the grid on both axes
        rs, cs = sp.tile_slices(sp.num_items - 1)
        assert (rs.start, rs.stop) == (96, 100)
        assert (cs.start, cs.stop) == (64, 90)
        with pytest.raises(IndexError):
            sp.tile_slices(12)

    def test_tiled_row_major_order(self):
        sp = TiledSpace((4, 6), (2, 2))  # 2x3 tiles
        assert [sp.tile_index(i) for i in range(6)] == [
            (0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2)]

    def test_tiled_slices_tile_the_grid(self):
        sp = TiledSpace((10, 7), (3, 2))
        mask = np.zeros((10, 7), int)
        for i in range(sp.num_items):
            rs, cs = sp.tile_slices(i)
            mask[rs, cs] += 1
        assert (mask == 1).all()

    def test_sharded_bounds_partition_exactly(self):
        sp = ShardedSpace(101, 4)
        bounds = sp.bounds
        assert bounds[0][0] == 0 and bounds[-1][1] == 101
        for (a, b), (c, d) in zip(bounds, bounds[1:]):
            assert b == c
        assert all(b > a for a, b in bounds)
        assert sp.shard_of(0) == 0 and sp.shard_of(100) == 3

    def test_sharded_weights_skew_partition(self):
        sp = ShardedSpace(100, 2, weights=[3.0, 1.0])
        (a0, b0), (a1, b1) = sp.bounds
        assert b0 - a0 == 75 and b1 - a1 == 25

    def test_sharded_validation(self):
        with pytest.raises(ValueError):
            ShardedSpace(3, 5)          # more shards than items
        with pytest.raises(ValueError):
            ShardedSpace(10, 2, weights=[1.0])
        with pytest.raises(ValueError):
            ShardedSpace(10, 2, weights=[1.0, -1.0])
        with pytest.raises(TypeError):
            ShardedSpace(ShardedSpace(10, 2), 2)

    def test_sharded_wraps_tiled(self):
        sp = ShardedSpace(TiledSpace((8, 8), (2, 2)), 2)
        assert sp.num_items == 16
        assert sp.bounds == [(0, 8), (8, 16)]


class TestShardedExecution:
    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("engine", ENGINES)
    def test_exact_once_all_policies_and_engines(self, policy, engine):
        rep = make_runtime().parallel_for(
            space=ShardedSpace(997, 3), policy=policy, engine=engine,
            acc_chunk=64,
        )
        assert rep.items == 997
        assert rep.num_shards == 3
        assert_exact_tiling(rep.coverage, 997)

    def test_merged_report_structure(self):
        space = ShardedSpace(4096, 4)
        rep = make_runtime().parallel_for(
            space=space, policy="multidynamic", engine="interrupt",
            acc_chunk=128,
        )
        assert len(rep.shard_reports) == 4
        # per-shard items add up, and per-shard coverage tiles its slice
        for k, sub in enumerate(rep.shard_reports):
            start, stop = space.shard_bounds(k)
            assert sub.items == stop - start
            assert sub.coverage[0][0] == start
            assert sub.coverage[-1][1] == stop
        # merged per-unit maps are shard-namespaced
        assert set(rep.per_worker_items) == {
            f"s{k}/{u}" for k in range(4)
            for u in ("acc0", "acc1", "cc0", "cc1")
        }
        assert sum(rep.per_worker_items.values()) == 4096
        assert rep.cross_shard_balance >= 1.0
        # shards run concurrently: global makespan is the slowest shard
        assert rep.wall_time == max(s.wall_time for s in rep.shard_reports)

    def test_sharded_makespan_beats_single_host(self):
        """4 hosts over the same space finish ~4x faster than one."""
        costs = np.random.default_rng(0).zipf(1.5, 8192).clip(max=50).astype(float)
        one = make_runtime().parallel_for(
            num_items=8192, policy="multidynamic", engine="interrupt",
            acc_chunk=128, item_cost=costs,
        )
        four = make_runtime().parallel_for(
            space=ShardedSpace(8192, 4), policy="multidynamic",
            engine="interrupt", acc_chunk=128, item_cost=costs,
        )
        assert four.makespan < one.makespan / 2.5

    def test_weighted_shards_balance_known_skew(self):
        """Weighting shards by host capacity narrows cross-shard imbalance
        for a regular workload on heterogeneous hosts... modelled here as
        per-item costs that double in the second half of the space."""
        costs = [1.0] * 500 + [2.0] * 500
        even = make_runtime().parallel_for(
            space=ShardedSpace(1000, 2), policy="multidynamic",
            engine="interrupt", acc_chunk=32, item_cost=costs,
        )
        weighted = make_runtime().parallel_for(
            space=ShardedSpace(1000, 2, weights=[2.0, 1.0]),
            policy="multidynamic", engine="interrupt", acc_chunk=32,
            item_cost=costs,
        )
        assert weighted.cross_shard_balance < even.cross_shard_balance

    def test_fixed_mapping_policy_rejected(self):
        rt = make_runtime()
        with pytest.raises(ValueError):
            rt.parallel_for(
                space=ShardedSpace(100, 2),
                policy={"acc0": (0, 100)}, engine="inline",
            )

    def test_sharded_deterministic(self):
        def run():
            return make_runtime().parallel_for(
                space=ShardedSpace(2048, 3), policy="multidynamic",
                engine="interrupt", acc_chunk=64,
            )
        r1, r2 = run(), run()
        assert r1.makespan == r2.makespan
        assert r1.coverage == r2.coverage
        assert r1.per_worker_items == r2.per_worker_items

    @given(
        n_items=st.integers(4, 3000),
        num_shards=st.integers(1, 4),
        acc_chunk=st.integers(1, 300),
        pick=st.integers(0, 8),
    )
    @settings(max_examples=40, deadline=None)
    def test_sharded_tiling_property(self, n_items, num_shards, acc_chunk, pick):
        rep = make_runtime().parallel_for(
            space=ShardedSpace(n_items, num_shards),
            policy=POLICIES[pick % 3], engine=ENGINES[pick // 3],
            acc_chunk=acc_chunk,
        )
        assert rep.items == n_items
        assert_exact_tiling(rep.coverage, n_items)

    def test_wall_clock_sharded(self):
        import time

        rt = HeteroRuntime()
        rt.register_unit("a", WorkerKind.ACC,
                         work_fn=lambda c: time.sleep(c.size * 1e-5))
        rt.register_unit("b", WorkerKind.CC,
                         work_fn=lambda c: time.sleep(c.size * 2e-5))
        rep = rt.parallel_for(
            space=ShardedSpace(400, 2), policy="multidynamic",
            engine="interrupt", acc_chunk=32,
        )
        assert rep.items == 400
        assert_exact_tiling(rep.coverage, 400)
        assert rep.num_shards == 2


class TestTiledExecution:
    @pytest.mark.parametrize("engine", ENGINES)
    def test_every_tile_scheduled_once(self, engine):
        space = TiledSpace((100, 90), (32, 32))
        mask = np.zeros(space.grid, int)

        def work(chunk):
            for rs, cs in space.chunk_slices(chunk):
                mask[rs, cs] += 1

        rep = make_runtime().parallel_for(
            work, space=space, policy="multidynamic", engine=engine,
            acc_chunk=2,
        )
        assert rep.items == space.num_items
        assert (mask == 1).all()

    def test_tiled_inside_sharded(self):
        space = TiledSpace((64, 64), (8, 8))  # 64 tiles
        rep = make_runtime().parallel_for(
            space=ShardedSpace(space, 2), policy="multidynamic",
            engine="interrupt", acc_chunk=4,
        )
        assert rep.items == 64
        assert_exact_tiling(rep.coverage, 64)

    def test_work_queue_over_space(self):
        rt = make_runtime(n_acc=2, n_cc=0)
        feed = rt.work_queue(space=FlatSpace(5), acc_chunk=1)
        seen = []
        while True:
            progressed = False
            for name in feed.idle_units:
                chunk = feed.acquire(name)
                if chunk is not None:
                    seen.append(chunk.start)
                    feed.complete(name)
                    progressed = True
            if not progressed:
                break
        assert sorted(seen) == list(range(5))

    def test_work_queue_rejects_sharded(self):
        rt = make_runtime()
        with pytest.raises(ValueError):
            rt.work_queue(space=ShardedSpace(10, 2))

"""MultiDynamic scheduler: unit + property tests (paper §3.3 semantics)."""

import random
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI container has no hypothesis; use the vendored shim
    from _propcheck import given, settings, strategies as st

from repro.core import (
    AsyncEngine,
    MultiDynamicScheduler,
    OracleStaticScheduler,
    PollingEngine,
    StaticScheduler,
    WorkerKind,
)


def make_sched(n_items=500, acc_chunk=64, n_acc=2, n_cc=2, **kw):
    s = MultiDynamicScheduler(n_items, acc_chunk, **kw)
    for i in range(n_acc):
        s.add_worker(f"acc{i}", WorkerKind.ACC)
    for i in range(n_cc):
        s.add_worker(f"cc{i}", WorkerKind.CC)
    return s


class TestChunkIssue:
    def test_acc_chunk_is_user_size(self):
        s = make_sched(n_items=1000, acc_chunk=128)
        c = s.next_chunk("acc0")
        assert c.size == 128

    def test_cc_chunk_adapts_to_throughput_ratio(self):
        s = make_sched(n_items=100_000, acc_chunk=100)
        s.next_chunk("acc0")
        s.complete("acc0", 0.001)       # 100k items/s
        s.next_chunk("cc0")
        s.complete("cc0", 0.1)          # ~adaptive seed chunk
        # now cc throughput known; next cc chunk ≈ acc_chunk * t_cc/t_acc
        c = s.next_chunk("cc0")
        t_cc = s.workers["cc0"].throughput
        t_acc = s.workers["acc0"].throughput
        expected = 100 * t_cc / t_acc
        assert c.size <= max(2 * expected, s.min_cc_chunk * 2)

    def test_busy_worker_cannot_double_issue(self):
        s = make_sched()
        s.next_chunk("acc0")
        with pytest.raises(RuntimeError):
            s.next_chunk("acc0")

    def test_exhaustion_returns_none(self):
        s = make_sched(n_items=64, acc_chunk=64)
        assert s.next_chunk("acc0") is not None
        assert s.next_chunk("acc1") is None


class TestCoverage:
    @given(
        n_items=st.integers(1, 2000),
        acc_chunk=st.integers(1, 300),
        n_acc=st.integers(1, 4),
        n_cc=st.integers(0, 4),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=60, deadline=None)
    def test_exact_disjoint_coverage(self, n_items, acc_chunk, n_acc, n_cc, seed):
        """Property: every index processed exactly once, none skipped —
        regardless of worker mix, chunk size, and completion order."""
        rng = random.Random(seed)
        s = MultiDynamicScheduler(n_items, acc_chunk)
        names = [f"acc{i}" for i in range(n_acc)] + [f"cc{i}" for i in range(n_cc)]
        for n in names:
            s.add_worker(n, WorkerKind.ACC if n.startswith("acc") else WorkerKind.CC)
        outstanding = {}
        while True:
            idle = [n for n in names if n not in outstanding]
            progressed = False
            for n in idle:
                c = s.next_chunk(n)
                if c is not None:
                    outstanding[n] = c
                    progressed = True
            if not outstanding:
                break
            done = rng.choice(list(outstanding))
            outstanding.pop(done)
            s.complete(done, rng.uniform(1e-4, 1e-2))
            if not progressed and not outstanding and s.issued >= n_items:
                break
        spans = s.coverage()
        assert spans[0][0] == 0
        assert spans[-1][1] == n_items
        for (a, b), (c, d) in zip(spans, spans[1:]):
            assert b == c, f"gap or overlap at {b}:{c}"

    def test_throughput_ewma_positive(self):
        s = make_sched()
        c = s.next_chunk("acc0")
        s.complete("acc0", 0.01)
        assert s.workers["acc0"].throughput == pytest.approx(c.size / 0.01)


class TestEngines:
    def _run(self, engine_cls, rates, n_items=400, **kw):
        s = MultiDynamicScheduler(n_items, acc_chunk=64)
        for name in rates:
            s.add_worker(name, WorkerKind.ACC if "acc" in name else WorkerKind.CC)

        def work(rate):
            def fn(chunk):
                time.sleep(chunk.size / rate)
            return fn

        eng = engine_cls(s, {n: work(r) for n, r in rates.items()}, **kw)
        return eng.run()

    def test_async_engine_completes_all(self):
        rep = self._run(AsyncEngine, {"acc0": 8e4, "acc1": 8e4, "cc0": 1e4})
        assert rep.items == 400

    def test_async_beats_polling_with_heterogeneous_units(self):
        rates = {"acc0": 8e4, "acc1": 8e4, "cc0": 2e4, "cc1": 2e4}
        rep_async = self._run(AsyncEngine, rates)
        rep_poll = self._run(PollingEngine, rates)
        # paper claim: interrupts (async) beat busy-wait on multi-unit runs
        assert rep_async.throughput > rep_poll.throughput

    def test_work_distribution_favours_fast_units(self):
        rep = self._run(AsyncEngine, {"acc0": 1e5, "cc0": 1e4})
        assert rep.per_worker_items["acc0"] > rep.per_worker_items["cc0"]


class TestBaselines:
    def test_static_even_split(self):
        s = StaticScheduler(100, ["a", "b", "c"])
        sizes = [s.next_chunk(w).size for w in ("a", "b", "c")]
        assert sum(sizes) == 100
        assert max(sizes) - min(sizes) <= 1

    def test_oracle_proportional(self):
        s = OracleStaticScheduler(100, {"fast": 9.0, "slow": 1.0})
        assert s.next_chunk("fast").size == 90
        assert s.next_chunk("slow").size == 10

"""MultiDynamic scheduler: unit + property tests (paper §3.3 semantics)."""

import random
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI container has no hypothesis; use the vendored shim
    from _propcheck import given, settings, strategies as st

from repro.core import (
    AsyncEngine,
    MultiDynamicScheduler,
    OracleStaticScheduler,
    PollingEngine,
    StaticScheduler,
    WorkerKind,
)
from repro.core.scheduler import (
    THROUGHPUT_FLOOR,
    latency_aware_split,
    proportional_split,
)


def make_sched(n_items=500, acc_chunk=64, n_acc=2, n_cc=2, **kw):
    s = MultiDynamicScheduler(n_items, acc_chunk, **kw)
    for i in range(n_acc):
        s.add_worker(f"acc{i}", WorkerKind.ACC)
    for i in range(n_cc):
        s.add_worker(f"cc{i}", WorkerKind.CC)
    return s


class TestChunkIssue:
    def test_acc_chunk_is_user_size(self):
        s = make_sched(n_items=1000, acc_chunk=128)
        c = s.next_chunk("acc0")
        assert c.size == 128

    def test_cc_chunk_adapts_to_throughput_ratio(self):
        s = make_sched(n_items=100_000, acc_chunk=100)
        s.next_chunk("acc0")
        s.complete("acc0", 0.001)       # 100k items/s
        s.next_chunk("cc0")
        s.complete("cc0", 0.1)          # ~adaptive seed chunk
        # now cc throughput known; next cc chunk ≈ acc_chunk * t_cc/t_acc
        c = s.next_chunk("cc0")
        t_cc = s.workers["cc0"].throughput
        t_acc = s.workers["acc0"].throughput
        expected = 100 * t_cc / t_acc
        assert c.size <= max(2 * expected, s.min_cc_chunk * 2)

    def test_busy_worker_cannot_double_issue(self):
        s = make_sched()
        s.next_chunk("acc0")
        with pytest.raises(RuntimeError):
            s.next_chunk("acc0")

    def test_exhaustion_returns_none(self):
        s = make_sched(n_items=64, acc_chunk=64)
        assert s.next_chunk("acc0") is not None
        assert s.next_chunk("acc1") is None


class TestCoverage:
    @given(
        n_items=st.integers(1, 2000),
        acc_chunk=st.integers(1, 300),
        n_acc=st.integers(1, 4),
        n_cc=st.integers(0, 4),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=60, deadline=None)
    def test_exact_disjoint_coverage(self, n_items, acc_chunk, n_acc, n_cc, seed):
        """Property: every index processed exactly once, none skipped —
        regardless of worker mix, chunk size, and completion order."""
        rng = random.Random(seed)
        s = MultiDynamicScheduler(n_items, acc_chunk)
        names = [f"acc{i}" for i in range(n_acc)] + [f"cc{i}" for i in range(n_cc)]
        for n in names:
            s.add_worker(n, WorkerKind.ACC if n.startswith("acc") else WorkerKind.CC)
        outstanding = {}
        while True:
            idle = [n for n in names if n not in outstanding]
            progressed = False
            for n in idle:
                c = s.next_chunk(n)
                if c is not None:
                    outstanding[n] = c
                    progressed = True
            if not outstanding:
                break
            done = rng.choice(list(outstanding))
            outstanding.pop(done)
            s.complete(done, rng.uniform(1e-4, 1e-2))
            if not progressed and not outstanding and s.issued >= n_items:
                break
        spans = s.coverage()
        assert spans[0][0] == 0
        assert spans[-1][1] == n_items
        for (a, b), (c, d) in zip(spans, spans[1:]):
            assert b == c, f"gap or overlap at {b}:{c}"

    def test_throughput_ewma_positive(self):
        s = make_sched()
        c = s.next_chunk("acc0")
        s.complete("acc0", 0.01)
        assert s.workers["acc0"].throughput == pytest.approx(c.size / 0.01)


class TestEngines:
    def _run(self, engine_cls, rates, n_items=400, **kw):
        s = MultiDynamicScheduler(n_items, acc_chunk=64)
        for name in rates:
            s.add_worker(name, WorkerKind.ACC if "acc" in name else WorkerKind.CC)

        def work(rate):
            def fn(chunk):
                time.sleep(chunk.size / rate)
            return fn

        eng = engine_cls(s, {n: work(r) for n, r in rates.items()}, **kw)
        return eng.run()

    def test_async_engine_completes_all(self):
        rep = self._run(AsyncEngine, {"acc0": 8e4, "acc1": 8e4, "cc0": 1e4})
        assert rep.items == 400

    def test_async_beats_polling_with_heterogeneous_units(self):
        rates = {"acc0": 8e4, "acc1": 8e4, "cc0": 2e4, "cc1": 2e4}
        rep_async = self._run(AsyncEngine, rates)
        rep_poll = self._run(PollingEngine, rates)
        # paper claim: interrupts (async) beat busy-wait on multi-unit runs
        assert rep_async.throughput > rep_poll.throughput

    def test_work_distribution_favours_fast_units(self):
        rep = self._run(AsyncEngine, {"acc0": 1e5, "cc0": 1e4})
        assert rep.per_worker_items["acc0"] > rep.per_worker_items["cc0"]


class TestBaselines:
    def test_static_even_split(self):
        s = StaticScheduler(100, ["a", "b", "c"])
        sizes = [s.next_chunk(w).size for w in ("a", "b", "c")]
        assert sum(sizes) == 100
        assert max(sizes) - min(sizes) <= 1

    def test_oracle_proportional(self):
        s = OracleStaticScheduler(100, {"fast": 9.0, "slow": 1.0})
        assert s.next_chunk("fast").size == 90
        assert s.next_chunk("slow").size == 10

    def test_oracle_accepts_overheads(self):
        # equal speeds, one unit pays per-chunk dispatch: the oracle's
        # pre-split shifts that unit's share of the line to the free ones
        s = OracleStaticScheduler(300, {"loc": 1000.0, "rem": 1000.0},
                                  overheads={"rem": 0.1})
        assert s.next_chunk("loc").size > s.next_chunk("rem").size


# ---------------------------------------------------------------------------
# latency-aware water-filling split (ISSUE 9 tentpole)
# ---------------------------------------------------------------------------
class TestLatencyAwareSplit:
    def test_zero_overhead_matches_proportional(self):
        tp = {"a": 3.0, "b": 1.0, "c": 2.0}
        assert latency_aware_split(600, tp) == proportional_split(600, tp)
        assert latency_aware_split(
            600, tp, {"a": 0.0, "b": 0.0, "c": 0.0}
        ) == proportional_split(600, tp)

    def test_high_overhead_unit_gets_smaller_share(self):
        # throughput-only would hand 100 items each; the remote unit pays
        # 0.04 s of dispatch = 40 items' worth at 1000 items/s, and the
        # water-fill splits that burden across the free units:
        # level = (300 + 1000*0.04) / 3000, shares {113.3, 113.3, 73.3}
        sizes = latency_aware_split(
            300, {"a": 1000.0, "b": 1000.0, "r": 1000.0}, {"r": 0.04})
        assert sizes == {"a": 113, "b": 113, "r": 74}

    def test_equalizes_predicted_completion(self):
        tp = {"a": 200.0, "b": 50.0}
        ov = {"a": 0.0, "b": 0.1}
        sizes = latency_aware_split(1000, tp, ov)
        assert sizes == {"a": 804, "b": 196}
        finish = {w: sizes[w] / tp[w] + ov[w] for w in tp}
        # predicted completion times agree to within one slow-unit item
        assert abs(finish["a"] - finish["b"]) <= 1.5 / min(tp.values())

    def test_dominated_unit_floors_at_one_item(self):
        # overhead past the water level excludes the unit from the fill;
        # the starvation floor still keeps it live with one item
        assert latency_aware_split(
            300, {"a": 10.0, "r": 10.0}, {"r": 1e6}) == {"a": 299, "r": 1}

    def test_fewer_items_than_units_starves_worst_unit(self):
        # no floor when the space cannot feed everyone: the highest-
        # overhead unit is the one that goes hungry
        sizes = latency_aware_split(
            2, {"a": 1.0, "b": 1.0, "c": 1.0}, {"c": 99.0})
        assert sizes == {"a": 1, "b": 1, "c": 0}

    def test_zero_items_and_negative(self):
        assert latency_aware_split(0, {"a": 1.0, "b": 2.0}) == {"a": 0, "b": 0}
        with pytest.raises(ValueError):
            latency_aware_split(-1, {"a": 1.0})
        with pytest.raises(ValueError):
            latency_aware_split(10, {})
        with pytest.raises(ValueError):
            latency_aware_split(10, {"a": 0.0})

    def test_proportional_starvation_floor(self):
        # regression: round(10 * 0.001/100.001) == 0 used to starve "b"
        # even though it has positive throughput and the space has room
        assert proportional_split(10, {"a": 100.0, "b": 0.001}) == \
            {"a": 9, "b": 1}

    def test_bankers_rounding_pinned(self):
        # insertion order, round-half-even on the interior units, last
        # unit absorbs the remainder — the exact contract downstream
        # pre-split consumers (and the stores that compare plans) rely on
        assert proportional_split(10, {"a": 1.0, "b": 1.0, "c": 1.0,
                                       "d": 1.0}) == \
            {"a": 2, "b": 2, "c": 2, "d": 4}

    @given(
        n_items=st.integers(0, 5000),
        n_units=st.integers(1, 6),
        seed=st.integers(0, 10_000),
    )
    @settings(max_examples=80, deadline=None)
    def test_property_exact_tiling_and_floor(self, n_items, n_units, seed):
        """Property: sizes tile the space exactly and every positive-
        throughput unit gets >= 1 item whenever the space has room —
        for any throughput/overhead mix (including zero-throughput and
        huge-overhead units)."""
        rng = random.Random(seed)
        tp = {f"u{i}": (0.0 if rng.random() < 0.2
                        else rng.uniform(1e-3, 1000.0))
              for i in range(n_units)}
        tp["u0"] = max(tp["u0"], 1.0)  # keep the total positive
        ov = {f"u{i}": (0.0 if rng.random() < 0.5
                        else rng.uniform(0.0, 5.0))
              for i in range(n_units)}
        sizes = latency_aware_split(n_items, tp, ov)
        assert set(sizes) == set(tp)
        assert sum(sizes.values()) == n_items
        assert all(v >= 0 for v in sizes.values())
        assert all(sizes[w] == 0 for w in tp if tp[w] <= 0.0)
        if n_items >= n_units:
            assert all(sizes[w] >= 1 for w in tp if tp[w] > 0.0), (
                f"starved a live unit: {sizes} tp={tp} ov={ov}")


# ---------------------------------------------------------------------------
# elastic leave: abort/remove_worker must surrender *all* in-flight chunks
# ---------------------------------------------------------------------------
class TestElasticReturns:
    def test_abort_returns_all_outstanding_capacity_3(self):
        s = make_sched(n_items=1000, acc_chunk=64)
        s.set_capacity("acc0", 3)
        issued = [s.next_chunk("acc0") for _ in range(3)]
        with pytest.raises(RuntimeError):
            s.next_chunk("acc0")  # capacity still enforced at 3
        returned = s.abort("acc0")
        # regression: a pipelined worker held 3 chunks but abort used to
        # surrender only the oldest, silently losing the other spans
        assert returned == issued
        assert not s.workers["acc0"].busy

    def test_remove_worker_returns_all_and_unregisters(self):
        s = make_sched(n_items=1000, acc_chunk=64)
        s.set_capacity("acc0", 3)
        issued = [s.next_chunk("acc0") for _ in range(3)]
        returned = s.remove_worker("acc0")
        assert returned == issued
        assert "acc0" not in s.workers
        # the surrendered spans are disjoint and oldest-first: exactly
        # what the caller must requeue for coverage to stay exact-once
        spans = [(c.start, c.stop) for c in returned]
        assert spans == sorted(spans)
        for (a, b), (c, d) in zip(spans, spans[1:]):
            assert b <= c

    def test_abort_idle_worker_returns_empty_list(self):
        s = make_sched()
        assert s.abort("acc0") == []


# ---------------------------------------------------------------------------
# throughput estimation: a measured 0.0 is an observation, not "no data"
# ---------------------------------------------------------------------------
class TestThroughputFloor:
    def test_measured_zero_is_floored_not_bootstrapped(self):
        s = MultiDynamicScheduler(100, 10)
        s.add_worker("cc0", WorkerKind.CC, throughput=0.0)
        # regression: truthiness treated a stalled unit's 0.0 as
        # unobserved and handed it the optimistic bootstrap prior
        est = s._estimated_throughput(s.workers["cc0"])
        assert est == THROUGHPUT_FLOOR

    def test_bootstrap_prior_sees_zero_observation(self):
        s = MultiDynamicScheduler(100, 10)
        s.add_worker("cc0", WorkerKind.CC, throughput=0.0)
        s.add_worker("acc0", WorkerKind.ACC)
        # the unobserved ACC bootstraps relative to the *slowest observed*
        # unit — which is the stalled one, floored, not skipped
        est = s._estimated_throughput(s.workers["acc0"])
        assert est == pytest.approx(THROUGHPUT_FLOOR * s.initial_acc_speedup)

    def test_zero_throughput_worker_still_issues_chunks(self):
        s = MultiDynamicScheduler(100, 10)
        s.add_worker("cc0", WorkerKind.CC, throughput=0.0)
        c = s.next_chunk("cc0")
        assert c is not None and c.size >= 1

"""End-to-end training driver: a ~100M-parameter llama-family model for a
few hundred steps on the synthetic corpus, with async checkpointing.

    PYTHONPATH=src python examples/train_small.py [--steps 300]

(~100M params: 12 layers × d512 with an 8k vocab — runs on CPU in minutes;
the identical driver lowers unchanged on real pods.)
"""

import argparse

from repro.configs import get_config
from repro.launch.train import TrainLoopConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    out = run_training(TrainLoopConfig(
        arch="tinyllama-1.1b",      # llama wiring; smoke-reduced dims
        steps=args.steps,
        global_batch=8,
        seq_len=128,
        lr=1e-3,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        log_every=25,
    ))
    print(f"\nfinal: loss {out['first_loss']:.4f} → {out['final_loss']:.4f} "
          f"({out['mean_tok_per_s']:,.0f} tok/s)")
    assert out["final_loss"] < out["first_loss"], "loss must decrease"


if __name__ == "__main__":
    main()

"""Sharded + elastic iteration spaces, end to end.

    PYTHONPATH=src python examples/elastic_sharded_demo.py

Three escalating scenarios, all in deterministic virtual time
(SimulatedClock — nothing sleeps, every run is exactly reproducible):

1. A global space sharded across hosts, each host running its own
   MultiDynamic scheduler + interrupt engine over its slice.
2. A mid-run host failure driven through ElasticMeshManager: the mesh's
   failure domain maps to scheduler units, the departed unit's in-flight
   chunk is requeued, and a replacement unit joins and starts stealing.
3. A 2D tiled kernel grid (hotspot-style) scheduled as tiles.
"""

import numpy as np

from repro.core import (
    ElasticMeshManager,
    ElasticSchedule,
    HeteroRuntime,
    ShardedSpace,
    SimulatedClock,
    TiledSpace,
    WorkerKind,
)


def make_host(clock):
    """One SoC's worth of units: 2 fast ACCs + 2 slow CCs."""
    rt = HeteroRuntime(clock=clock)
    for i in range(2):
        rt.register_unit(f"acc{i}", WorkerKind.ACC, speed=8e4)
        rt.register_unit(f"cc{i}", WorkerKind.CC, speed=1e4)
    return rt


def exact_once(coverage, n):
    ok = coverage[0][0] == 0 and coverage[-1][1] == n
    return ok and all(b == c for (_, b), (c, _) in zip(coverage, coverage[1:]))


# -- 1. sharded ------------------------------------------------------------
rng = np.random.default_rng(0)
costs = rng.zipf(1.5, 16384).clip(max=50).astype(float)   # irregular workload

rt = make_host(SimulatedClock())
rep = rt.parallel_for(
    space=ShardedSpace(16384, num_shards=4),
    policy="multidynamic", engine="interrupt", acc_chunk=256,
    item_cost=costs,
)
print(f"[sharded]  {rep.num_shards} shards x {len(rt.units)} units, "
      f"items={rep.items}, exact-once={exact_once(rep.coverage, 16384)}")
print(f"           makespan={rep.makespan * 1e3:.2f}ms virtual, "
      f"cross-shard balance={rep.cross_shard_balance:.3f}, "
      f"intra-shard load balance={rep.load_balance:.3f}")

# -- 2. elastic, mesh-driven -----------------------------------------------
# Two hosts of 4 devices each; units are bound to hosts so a device fault
# (which takes out its whole host) becomes unit-leave events for the run.
mesh = ElasticMeshManager((2, 4), ("host", "model"), host_size=4)
schedule = ElasticSchedule.from_mesh(
    mesh,
    bindings={"acc1": 1, "cc1": 1},        # these units live on host 1
    faults=[(0.02, 5)],                    # device 5 fails at t=0.02
    joins=[],
)
schedule.join(0.05, "acc9", kind="acc", speed=8e4)   # replacement capacity

rt = make_host(SimulatedClock())
rep = rt.parallel_for(
    num_items=16384, policy="multidynamic", engine="interrupt",
    acc_chunk=256, item_cost=costs, elastic=schedule,
)
print(f"[elastic]  exact-once={exact_once(rep.coverage, 16384)}, "
      f"mesh lost devices={mesh.lost_ids}")
for ev in rep.events:
    req = f", requeued {ev['requeued']}" if ev["requeued"] else ""
    print(f"           t={ev['t']:.3f}s {ev['action']:>5} {ev['unit']}{req}")
print(f"           replacement did {rep.per_worker_items.get('acc9', 0)} items")

# -- 3. tiled 2D kernel grid ----------------------------------------------
space = TiledSpace(grid=(1024, 1024), tile=(128, 128))   # 8x8 tiles
touched = []
rt = make_host(SimulatedClock())
rep = rt.parallel_for(
    lambda chunk: touched.extend(space.chunk_slices(chunk)),
    space=space, policy="multidynamic", engine="interrupt", acc_chunk=8,
)
print(f"[tiled]    {space.describe()}: {rep.items} tiles, "
      f"{len(touched)} slices recorded, "
      f"first={touched[0][0]}, {touched[0][1]}")

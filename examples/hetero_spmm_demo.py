"""The paper's SPMM experiment in miniature: MultiDynamic hybrid execution
of an irregular sparse matmul across the MXU-dense and VPU-gather paths.

    PYTHONPATH=src python examples/hetero_spmm_demo.py
"""

import numpy as np

from repro.kernels.spmm.ops import make_hybrid_executor
from repro.kernels.spmm.ref import make_problem, spmm_dense_ref

# Irregular rows (lognormal nnz) — the workload ENEAC targets.
problem = make_problem(rows=512, cols=1024, n_dense=64,
                       nnz_mean=12.0, nnz_sigma=1.2, seed=7)
print(f"SPMM {problem.rows}×{problem.n_cols} · {problem.n_cols}×64, "
      f"nnz/row: min={problem.nnz.min()} median={int(np.median(problem.nnz))} "
      f"max={problem.nnz.max()}")

executor, order = make_hybrid_executor(problem)
decision = executor.converge(rounds=5)
print(f"MultiDynamic split after adaptation: dense(ACC)={decision.n_dense} "
      f"rows, sparse(CC)={decision.n_sparse} rows "
      f"({decision.dense_fraction:.0%} on the dense path)")

result, _ = executor.run(decision)
inv = np.empty_like(order)
inv[order] = np.arange(len(order))
err = np.abs(np.asarray(result)[inv] - spmm_dense_ref(problem)).max()
print(f"hybrid result max|err| vs dense oracle: {err:.2e}")
assert err < 1e-3

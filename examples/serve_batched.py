"""Serving example: continuous batching vs the static baseline on one
request set — the serving face of the paper's interrupt-vs-polling result.

    PYTHONPATH=src python examples/serve_batched.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.models import make_model
from repro.serving import Request, ServingEngine

cfg = get_config("llama3.2-3b").smoke()
model = make_model(cfg)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(0)
protos = [
    (rng.integers(0, cfg.vocab_size, int(rng.integers(3, 12))).astype(np.int32),
     int(rng.integers(3, 28)))
    for _ in range(16)
]

for mode in ("static", "continuous"):
    engine = ServingEngine(model, params, slots=4, max_len=96, mode=mode)
    for i, (prompt, mx) in enumerate(protos):
        engine.submit(Request(rid=i, prompt=prompt, max_new_tokens=mx))
    results = engine.run()
    rep = engine.throughput_report()
    print(f"{mode:11s}: {rep['tokens']} tokens / {rep['steps']} decode steps "
          f"= {rep['tokens_per_step']:.2f} tok/step "
          f"(mean latency {rep['mean_latency'] * 1e3:.0f} ms)")

"""Quickstart: the public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import MultiDynamicScheduler, AsyncEngine, WorkerKind
from repro.models import make_model

# ---------------------------------------------------------------- models --
# Any assigned architecture by id; .smoke() gives a CPU-runnable reduction.
cfg = get_config("qwen3-14b").smoke()
model = make_model(cfg)
params = model.init(jax.random.PRNGKey(0))

tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
loss, metrics = model.loss_fn(
    params,
    {"tokens": tokens, "labels": tokens,
     "mask": jnp.ones(tokens.shape, jnp.float32)},
)
print(f"[models]   {cfg.name}: loss={float(loss):.4f}")

# generation: prefill + decode with a KV cache
logits, caches = model.prefill(params, {"tokens": tokens}, max_len=24)
nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
logits, caches = model.decode_step(
    params, nxt, jnp.full((2, 1), 16, jnp.int32), caches)
print(f"[serving]  decoded next tokens: {np.asarray(jnp.argmax(logits, -1))}")

# ------------------------------------------------------------- scheduler --
# The paper's MultiDynamic parallel_for: 2 fast accelerators + 2 slow cores
# work one iteration space simultaneously; chunks hand out on completion.
import time

sched = MultiDynamicScheduler(num_items=400, acc_chunk=64)
for i in range(2):
    sched.add_worker(f"acc{i}", WorkerKind.ACC)
    sched.add_worker(f"cc{i}", WorkerKind.CC)

def unit(rate):
    def work(chunk):
        time.sleep(chunk.size / rate)
    return work

report = AsyncEngine(
    sched,
    {"acc0": unit(8e4), "acc1": unit(8e4), "cc0": unit(1e4), "cc1": unit(1e4)},
).run()
print(f"[eneac]    {report.items} items, split={report.per_worker_items}, "
      f"load-balance={report.load_balance:.2f}")

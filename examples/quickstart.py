"""Quickstart: the public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    ElasticSchedule,
    HeteroRuntime,
    ShardedSpace,
    SimulatedClock,
    TiledSpace,
    WorkerKind,
)
from repro.models import make_model

# ---------------------------------------------------------------- models --
# Any assigned architecture by id; .smoke() gives a CPU-runnable reduction.
cfg = get_config("qwen3-14b").smoke()
model = make_model(cfg)
params = model.init(jax.random.PRNGKey(0))

tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
loss, metrics = model.loss_fn(
    params,
    {"tokens": tokens, "labels": tokens,
     "mask": jnp.ones(tokens.shape, jnp.float32)},
)
print(f"[models]   {cfg.name}: loss={float(loss):.4f}")

# generation: prefill + decode with a KV cache
logits, caches = model.prefill(params, {"tokens": tokens}, max_len=24)
nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
logits, caches = model.decode_step(
    params, nxt, jnp.full((2, 1), 16, jnp.int32), caches)
print(f"[serving]  decoded next tokens: {np.asarray(jnp.argmax(logits, -1))}")

# -------------------------------------------------------------- runtime --
# The paper's pipeline behind one call: register heterogeneous units, then
# HeteroRuntime.parallel_for runs the iteration space under a pluggable
# scheduling policy (multidynamic / static / oracle) and completion engine
# (interrupt / polling / inline).  Real execution uses per-unit work_fns:
import time

rt = HeteroRuntime()
for i in range(2):
    rt.register_unit(f"acc{i}", WorkerKind.ACC,
                     work_fn=lambda c: time.sleep(c.size / 8e4))
    rt.register_unit(f"cc{i}", WorkerKind.CC,
                     work_fn=lambda c: time.sleep(c.size / 1e4))
report = rt.parallel_for(num_items=400, policy="multidynamic",
                         engine="interrupt", acc_chunk=64)
print(f"[eneac]    {report.items} items, split={report.per_worker_items}, "
      f"load-balance={report.load_balance:.2f}")

# Under SimulatedClock the same run is virtual-time: unit `speed` priors
# (items/s) replace work_fns, nothing sleeps, and makespan / utilization /
# coverage are exactly reproducible — Table-1 ablations in microseconds.
sim = HeteroRuntime(clock=SimulatedClock())
for i in range(2):
    sim.register_unit(f"acc{i}", WorkerKind.ACC, speed=8e4)
    sim.register_unit(f"cc{i}", WorkerKind.CC, speed=1e4)
vrep = sim.parallel_for(num_items=4000, policy="multidynamic",
                        engine="interrupt", acc_chunk=256)
util = {k: f"{v:.2f}" for k, v in vrep.utilization.items()}
print(f"[virtual]  makespan={vrep.makespan * 1e3:.2f}ms (virtual), "
      f"utilization={util}")

# --------------------------------------------------------------- spaces --
# parallel_for iterates an IterationSpace.  num_items=N is sugar for
# FlatSpace(N); a ShardedSpace splits the global space across host shards
# (one scheduler/engine per shard, merged report); a TiledSpace hands the
# scheduler 2D kernel tiles (hotspot stencils, block-ELL SPMM rows).
srep = sim.parallel_for(space=ShardedSpace(8000, num_shards=2),
                        policy="multidynamic", engine="interrupt",
                        acc_chunk=256)
print(f"[sharded]  {srep.num_shards} shards, items={srep.items}, "
      f"cross-shard balance={srep.cross_shard_balance:.3f}")

tiles = TiledSpace(grid=(512, 512), tile=(128, 128))   # 4x4 = 16 tiles
trep = sim.parallel_for(space=tiles, policy="multidynamic",
                        engine="interrupt", acc_chunk=4)
print(f"[tiled]    {tiles.describe()}: {trep.items} tiles scheduled")

# -------------------------------------------------------------- elastic --
# Units may join/leave mid-run (SimulatedClock): a departing unit's
# in-flight chunk is requeued to the survivors, a joining unit starts
# stealing immediately, and the events land in RunReport.events.
events = ElasticSchedule().leave(0.01, "cc0").join(0.015, "cc2", kind="cc",
                                                   speed=2e4)
erep = sim.parallel_for(num_items=4000, policy="multidynamic",
                        engine="interrupt", acc_chunk=256, elastic=events)
print(f"[elastic]  coverage intact={erep.coverage[0][0] == 0 and erep.coverage[-1][1] == 4000}, "
      f"events={[(e['action'], e['unit']) for e in erep.events]}")

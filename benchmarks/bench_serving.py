"""Serving benchmark: continuous vs static batching (the serving face of
the paper's interrupt-vs-polling comparison) on identical request sets."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from repro.configs import get_config
from repro.models import make_model
from repro.serving import Request, ServingEngine


def serving_rows(
    *, quick: bool = False, backend: str = "inline", workers: int = 1
) -> List[Tuple[str, float, str]]:
    config_name, seed = "tinyllama-1.1b", 0
    cfg = get_config(config_name).smoke()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    n_req = 12 if quick else 24
    rng = np.random.default_rng(0)
    protos = [
        (rng.integers(0, cfg.vocab_size, int(rng.integers(2, 10))).astype(np.int32),
         int(rng.integers(2, 24)))
        for _ in range(n_req)
    ]
    # --backend remote: prefill admission runs in worker subprocesses —
    # they rebuild the model from (config, smoke, seed), so results are
    # identical; the transport cost shows up in prefill_disp_us.
    handles: List = []
    model_spec = None
    engine_backend = backend
    if backend == "remote":
        from repro.core.transport import spawn_worker

        handles = [spawn_worker() for _ in range(max(workers, 1))]
        engine_backend = "remote:" + ",".join(h.address for h in handles)
        model_spec = {"config": config_name, "smoke": True, "seed": seed}
    rows = []
    suffix = f"_{backend}" if backend != "inline" else ""
    try:
        for mode in ("static", "continuous"):
            rows.append(_run_mode(model, params, protos, mode, suffix,
                                  engine_backend, model_spec))
    finally:
        for h in handles:
            h.terminate()
    return rows


def _run_mode(model, params, protos, mode, suffix, engine_backend,
              model_spec) -> Tuple[str, float, str]:
    eng = ServingEngine(model, params, slots=4, max_len=96, mode=mode,
                        backend=engine_backend, model_spec=model_spec)
    for i, (prompt, mx) in enumerate(protos):
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=mx))
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    rep = eng.throughput_report()
    # per-slot coverage/utilization from the runtime's RunReport of the
    # final batch (the ROADMAP's last_run_report exposure)
    run_rep = eng.last_run_report
    slot_cols = ""
    if run_rep is not None:
        utils = run_rep.utilization.values()
        slot_cols = (
            f";load_balance={run_rep.load_balance:.3f}"
            f";slot_util_mean={sum(utils) / len(utils):.3f}"
            f";slot_items={'/'.join(str(v) for v in run_rep.per_worker_items.values())}"
        )
        if run_rep.dispatch_latency:
            disp = run_rep.dispatch_latency.values()
            slot_cols += (
                f";prefill_disp_us={sum(disp) / len(disp) * 1e6:.1f}"
            )
    return (
        f"serving_{mode}{suffix}",
        wall / max(rep["steps"], 1) * 1e6,
        f"us_per_step;tok_per_step={rep['tokens_per_step']:.3f};"
        f"steps={rep['steps']};tokens={rep['tokens']}" + slot_cols,
    )


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-scale)")
    ap.add_argument("--backend", default="inline",
                    choices=["inline", "threads", "remote"],
                    help="prefill admission path: synchronous (inline), "
                         "per-slot ThreadUnits (async prefill overlapping "
                         "the decode loop), or per-slot RemoteUnits "
                         "prefilling in spawned worker subprocesses over "
                         "SocketTransport")
    ap.add_argument("--workers", type=int, default=1,
                    help="worker subprocesses for --backend remote")
    args = ap.parse_args()
    print("name,us_per_step,derived")
    for name, us, derived in serving_rows(quick=args.quick,
                                          backend=args.backend,
                                          workers=args.workers):
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()

"""Serving benchmark: continuous vs static batching (the serving face of
the paper's interrupt-vs-polling comparison) on identical request sets,
plus the open-loop loadgen sweep that commits ``BENCH_serving.json`` —
admission policies x refill modes on a seeded Zipf/Poisson trace with
p50/p95/p99 latency, TTFT, and goodput per configuration."""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import get_config
from repro.models import make_model
from repro.serving import LoadgenScenario, Request, ServingEngine
from repro.serving.loadgen import make_trace, run_trace

BENCH_SCHEMA = "bench_serving/v1"


def _build_model(config_name: str = "tinyllama-1.1b", seed: int = 0):
    cfg = get_config(config_name).smoke()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


# ---------------------------------------------------------------------------
# classic closed-batch comparison (CSV rows, kept from earlier PRs)
# ---------------------------------------------------------------------------
def serving_rows(
    *, quick: bool = False, backend: str = "inline", workers: int = 1
) -> List[Tuple[str, float, str]]:
    config_name, seed = "tinyllama-1.1b", 0
    cfg, model, params = _build_model(config_name, seed)
    n_req = 12 if quick else 24
    rng = np.random.default_rng(0)
    protos = [
        (rng.integers(0, cfg.vocab_size, int(rng.integers(2, 10))).astype(np.int32),
         int(rng.integers(2, 24)))
        for _ in range(n_req)
    ]
    # --backend remote: prefill admission runs in worker subprocesses —
    # they rebuild the model from (config, smoke, seed), so results are
    # identical; the transport cost shows up in prefill_disp_us.
    handles: List = []
    model_spec = None
    engine_backend = backend
    if backend == "remote":
        from repro.core.transport import spawn_worker

        handles = [spawn_worker() for _ in range(max(workers, 1))]
        engine_backend = "remote:" + ",".join(h.address for h in handles)
        model_spec = {"config": config_name, "smoke": True, "seed": seed}
    rows = []
    suffix = f"_{backend}" if backend != "inline" else ""
    try:
        for mode in ("static", "continuous"):
            rows.append(_run_mode(model, params, protos, mode, suffix,
                                  engine_backend, model_spec))
    finally:
        for h in handles:
            h.terminate()
    return rows


def _run_mode(model, params, protos, mode, suffix, engine_backend,
              model_spec) -> Tuple[str, float, str]:
    eng = ServingEngine(model, params, slots=4, max_len=96, mode=mode,
                        backend=engine_backend, model_spec=model_spec)
    for i, (prompt, mx) in enumerate(protos):
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=mx))
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    rep = eng.throughput_report()
    # per-slot coverage/utilization from the runtime's RunReport of the
    # final batch (the ROADMAP's last_run_report exposure)
    run_rep = eng.last_run_report
    slot_cols = ""
    if run_rep is not None:
        utils = run_rep.utilization.values()
        slot_cols = (
            f";load_balance={run_rep.load_balance:.3f}"
            f";slot_util_mean={sum(utils) / len(utils):.3f}"
            f";slot_items={'/'.join(str(v) for v in run_rep.per_worker_items.values())}"
        )
        if run_rep.dispatch_latency:
            disp = run_rep.dispatch_latency.values()
            slot_cols += (
                f";prefill_disp_us={sum(disp) / len(disp) * 1e6:.1f}"
            )
    return (
        f"serving_{mode}{suffix}",
        wall / max(rep["steps"], 1) * 1e6,
        f"us_per_step;tok_per_step={rep['tokens_per_step']:.3f};"
        f"steps={rep['steps']};tokens={rep['tokens']}" + slot_cols,
    )


# ---------------------------------------------------------------------------
# open-loop loadgen sweep -> BENCH_serving.json
# ---------------------------------------------------------------------------
def mixed_scenario(*, quick: bool = False, vocab_size: int,
                   seed: int = 0) -> LoadgenScenario:
    """The mixed-length Zipf/Poisson scenario the acceptance gate pins:
    short prompts with a wide Zipf generation-length spread (8-96
    tokens), Poisson arrivals fast enough to saturate the 4 decode
    slots, and per-request SLOs loose enough that misses measure
    scheduling (batch stragglers holding short requests hostage), not
    model compile noise.  At this operating point static batching
    strands capacity behind its longest in-flight request while
    continuous refill backfills freed slots — the paper's
    interrupt-beats-polling claim at the serving tier."""
    return LoadgenScenario(
        name="mixed-zipf-poisson",
        seed=seed,
        n=12 if quick else 32,
        rate=10.0,
        arrival="poisson",
        prompt_lens=(2, 12),
        gen_lens=(8, 48) if quick else (8, 96),
        zipf_a=1.4,
        vocab_size=vocab_size,
        deadline_base=1.5,
        deadline_per_token=0.08,
    )


def loadgen_sweep(
    *,
    quick: bool = False,
    policies: Tuple[str, ...] = ("fifo", "cost"),
    modes: Tuple[str, ...] = ("static", "continuous"),
    backends: Tuple[str, ...] = ("inline",),
    slots: int = 4,
    max_len: int = 128,
    seed: int = 0,
    repeats: int = 1,
) -> Dict:
    """Run the policy x mode x backend sweep on one seeded trace.

    Every configuration replays the *same* scenario (fresh Request
    objects per run — the engine stamps them).  A warmup pass first
    drives the whole trace through a throwaway engine so jit compilation
    of every prompt-length variant is paid before anything is timed.
    With ``repeats > 1`` each configuration runs that many times and the
    reported entry is the run with median goodput — wall-clock noise on
    a loaded host is the dominant error source, and a median run keeps
    the metrics internally consistent (unlike element-wise medians).
    """
    config_name = "tinyllama-1.1b"
    cfg, model, params = _build_model(config_name, seed)
    scenario = mixed_scenario(quick=quick, vocab_size=cfg.vocab_size,
                              seed=seed)

    warm = ServingEngine(model, params, slots=slots, max_len=max_len)
    run_trace(warm, make_trace(scenario), time_scale=0.0)

    entries = []
    for backend in backends:
        for policy in policies:
            for mode in modes:
                runs = []
                for _ in range(max(repeats, 1)):
                    eng = ServingEngine(
                        model, params, slots=slots, max_len=max_len,
                        mode=mode, policy=policy, backend=backend,
                        seed=seed,
                    )
                    runs.append(run_trace(eng, make_trace(scenario)))
                runs.sort(key=lambda m: m["goodput_tokens_per_s"])
                metrics = runs[len(runs) // 2]
                entries.append({
                    "policy": policy,
                    "mode": mode,
                    "backend": backend,
                    "repeats": len(runs),
                    "metrics": metrics,
                })
                print(f"  {policy}/{mode}/{backend}: "
                      f"p50={metrics['p50_latency_s']:.3f}s "
                      f"p99={metrics['p99_latency_s']:.3f}s "
                      f"ttft={metrics['mean_ttft_s']:.3f}s "
                      f"goodput={metrics['goodput_tokens_per_s']:.1f}tok/s "
                      f"hit={metrics['deadline_hit_rate']:.2f}")
    return {
        "schema": BENCH_SCHEMA,
        "scenario": scenario.describe(),
        "engine": {
            "model": config_name, "smoke": True, "slots": slots,
            "max_len": max_len, "temperature": 0.0, "seed": seed,
        },
        "configs": entries,
    }


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-scale)")
    ap.add_argument("--backend", default="inline",
                    choices=["inline", "threads", "remote"],
                    help="prefill admission path: synchronous (inline), "
                         "per-slot ThreadUnits (async prefill overlapping "
                         "the decode loop), or per-slot RemoteUnits "
                         "prefilling in spawned worker subprocesses over "
                         "SocketTransport")
    ap.add_argument("--workers", type=int, default=1,
                    help="worker subprocesses for --backend remote")
    ap.add_argument("--loadgen", action="store_true",
                    help="run the open-loop admission-policy sweep "
                         "(policies x modes x backends on a seeded "
                         "Zipf/Poisson trace) instead of the closed-batch "
                         "CSV comparison")
    ap.add_argument("--policies", default="fifo,cost",
                    help="comma list for --loadgen (fifo,priority,"
                         "deadline,cost)")
    ap.add_argument("--backends", default="inline",
                    help="comma list for --loadgen (inline,threads)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the --loadgen result as JSON "
                         "(the BENCH_serving.json artifact)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="runs per --loadgen config, median reported "
                         "(default: 1 with --quick, 3 otherwise)")
    args = ap.parse_args()
    if args.loadgen:
        result = loadgen_sweep(
            quick=args.quick,
            policies=tuple(args.policies.split(",")),
            backends=tuple(args.backends.split(",")),
            repeats=args.repeats or (1 if args.quick else 3),
        )
        if args.json:
            with open(args.json, "w") as fh:
                json.dump(result, fh, indent=2, sort_keys=True)
                fh.write("\n")
            print(f"wrote {args.json}")
        return
    print("name,us_per_step,derived")
    for name, us, derived in serving_rows(quick=args.quick,
                                          backend=args.backend,
                                          workers=args.workers):
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()

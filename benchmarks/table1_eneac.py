"""Table-1 reproduction: 7 platform configurations × {HOTSPOT, SPMM}.

Methodology (calibrated simulation — documented in EXPERIMENTS.md §Table1):
this container has ONE CPU core, so the 4CC+4ACC concurrency cannot be
timed directly.  Instead we (a) MEASURE the real per-item cost of every
execution path from its actual jit-compiled implementation (the CC gather
path, the ACC dense path, and the HP-port penalty from the extra shifted
-copy buffers the HP hotspot kernel performs), then (b) replay those costs
through the REAL schedulers/engines (MultiDynamicScheduler + AsyncEngine /
PollingEngine) with sleep-calibrated workers, so all queueing, chunk
adaptation, and completion-driven dynamics are genuine.  Throughput is
reported in the paper's units (compute objects per ms).

Config IDs follow the paper:
  (1) 4CC   (2) 4HPACC   (3) 4HPCACC   (4) 4CC+4HPACC   (5) +INT
  (6) 4CC+4HPCACC        (7) +INT
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_eneac import HotspotConfig, SpmmConfig, TABLE1_CONFIGS
from repro.core import CostModel, HeteroRuntime, ShardedSpace, SimulatedClock, WorkerKind
from repro.core.interrupts import RunReport
from repro.kernels.hotspot.ref import hotspot_step_ref
from repro.kernels.spmm.ref import make_problem, spmm_ell_ref, to_block_ell
from repro.kernels.spmm.ops import pad_rhs

N_CC = 4
N_ACC = 4


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


# ---------------------------------------------------------------------------
# calibration: measured per-item (per-row) costs of each real path
# ---------------------------------------------------------------------------
def calibrate_hotspot(grid: int = 512) -> Dict[str, float]:
    cfg = HotspotConfig(grid=grid, iterations=grid)
    key = jax.random.PRNGKey(0)
    t = 80.0 + 10 * jax.random.uniform(key, (grid, grid))
    p = jax.random.uniform(jax.random.PRNGKey(1), (grid, grid))

    # ACC/HPC analogue: whole-grid fused step (working set stays local)
    step_full = jax.jit(lambda t, p: hotspot_step_ref(t, p, cfg))
    t_acc = _time(step_full, t, p) / grid

    # HP analogue: the halo copies round-trip through memory as REAL
    # intermediate buffers (two separate executables, so XLA cannot fuse
    # them away) — mirroring the paper's software buffer copies between
    # cacheable and non-cacheable memory on the HP port path.
    shift = jax.jit(lambda t: (
        jnp.concatenate([t[:1], t[:-1]], 0),
        jnp.concatenate([t[1:], t[-1:]], 0),
    ))

    from repro.kernels.hotspot.ref import hotspot_coefficients
    cap, rx, ry, rz, dt = hotspot_coefficients(cfg, grid, grid)

    @jax.jit
    def step_with_halo(t, up, down, p):
        left = jnp.concatenate([t[:, :1], t[:, :-1]], 1)
        right = jnp.concatenate([t[:, 1:], t[:, -1:]], 1)
        return t + (dt / cap) * (p + (left + right - 2 * t) / rx
                                 + (up + down - 2 * t) / ry
                                 + (cfg.amb_temp - t) / rz)

    def hp_step(t, p):
        up, down = shift(t)
        return step_with_halo(t, up, down, p)

    t_acc_hp = _time(hp_step, t, p) / grid
    t_acc_hp = max(t_acc_hp, t_acc * 1.05)  # copies can never be free

    # CC analogue: row-banded execution (one band per chunk, touched row-wise)
    band = 32
    step_band = jax.jit(
        lambda tb, pb: hotspot_step_ref(tb, pb, cfg))
    tb = t[: band + 2]
    pb = p[: band + 2]
    t_cc = _time(step_band, tb, pb) / band * 3.0  # scalar-path penalty vs fused

    return {"cc": t_cc, "acc_hpc": t_acc, "acc_hp": t_acc_hp, "items": grid}


def calibrate_spmm(rows: int = 4096, cols: int = 4096, n: int = 128) -> Dict[str, float]:
    p = make_problem(rows, cols, n, nnz_mean=16.0, nnz_sigma=1.0, seed=0)
    vals, colix, rhs = jnp.asarray(p.vals), jnp.asarray(p.cols), jnp.asarray(p.rhs)

    # CC path: the real row-gather implementation
    gather = jax.jit(spmm_ell_ref)
    t_cc = _time(gather, vals, colix, rhs) / rows

    # ACC path: block-ELL dense-tile compute (jnp analogue of the MXU kernel:
    # batched (8,128)·(128,N) matmuls over occupied blocks)
    be = to_block_ell(p)
    bvals = jnp.asarray(be.vals)
    bcols = jnp.asarray(be.colblocks)
    rhs_pad = jnp.asarray(pad_rhs(p))

    @jax.jit
    def block_path(bvals, bcols, rhs_pad):
        nrb, K, RB, CB = bvals.shape
        b_blocks = rhs_pad.reshape(-1, CB, rhs_pad.shape[1])[bcols]  # (nrb,K,CB,N)
        return jnp.einsum("rkac,rkcn->ran", bvals, b_blocks)

    t_acc = _time(block_path, bvals, bcols, rhs_pad) / rows
    # HP penalty: measured on the hotspot pair (same port mechanics);
    # applied as a multiplier to the ACC rate
    return {"cc": t_cc, "acc_hpc": t_acc, "items": rows}


# ---------------------------------------------------------------------------
# simulation: real schedulers + sleep-calibrated workers
# ---------------------------------------------------------------------------
# SleepWork lives in repro.core.transport: work functions cross the remote
# backend's pickling transport by module reference, so they cannot be
# defined in this script's __main__.
from repro.core.transport import SleepWork  # noqa: E402


def run_config(
    units: str, port: str, interrupts: bool,
    *, n_items: int, acc_chunk: int, t_cc: float, t_acc: float,
    hp_penalty: float, time_scale: float = 1.0, shards: int = 1,
    backend: str = "threads", worker_addrs: List[str] = (),
    policy: str = "multidynamic",
) -> Tuple[float, RunReport]:
    """Returns (throughput in items/ms — paper units, the full RunReport).

    ``shards > 1`` iterates a :class:`ShardedSpace` instead of the flat
    range: each shard gets its own replica of the unit set and its own
    scheduler/engine (concurrent host threads), modelling one SoC per
    shard over a slice of the global space.

    ``backend`` selects where interrupt-engine chunks execute:
    ``"threads"`` (dedicated worker thread per unit — real overlap, the
    default), ``"inline"`` (serial execution on the dispatcher — the
    no-overlap control, isolating pure dispatch overhead), or
    ``"remote"`` (each unit proxies to a worker *subprocess* over a
    SocketTransport — ``worker_addrs`` assigns units to the spawned
    workers round-robin, and the summary's ``wire_us`` column becomes
    the measured wire + remote-queue share of dispatch latency).

    ``policy="learned"`` attaches a fresh :class:`CostModel` and runs one
    untimed warmup pass first (the adaptive cold-start that trains the
    model), then times the measured-split run — the online analogue of
    the oracle policy, with no registered speeds consulted.
    """
    if backend == "remote" and not worker_addrs:
        raise ValueError("backend='remote' needs worker_addrs")
    if backend == "remote" and shards > 1:
        raise ValueError(
            "remote units are one-host resources: combining --shards with "
            "--backend remote needs explicit ShardedSpace placement, which "
            "this benchmark does not model"
        )
    rt = HeteroRuntime(cost_model=CostModel() if policy == "learned" else None)
    registered = 0

    def register(name, kind, t_item):
        nonlocal registered
        spec = (f"remote:{worker_addrs[registered % len(worker_addrs)]}"
                if backend == "remote" else backend)
        rt.register_unit(name, kind, work_fn=SleepWork(t_item * time_scale),
                         backend=spec)
        registered += 1

    if units in ("acc", "hybrid"):
        t = t_acc * (hp_penalty if port == "hp" else 1.0)
        for i in range(N_ACC):
            register(f"acc{i}", WorkerKind.ACC, t)
    if units in ("cc", "hybrid"):
        for i in range(N_CC):
            register(f"cc{i}", WorkerKind.CC, t_cc)

    # Inter.=No configs poll their accelerators (the paper's host thread
    # burns cycles checking completion); CC-only has nothing to poll — the
    # host threads ARE the compute units.
    engine = "interrupt" if (interrupts or units == "cc") else "polling"
    space = ShardedSpace(n_items, shards) if shards > 1 else None
    if policy == "learned":
        # warmup: adaptive cold-start run that trains the cost model;
        # only the second (measured-split) run is timed
        rt.parallel_for(
            num_items=0 if space is not None else n_items,
            space=ShardedSpace(n_items, shards) if shards > 1 else None,
            policy="learned", engine=engine, acc_chunk=acc_chunk,
        )
    rep = rt.parallel_for(
        num_items=0 if space is not None else n_items, space=space,
        policy=policy, engine=engine, acc_chunk=acc_chunk,
    )
    return rep.items / (rep.wall_time / time_scale) / 1e3, rep


def report_columns(rep: RunReport) -> Tuple[float, float, float, float, float]:
    """(load_balance, util_mean, util_min, disp_us, wire_us) — the summary.

    ``disp_us`` is the mean backend dispatch latency across units in
    microseconds (0 when the run had no backend layer, e.g. polling);
    ``wire_us`` is its wire + remote-queue component, nonzero only when
    units executed behind a transport (``--backend remote``).
    """
    utils = list(rep.utilization.values())
    disp = list((rep.dispatch_latency or {}).values())
    disp_us = (sum(disp) / len(disp) * 1e6) if disp else 0.0
    wire = list((rep.wire_latency or {}).values())
    wire_us = (sum(wire) / len(wire) * 1e6) if wire else 0.0
    return (rep.load_balance, sum(utils) / len(utils), min(utils), disp_us,
            wire_us)


def table1(
    benchmark: str, *, quick: bool = False, shards: int = 1,
    backend: str = "threads", workers: int = 2,
    policy: str = "multidynamic",
) -> List[Tuple[str, float, str, float, float, float, float, float]]:
    if benchmark == "hotspot":
        cal = calibrate_hotspot(256 if quick else 512)
        n_items, acc_chunk = cal["items"], (64 if quick else 128)
        hp_penalty = cal["acc_hp"] / cal["acc_hpc"]
        t_cc, t_acc = cal["cc"], cal["acc_hpc"]
    else:
        cal = calibrate_spmm(2048 if quick else 4096)
        n_items, acc_chunk = cal["items"], (256 if quick else 512)
        hot = calibrate_hotspot(256)
        hp_penalty = hot["acc_hp"] / hot["acc_hpc"]
        t_cc, t_acc = cal["cc"], cal["acc_hpc"]

    # normalize the simulated CC-only runtime to a fixed budget so sleep
    # durations dwarf thread/scheduler overhead (per-chunk sleeps of
    # milliseconds, not microseconds); throughputs are converted back.
    target_s = 1.0 if quick else 2.5
    time_scale = target_s / (n_items * t_cc)
    rows = []
    suffix = f"_x{shards}shards" if shards > 1 else ""
    if backend == "remote":
        suffix += "_remote"
    if policy != "multidynamic":
        suffix += f"_{policy}"
    handles, addrs = _spawn_remote_workers(backend, workers)
    try:
        for cid, label, units, port, interrupts in TABLE1_CONFIGS:
            thr, rep = run_config(
                units, port or "hpc", interrupts,
                n_items=n_items, acc_chunk=acc_chunk,
                t_cc=t_cc, t_acc=t_acc, hp_penalty=hp_penalty,
                time_scale=time_scale, shards=shards, backend=backend,
                worker_addrs=addrs, policy=policy,
            )
            lb, u_mean, u_min, disp_us, wire_us = report_columns(rep)
            rows.append((f"table1_{benchmark}_{cid}_{label}{suffix}", thr,
                         "items_per_ms", lb, u_mean, u_min, disp_us, wire_us))
    finally:
        for h in handles:
            h.terminate()
    return rows


def _spawn_remote_workers(backend: str, workers: int):
    """(handles, addresses): worker subprocesses for ``backend='remote'``."""
    if backend != "remote":
        return [], []
    from repro.core.transport import spawn_worker

    handles = [spawn_worker() for _ in range(max(workers, 1))]
    return handles, [h.address for h in handles]


def chunk_sweep(benchmark: str = "hotspot", *, quick: bool = False,
                backend: str = "threads", workers: int = 2):
    """Fig-4 reproduction: hybrid(+INT) throughput vs ACC chunk size —
    exhibits the paper's cliff when one chunk exceeds ~1/4 of the space."""
    cal = calibrate_hotspot(256 if quick else 512)
    n_items = cal["items"]
    hp_penalty = cal["acc_hp"] / cal["acc_hpc"]
    time_scale = (1.0 if quick else 2.5) / (n_items * cal["cc"])
    rows = []
    sweep = sorted({16, 32, 64, 128, 256, n_items // 4, n_items // 2})
    handles, addrs = _spawn_remote_workers(backend, workers)
    try:
        for chunk in sweep:
            thr, rep = run_config(
                "hybrid", "hpc", True, n_items=n_items, acc_chunk=chunk,
                t_cc=cal["cc"], t_acc=cal["acc_hpc"], hp_penalty=hp_penalty,
                time_scale=time_scale, backend=backend, worker_addrs=addrs,
            )
            lb, u_mean, u_min, disp_us, wire_us = report_columns(rep)
            rows.append((f"chunksweep_{benchmark}_c{chunk}", thr,
                         "items_per_ms", lb, u_mean, u_min, disp_us, wire_us))
    finally:
        for h in handles:
            h.terminate()
    return rows


def costmodel_bench(
    *, seeds: int = 32, n_items: int = 4096, acc_chunk: int = 64,
    n_units: int = 4, base_seed: int = 0,
) -> Dict:
    """Seeded learned-vs-oracle convergence sweep → ``bench_costmodel/v1``.

    Per seed: randomized heterogeneous unit speeds under a
    :class:`SimulatedClock` (fully deterministic — no sleeps, no jax), a
    cold ``policy="learned"`` warmup run that trains a fresh
    :class:`CostModel`, then a timed learned run against the oracle
    split from the true registered speeds.  The committed artifact's
    per-seed ``gap`` (learned/oracle makespan − 1) is the acceptance
    number ``tools/check_bench.py`` enforces at ≤ 10% in CI.
    """
    import random

    configs = []
    for s in range(seeds):
        rng = random.Random(base_seed + s)
        model = CostModel()
        rt = HeteroRuntime(clock=SimulatedClock(), cost_model=model)
        speeds = {}
        for i in range(n_units):
            acc = i < max(1, n_units // 2)
            name = f"{'acc' if acc else 'cc'}{i}"
            speed = (rng.uniform(40.0, 400.0) if acc
                     else rng.uniform(5.0, 50.0))
            rt.register_unit(name, WorkerKind.ACC if acc else WorkerKind.CC,
                             speed=speed)
            speeds[name] = speed
        warm = rt.parallel_for(num_items=n_items, policy="learned",
                               acc_chunk=acc_chunk)
        learned = rt.parallel_for(num_items=n_items, policy="learned",
                                  acc_chunk=acc_chunk)
        oracle = rt.parallel_for(num_items=n_items, policy="oracle",
                                 acc_chunk=acc_chunk)
        gap = learned.makespan / oracle.makespan - 1.0
        configs.append({
            "seed": base_seed + s,
            "units": {k: round(v, 4) for k, v in speeds.items()},
            "warmup_makespan": warm.makespan,
            "learned_makespan": learned.makespan,
            "oracle_makespan": oracle.makespan,
            "learned_chunks": learned.chunks,
            "gap": gap,
        })
    gaps = [c["gap"] for c in configs]
    return {
        "schema": "bench_costmodel/v1",
        "params": {"seeds": seeds, "n_items": n_items,
                   "acc_chunk": acc_chunk, "n_units": n_units,
                   "base_seed": base_seed},
        "configs": configs,
        "max_gap": max(gaps),
        "mean_gap": sum(gaps) / len(gaps),
    }


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="reduced sizes (CI-scale)")
    ap.add_argument("--benchmarks", nargs="+", default=["hotspot", "spmm"],
                    choices=["hotspot", "spmm"])
    ap.add_argument("--shards", type=int, default=1,
                    help="host shards: each runs its own scheduler/engine "
                         "over a slice of the space (ShardedSpace)")
    ap.add_argument("--backend", default="threads",
                    choices=["threads", "inline", "remote"],
                    help="backend units for interrupt-engine configs: "
                         "dedicated worker threads (real overlap), inline "
                         "serial execution (dispatch-overhead control), or "
                         "remote worker subprocesses over SocketTransport "
                         "(multi-host dispatch; adds the wire_us column's "
                         "measured wire latency)")
    ap.add_argument("--workers", type=int, default=2,
                    help="worker subprocesses to spawn for --backend remote "
                         "(units are assigned round-robin)")
    ap.add_argument("--policy", default="multidynamic",
                    choices=["multidynamic", "learned"],
                    help="chunking policy for the table runs; 'learned' "
                         "trains a CostModel on one untimed warmup pass "
                         "and times the measured pre-split run")
    ap.add_argument("--costmodel", action="store_true",
                    help="run the seeded learned-vs-oracle convergence "
                         "sweep instead of the table (SimulatedClock; "
                         "emits a bench_costmodel/v1 JSON artifact)")
    ap.add_argument("--seeds", type=int, default=32,
                    help="seed count for --costmodel")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="with --costmodel: write the artifact here "
                         "(default: stdout)")
    args = ap.parse_args()
    if args.costmodel:
        doc = costmodel_bench(seeds=args.seeds,
                              n_items=2048 if args.quick else 4096)
        payload = json.dumps(doc, indent=1, sort_keys=True) + "\n"
        if args.json:
            with open(args.json, "w") as fh:
                fh.write(payload)
            print(f"costmodel: {len(doc['configs'])} seeds, "
                  f"max gap {doc['max_gap']:.4f} -> {args.json}")
        else:
            print(payload, end="")
        return
    print("name,throughput,unit,load_balance,util_mean,util_min,disp_us,"
          "wire_us")
    for bench in args.benchmarks:
        for (name, thr, unit, lb, u_mean, u_min, disp_us,
             wire_us) in table1(
            bench, quick=args.quick, shards=args.shards,
            backend=args.backend, workers=args.workers,
            policy=args.policy,
        ):
            print(f"{name},{thr:.3f},{unit},{lb:.3f},{u_mean:.3f},"
                  f"{u_min:.3f},{disp_us:.1f},{wire_us:.1f}")


if __name__ == "__main__":
    main()

"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:

  * table1_{hotspot,spmm}_*  — Table 1 reproduction (7 configs each)
  * chunksweep_*             — Fig. 4 chunk-size sweep (the >1/4 cliff)
  * serving_*                — continuous vs static batching (interrupt
                               analogue at the serving layer)
  * hotspot_/spmm_/flash_*   — kernel micro-benchmarks
  * roofline_*               — per-(arch × shape) three-term roofline from
                               the committed dry-run artifacts

``python -m benchmarks.run [--quick]``
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-scale)")
    ap.add_argument("--skip-table1", action="store_true")
    args = ap.parse_args()
    quick = args.quick

    rows = []

    from benchmarks.bench_kernels import kernel_rows
    rows += kernel_rows(quick=quick)

    from benchmarks.bench_serving import serving_rows
    rows += serving_rows(quick=quick)

    if not args.skip_table1:
        from benchmarks.table1_eneac import chunk_sweep, table1
        for bench in ("hotspot", "spmm"):
            t1 = table1(bench, quick=quick)
            rows += [(n, 1e3 / max(thr, 1e-9),
                      f"throughput={thr:.2f}items_per_ms;load_balance={lb:.2f}")
                     for n, thr, _, lb, *_rest in t1]
        rows += [(n, 1e3 / max(thr, 1e-9),
                  f"throughput={thr:.2f}items_per_ms;load_balance={lb:.2f}")
                 for n, thr, _, lb, *_rest in chunk_sweep(quick=quick)]

    from benchmarks.roofline import roofline_rows
    rows += roofline_rows()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()

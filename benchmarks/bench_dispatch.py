"""Dispatch fast-path microbenchmark (ISSUE 8): per-chunk dispatch cost
through a :class:`~repro.core.transport.RemoteUnit` with the fast-path
knobs toggled — session-cached work descriptors (``fn_cache``) and
chunk-batched frames (``batch_frames``) — against the PR-7 baseline
(inline fn pickling, one frame per chunk).

Three transports x three modes:

* transports: ``loopback`` (in-process queue pair), ``socket`` (real TCP
  through an in-process :class:`WorkerServer`), ``flaky`` (seeded
  drop/dup/reorder injection over loopback — the fast path must stay
  fast *and* correct when frames need retransmits);
* modes: ``baseline`` (fn_cache off, batch_frames=1 — the pre-fast-path
  wire protocol), ``cached`` (descriptor cache on, unbatched),
  ``batched`` (cache on, ``batch_frames`` chunks per frame).

The work function carries a ~4 KiB payload attribute so the baseline
pays the real per-frame descriptor pickling cost the cache elides.  Per
config we report median-of-repeats ``chunks_per_sec``, the amortized
``dispatch_us`` (wall clock per chunk — the number batching must lower),
the raw per-chunk ``submit_latency_us`` (which legitimately *rises*
under batching as chunks pipeline behind their batch siblings) and the
per-chunk ``wire_us`` attribution from the unit's latency ledgers, plus
a ``speedups`` block (batched-vs-baseline chunks_per_sec per transport).

On top of the 3x3 grid, the artifact carries a ``latency_aware`` block
(ISSUE 9) with two studies on a *flaky-delay* transport (seeded
per-frame delivery delay — a high-latency link, not just a lossy one):

* adaptive frame batching: ``batch_frames="auto"`` (width learned from
  frame transit vs. per-chunk service time) against the fixed
  ``batch_frames`` row on the same link; ``auto_ratio`` is the
  chunks/s ratio and must stay >= 1.0 (auto must find at least the
  hand-tuned width);
* latency-aware learned splits: a mixed local+high-latency-remote unit
  set driven through ``HeteroRuntime`` with a shared ``CostModel`` —
  after a learned warmup, the makespan of a *throughput-only*
  proportional pre-split vs. ``policy="learned"``'s latency-aware
  split (``makespan_ratio`` > 1.0 means the latency terms paid off).

    PYTHONPATH=src python benchmarks/bench_dispatch.py --json BENCH_dispatch.json
    PYTHONPATH=src python benchmarks/bench_dispatch.py --quick --json /tmp/smoke.json

``tools/check_bench.py --schema bench_dispatch/v2`` validates the
artifact; CI additionally gates the committed one on a >=2x socket
speedup plus the two latency-aware ratios (``--min-auto-ratio`` /
``--min-split-ratio``).
"""

from __future__ import annotations

import json
import statistics
import threading
import time
from typing import Dict, List, Optional

from repro.core.backends import CompletionBus
from repro.core.costmodel import CostModel
from repro.core.runtime import HeteroRuntime
from repro.core.scheduler import (
    Chunk,
    WorkerKind,
    latency_aware_split,
    proportional_split,
)
from repro.core.transport import (
    FlakyTransport,
    LoopbackTransport,
    RemoteUnit,
    RemoteWorker,
    SleepWork,
    WorkerServer,
)

BENCH_SCHEMA = "bench_dispatch/v2"

MODES = (
    # (mode, fn_cache, batched) — batch_frames filled in from params
    ("baseline", False, False),
    ("cached", True, False),
    ("batched", True, True),
)
TRANSPORTS = ("loopback", "socket", "flaky")


class DispatchWork:
    """Trivial per-chunk work with a deliberately chunky pickle.

    The payload models a real work descriptor (closure constants, kernel
    params): ~4 KiB that the baseline protocol re-pickles onto every
    frame and the descriptor cache ships exactly once per session.
    """

    def __init__(self, payload_bytes: int) -> None:
        self.payload = b"\x5a" * payload_bytes

    def __call__(self, chunk) -> int:
        return chunk.stop - chunk.start


def _make_unit(transport: str, name: str, *, mode_batch: int,
               fn_cache: bool, seed: int,
               server: Optional[WorkerServer]) -> RemoteUnit:
    if transport == "socket":
        assert server is not None
        return RemoteUnit(name, address=server.address,
                          batch_frames=mode_batch, fn_cache=fn_cache)
    client_end, worker_end = LoopbackTransport.pair()
    client_side, worker_side = client_end, worker_end
    if transport == "flaky":
        faults = dict(drop=0.05, duplicate=0.05, reorder=0.10)
        client_side = FlakyTransport(client_end, seed=seed, **faults)
        worker_side = FlakyTransport(worker_end, seed=seed + 1, **faults)
    worker = RemoteWorker(worker_side, poll_interval=0.05)
    import threading

    threading.Thread(target=worker.serve, daemon=True).start()
    return RemoteUnit(name, transport=client_side, retry_interval=0.02,
                      max_retries=600, batch_frames=mode_batch,
                      fn_cache=fn_cache)


def _drive(unit: RemoteUnit, n_chunks: int, work_fn) -> Dict[str, float]:
    """Pump ``n_chunks`` through the unit, windowed at its capacity."""
    bus = CompletionBus()
    unit.start(bus)
    try:
        issued = done = 0
        t0 = time.perf_counter()
        while done < n_chunks:
            while issued < n_chunks and issued - done < unit.capacity:
                unit.submit(Chunk(issued, issued + 1, unit.name), work_fn)
                issued += 1
            unit.flush()
            if not bus.wait(timeout=60.0):
                raise RuntimeError(f"unit {unit.name}: completions stalled "
                                   f"at {done}/{n_chunks}")
            for rec in bus.drain():
                if rec.error is not None:
                    raise rec.error
                done += 1
        wall = time.perf_counter() - t0
        final_width = unit.batch_frames
    finally:
        unit.close()
    return {
        "wall_s": wall,
        "chunks_per_sec": n_chunks / max(wall, 1e-12),
        # amortized cost of dispatching one chunk end-to-end — the number
        # batching must lower (per-chunk *latency* legitimately rises as
        # chunks pipeline behind batch siblings; that is submit_latency_us)
        "dispatch_us": 1e6 * wall / n_chunks,
        "submit_latency_us": 1e6 * statistics.fmean(unit.dispatch_latencies),
        "wire_us": 1e6 * statistics.fmean(unit.wire_latencies),
        "final_batch_frames": final_width,
    }


# ---------------------------------------------------------------------------
# latency-aware studies (flaky-delay transport)
# ---------------------------------------------------------------------------
def _delayed_loopback_unit(name: str, *, seed: int, max_delay: float,
                           batch_frames, retry_interval: float = 0.5,
                           fn_cache: bool = True) -> RemoteUnit:
    """Loopback unit behind a seeded high-latency link: every frame in
    both directions is delayed uniform(0, max_delay) seconds."""
    client_end, worker_end = LoopbackTransport.pair()
    client_side = FlakyTransport(client_end, seed=seed,
                                 delay=1.0, max_delay=max_delay)
    worker_side = FlakyTransport(worker_end, seed=seed + 1,
                                 delay=1.0, max_delay=max_delay)
    worker = RemoteWorker(worker_side, poll_interval=0.02)
    threading.Thread(target=worker.serve, daemon=True).start()
    return RemoteUnit(name, transport=client_side,
                      retry_interval=retry_interval, max_retries=200,
                      batch_frames=batch_frames, fn_cache=fn_cache)


def _auto_batch_study(*, n_chunks: int, repeats: int, batch_frames: int,
                      payload_bytes: int, max_delay: float, seed: int) -> dict:
    """Fixed ``batch_frames`` vs ``"auto"`` on the flaky-delay link."""
    entries = {}
    for mode, bf in (("batched", batch_frames), ("auto", "auto")):
        runs = []
        for r in range(repeats):
            unit = _delayed_loopback_unit(
                f"d{r}", seed=seed * 313 + r * 17 + 1, max_delay=max_delay,
                batch_frames=bf, retry_interval=0.5)
            runs.append(_drive(unit, n_chunks, DispatchWork(payload_bytes)))
        entry = {
            "transport": "flaky-delay", "mode": mode, "fn_cache": True,
            "batch_frames": bf, "n_chunks": n_chunks,
        }
        for key in ("wall_s", "chunks_per_sec", "dispatch_us",
                    "submit_latency_us", "wire_us"):
            entry[key] = statistics.median(r[key] for r in runs)
        entry["final_batch_frames"] = int(statistics.median(
            r["final_batch_frames"] for r in runs))
        entries[mode] = entry
        print(f"  {'fl-delay':8s} {mode:8s}  "
              f"{entry['chunks_per_sec']:10.0f} chunks/s  "
              f"dispatch {entry['dispatch_us']:8.1f}us  "
              f"width -> {entry['final_batch_frames']}")
    ratio = (entries["auto"]["chunks_per_sec"]
             / max(entries["batched"]["chunks_per_sec"], 1e-12))
    print(f"  flaky-delay auto/fixed chunks_per_sec ratio: {ratio:.2f}x")
    return {"fixed": entries["batched"], "auto": entries["auto"],
            "auto_ratio": ratio}


def _split_run(model: CostModel, *, policy, n_items: int, acc_chunk: int,
               per_item_s: float, max_delay: float, seed: int):
    """One wall-clock run over 2 local + 1 high-latency-remote unit.

    Transports are single-session, so every run builds a fresh runtime
    and remote unit; the shared ``model`` is the state that carries the
    learned speeds and latencies across runs (the runtime folds every
    finished report back in).
    """
    rt = HeteroRuntime(cost_model=model)
    work = SleepWork(per_item_s)
    rt.register_unit("loc0", WorkerKind.CC, work_fn=work)
    rt.register_unit("loc1", WorkerKind.CC, work_fn=work)
    rt.register_unit("rem0", WorkerKind.ACC, work_fn=work,
                     backend=_delayed_loopback_unit(
                         "rem0", seed=seed, max_delay=max_delay,
                         batch_frames=1))
    return rt.parallel_for(num_items=n_items, policy=policy,
                           acc_chunk=acc_chunk, kernel="latsplit")


def _split_study(*, n_items: int, repeats: int, warmups: int,
                 per_item_s: float, max_delay: float, seed: int) -> dict:
    """Throughput-only vs latency-aware learned splits, measured.

    The remote unit computes as fast as the locals but pays a learned
    ~``max_delay/2`` wire overhead per dispatch; equalizing predicted
    *completion* time hands it fewer items, so the latency-aware run's
    makespan must come in under the throughput-only pre-split's.
    """
    model = CostModel()
    names = ["loc0", "loc1", "rem0"]
    acc_chunk = max(16, n_items // 5)
    for w in range(warmups):
        _split_run(model, policy="learned", n_items=n_items,
                   acc_chunk=acc_chunk, per_item_s=per_item_s,
                   max_delay=max_delay, seed=seed * 977 + w * 29 + 3)
    speeds = model.speeds(names, "latsplit")
    overheads = model.overheads(names, "latsplit")
    t_only_sizes = proportional_split(n_items, {n: speeds[n] for n in names})
    lat_sizes = latency_aware_split(n_items, {n: speeds[n] for n in names},
                                    overheads)
    mapping, start = {}, 0
    for n in names:
        mapping[n] = (start, start + t_only_sizes[n])
        start += t_only_sizes[n]
    t_only_walls, lat_walls = [], []
    for r in range(repeats):
        rep_t = _split_run(model, policy=mapping, n_items=n_items,
                           acc_chunk=acc_chunk, per_item_s=per_item_s,
                           max_delay=max_delay, seed=seed * 601 + r * 41 + 7)
        rep_l = _split_run(model, policy="learned", n_items=n_items,
                           acc_chunk=acc_chunk, per_item_s=per_item_s,
                           max_delay=max_delay, seed=seed * 601 + r * 41 + 19)
        t_only_walls.append(rep_t.makespan)
        lat_walls.append(rep_l.makespan)
    t_only = statistics.median(t_only_walls)
    lat = statistics.median(lat_walls)
    ratio = t_only / max(lat, 1e-12)
    print(f"  split    t-only {1e3 * t_only:7.1f}ms  "
          f"latency-aware {1e3 * lat:7.1f}ms  ratio {ratio:.2f}x  "
          f"shares {t_only_sizes} -> {lat_sizes}")
    return {
        "n_items": n_items, "per_item_s": per_item_s,
        "speeds": speeds, "overheads": overheads,
        "throughput_only_split": t_only_sizes,
        "latency_aware_split": lat_sizes,
        "throughput_only_makespan_s": t_only,
        "latency_aware_makespan_s": lat,
        "makespan_ratio": ratio,
    }


def run(*, quick: bool = False, seed: int = 0,
        batch_frames: int = 8) -> dict:
    n_chunks = 96 if quick else 512
    repeats = 2 if quick else 5
    payload_bytes = 4096
    params = {
        "n_chunks": n_chunks, "repeats": repeats,
        "batch_frames": batch_frames, "payload_bytes": payload_bytes,
        "seed": seed, "quick": quick,
    }
    server = WorkerServer().start()
    configs: List[dict] = []
    try:
        for transport in TRANSPORTS:
            for mode, fn_cache, batched in MODES:
                bf = batch_frames if batched else 1
                runs = []
                for r in range(repeats):
                    work = DispatchWork(payload_bytes)
                    unit = _make_unit(
                        transport, f"{transport[0]}{r}", mode_batch=bf,
                        fn_cache=fn_cache,
                        seed=seed * 101 + r * 13 + 1, server=server)
                    runs.append(_drive(unit, n_chunks, work))
                entry = {
                    "transport": transport, "mode": mode,
                    "fn_cache": fn_cache, "batch_frames": bf,
                    "n_chunks": n_chunks,
                }
                for key in ("wall_s", "chunks_per_sec", "dispatch_us",
                            "submit_latency_us", "wire_us"):
                    entry[key] = statistics.median(r[key] for r in runs)
                configs.append(entry)
                print(f"  {transport:8s} {mode:8s}  "
                      f"{entry['chunks_per_sec']:10.0f} chunks/s  "
                      f"dispatch {entry['dispatch_us']:8.1f}us  "
                      f"wire {entry['wire_us']:8.1f}us")
    finally:
        server.stop()

    by_key = {(c["transport"], c["mode"]): c for c in configs}
    speedups = {
        t: (by_key[(t, "batched")]["chunks_per_sec"]
            / max(by_key[(t, "baseline")]["chunks_per_sec"], 1e-12))
        for t in TRANSPORTS
    }
    for t, s in speedups.items():
        print(f"  {t:8s} batched/baseline speedup: {s:.2f}x")

    # latency-aware studies: adaptive width and learned splits on a
    # high-latency (delayed, not just lossy) link
    delay_s = 0.004
    latency_aware = _auto_batch_study(
        n_chunks=n_chunks, repeats=repeats, batch_frames=batch_frames,
        payload_bytes=payload_bytes, max_delay=delay_s, seed=seed)
    latency_aware["transport"] = "flaky-delay"
    latency_aware["max_delay_s"] = delay_s
    latency_aware["split"] = _split_study(
        n_items=120 if quick else 240, repeats=2 if quick else 3,
        warmups=2, per_item_s=0.001, max_delay=0.08, seed=seed)

    return {"schema": BENCH_SCHEMA, "params": params, "configs": configs,
            "speedups": speedups, "latency_aware": latency_aware}


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small chunk count / fewer repeats (CI smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-frames", type=int, default=8,
                    help="frames coalesced per work_batch in batched mode")
    ap.add_argument("--json", metavar="PATH",
                    help="write the bench_dispatch/v2 artifact here")
    args = ap.parse_args()
    result = run(quick=args.quick, seed=args.seed,
                 batch_frames=args.batch_frames)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(result, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()

"""Dispatch fast-path microbenchmark (ISSUE 8): per-chunk dispatch cost
through a :class:`~repro.core.transport.RemoteUnit` with the fast-path
knobs toggled — session-cached work descriptors (``fn_cache``) and
chunk-batched frames (``batch_frames``) — against the PR-7 baseline
(inline fn pickling, one frame per chunk).

Three transports x three modes:

* transports: ``loopback`` (in-process queue pair), ``socket`` (real TCP
  through an in-process :class:`WorkerServer`), ``flaky`` (seeded
  drop/dup/reorder injection over loopback — the fast path must stay
  fast *and* correct when frames need retransmits);
* modes: ``baseline`` (fn_cache off, batch_frames=1 — the pre-fast-path
  wire protocol), ``cached`` (descriptor cache on, unbatched),
  ``batched`` (cache on, ``batch_frames`` chunks per frame).

The work function carries a ~4 KiB payload attribute so the baseline
pays the real per-frame descriptor pickling cost the cache elides.  Per
config we report median-of-repeats ``chunks_per_sec``, the amortized
``dispatch_us`` (wall clock per chunk — the number batching must lower),
the raw per-chunk ``submit_latency_us`` (which legitimately *rises*
under batching as chunks pipeline behind their batch siblings) and the
per-chunk ``wire_us`` attribution from the unit's latency ledgers, plus
a ``speedups`` block (batched-vs-baseline chunks_per_sec per transport).

    PYTHONPATH=src python benchmarks/bench_dispatch.py --json BENCH_dispatch.json
    PYTHONPATH=src python benchmarks/bench_dispatch.py --quick --json /tmp/smoke.json

``tools/check_bench.py --schema bench_dispatch/v1`` validates the
artifact; CI additionally gates the committed one on a >=2x socket
speedup (the ISSUE's acceptance line).
"""

from __future__ import annotations

import json
import statistics
import time
from typing import Dict, List, Optional

from repro.core.backends import CompletionBus
from repro.core.scheduler import Chunk
from repro.core.transport import (
    FlakyTransport,
    LoopbackTransport,
    RemoteUnit,
    RemoteWorker,
    WorkerServer,
)

BENCH_SCHEMA = "bench_dispatch/v1"

MODES = (
    # (mode, fn_cache, batched) — batch_frames filled in from params
    ("baseline", False, False),
    ("cached", True, False),
    ("batched", True, True),
)
TRANSPORTS = ("loopback", "socket", "flaky")


class DispatchWork:
    """Trivial per-chunk work with a deliberately chunky pickle.

    The payload models a real work descriptor (closure constants, kernel
    params): ~4 KiB that the baseline protocol re-pickles onto every
    frame and the descriptor cache ships exactly once per session.
    """

    def __init__(self, payload_bytes: int) -> None:
        self.payload = b"\x5a" * payload_bytes

    def __call__(self, chunk) -> int:
        return chunk.stop - chunk.start


def _make_unit(transport: str, name: str, *, mode_batch: int,
               fn_cache: bool, seed: int,
               server: Optional[WorkerServer]) -> RemoteUnit:
    if transport == "socket":
        assert server is not None
        return RemoteUnit(name, address=server.address,
                          batch_frames=mode_batch, fn_cache=fn_cache)
    client_end, worker_end = LoopbackTransport.pair()
    client_side, worker_side = client_end, worker_end
    if transport == "flaky":
        faults = dict(drop=0.05, duplicate=0.05, reorder=0.10)
        client_side = FlakyTransport(client_end, seed=seed, **faults)
        worker_side = FlakyTransport(worker_end, seed=seed + 1, **faults)
    worker = RemoteWorker(worker_side, poll_interval=0.05)
    import threading

    threading.Thread(target=worker.serve, daemon=True).start()
    return RemoteUnit(name, transport=client_side, retry_interval=0.02,
                      max_retries=600, batch_frames=mode_batch,
                      fn_cache=fn_cache)


def _drive(unit: RemoteUnit, n_chunks: int, work_fn) -> Dict[str, float]:
    """Pump ``n_chunks`` through the unit, windowed at its capacity."""
    bus = CompletionBus()
    unit.start(bus)
    try:
        issued = done = 0
        t0 = time.perf_counter()
        while done < n_chunks:
            while issued < n_chunks and issued - done < unit.capacity:
                unit.submit(Chunk(issued, issued + 1, unit.name), work_fn)
                issued += 1
            unit.flush()
            if not bus.wait(timeout=60.0):
                raise RuntimeError(f"unit {unit.name}: completions stalled "
                                   f"at {done}/{n_chunks}")
            for rec in bus.drain():
                if rec.error is not None:
                    raise rec.error
                done += 1
        wall = time.perf_counter() - t0
    finally:
        unit.close()
    return {
        "wall_s": wall,
        "chunks_per_sec": n_chunks / max(wall, 1e-12),
        # amortized cost of dispatching one chunk end-to-end — the number
        # batching must lower (per-chunk *latency* legitimately rises as
        # chunks pipeline behind batch siblings; that is submit_latency_us)
        "dispatch_us": 1e6 * wall / n_chunks,
        "submit_latency_us": 1e6 * statistics.fmean(unit.dispatch_latencies),
        "wire_us": 1e6 * statistics.fmean(unit.wire_latencies),
    }


def run(*, quick: bool = False, seed: int = 0,
        batch_frames: int = 8) -> dict:
    n_chunks = 96 if quick else 512
    repeats = 2 if quick else 5
    payload_bytes = 4096
    params = {
        "n_chunks": n_chunks, "repeats": repeats,
        "batch_frames": batch_frames, "payload_bytes": payload_bytes,
        "seed": seed, "quick": quick,
    }
    server = WorkerServer().start()
    configs: List[dict] = []
    try:
        for transport in TRANSPORTS:
            for mode, fn_cache, batched in MODES:
                bf = batch_frames if batched else 1
                runs = []
                for r in range(repeats):
                    work = DispatchWork(payload_bytes)
                    unit = _make_unit(
                        transport, f"{transport[0]}{r}", mode_batch=bf,
                        fn_cache=fn_cache,
                        seed=seed * 101 + r * 13 + 1, server=server)
                    runs.append(_drive(unit, n_chunks, work))
                entry = {
                    "transport": transport, "mode": mode,
                    "fn_cache": fn_cache, "batch_frames": bf,
                    "n_chunks": n_chunks,
                }
                for key in ("wall_s", "chunks_per_sec", "dispatch_us",
                            "submit_latency_us", "wire_us"):
                    entry[key] = statistics.median(r[key] for r in runs)
                configs.append(entry)
                print(f"  {transport:8s} {mode:8s}  "
                      f"{entry['chunks_per_sec']:10.0f} chunks/s  "
                      f"dispatch {entry['dispatch_us']:8.1f}us  "
                      f"wire {entry['wire_us']:8.1f}us")
    finally:
        server.stop()

    by_key = {(c["transport"], c["mode"]): c for c in configs}
    speedups = {
        t: (by_key[(t, "batched")]["chunks_per_sec"]
            / max(by_key[(t, "baseline")]["chunks_per_sec"], 1e-12))
        for t in TRANSPORTS
    }
    for t, s in speedups.items():
        print(f"  {t:8s} batched/baseline speedup: {s:.2f}x")
    return {"schema": BENCH_SCHEMA, "params": params,
            "configs": configs, "speedups": speedups}


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small chunk count / fewer repeats (CI smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-frames", type=int, default=8,
                    help="frames coalesced per work_batch in batched mode")
    ap.add_argument("--json", metavar="PATH",
                    help="write the bench_dispatch/v1 artifact here")
    args = ap.parse_args()
    result = run(quick=args.quick, seed=args.seed,
                 batch_frames=args.batch_frames)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(result, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()

"""Fleet membership benchmark (ISSUE 10): what liveness + checkpoints buy.

Two SimulatedClock studies, both deterministic (seeded, virtual time),
so the committed artifact is reproducible and CI can gate orderings:

* **recovery** — a run dies mid-flight (``done_frac`` of the space
  covered, the coverage bitmap on disk via
  :func:`repro.checkpoint.coverage.save_coverage`).  Restarting with
  :func:`~repro.checkpoint.coverage.checkpointed_parallel_for` restores
  the bitmap through the verifying path and recomputes only the
  remainder; the baseline recomputes the whole pre-split from zero.
  ``recovery_ratio = full_recompute_s / resume_s`` must be > 1.0
  (strictly faster) and CI pins a margin via
  ``check_bench.py --min-recovery-ratio``.

* **churn** — one worker of the fleet is dead from the start (crashed,
  silent, chunk in flight).  With heartbeat liveness the unit is
  convicted after ``patience x heartbeat`` seconds and its hostage
  chunk requeues to the survivors; with static membership the engine
  only learns at retransmit exhaustion (``max_retries x
  retry_interval``).  Both timelines run through the real engine as
  elastic leaves at the respective *detection* times; goodput is
  ``items / makespan``.  ``detect_ratio`` and ``goodput_ratio``
  (heartbeat over static) must be >= 1.0.

    PYTHONPATH=src python benchmarks/bench_fleet.py --json BENCH_fleet.json
    PYTHONPATH=src python benchmarks/bench_fleet.py --quick --json /tmp/smoke.json

``tools/check_bench.py --schema bench_fleet/v1`` validates structure
and orderings; the CI ``fleet`` job gates the committed artifact.
"""

from __future__ import annotations

import json
import tempfile
from typing import Dict

from repro.checkpoint import (
    Checkpointer,
    CoverageMap,
    checkpointed_parallel_for,
    save_coverage,
)
from repro.core import ElasticSchedule, HeteroRuntime, SimulatedClock
from repro.core.scheduler import WorkerKind

BENCH_SCHEMA = "bench_fleet/v1"

# liveness/transport timing constants the studies derive detection from
HEARTBEAT_S = 0.05
PATIENCE = 3
RETRY_INTERVAL_S = 0.05
MAX_RETRIES = 600


def _noop(chunk) -> None:
    """The items are pure virtual time here; coverage is what we measure."""


def _sim_runtime(num_units: int, *, dead: int = 0) -> HeteroRuntime:
    """A fresh simulated fleet; the first ``dead`` units are crashed
    (near-zero speed: they accept a chunk and never finish it)."""
    rt = HeteroRuntime(clock=SimulatedClock())
    for i in range(num_units):
        speed = 1e-9 if i < dead else 1.0
        rt.register_unit(f"u{i}", WorkerKind.CC, speed=speed)
    return rt


def recovery_study(*, items: int, num_units: int, done_frac: float,
                   round_items: int) -> Dict[str, float]:
    """Checkpoint-backed resume vs full recompute after mid-run death."""
    # the death scene: a real bitmap covering done_frac of the space,
    # written through the standard checkpointer (what a dying run left)
    done_items = int(items * done_frac)
    with tempfile.TemporaryDirectory() as death_dir:
        ckpt = Checkpointer(death_dir)
        cov = CoverageMap(items)
        cov.mark(0, done_items)
        save_coverage(ckpt, done_items, cov, blocking=True)
        ckpt.wait_all()
        resume = checkpointed_parallel_for(
            _sim_runtime(num_units), _noop, items, checkpointer=ckpt,
            round_items=round_items, policy="multidynamic", acc_chunk=16)
    with tempfile.TemporaryDirectory() as fresh_dir:
        full = checkpointed_parallel_for(
            _sim_runtime(num_units), _noop, items,
            checkpointer=Checkpointer(fresh_dir), resume=False,
            round_items=round_items, policy="multidynamic", acc_chunk=16)
    resume_s = sum(r.wall_time for r in resume.reports)
    full_s = sum(r.wall_time for r in full.reports)
    assert resume.items_run == items - done_items
    return {
        "full_recompute_items": full.items_run,
        "resume_items": resume.items_run,
        "full_recompute_s": full_s,
        "resume_s": resume_s,
        "recovery_ratio": full_s / resume_s,
    }


def churn_study(*, items: int, num_units: int) -> Dict[str, float]:
    """Goodput with heartbeat-convicted vs static membership, one dead
    worker holding a chunk hostage until detection."""
    hb_detect = PATIENCE * HEARTBEAT_S
    static_detect = MAX_RETRIES * RETRY_INTERVAL_S

    def run(detect_s: float) -> float:
        rt = _sim_runtime(num_units, dead=1)
        sched = ElasticSchedule().leave(detect_s, "u0")
        rep = rt.parallel_for(num_items=items, policy="multidynamic",
                              acc_chunk=8, elastic=sched)
        assert rep.items == items
        return rep.wall_time

    hb_makespan = run(hb_detect)
    static_makespan = run(static_detect)
    return {
        "heartbeat_detect_s": hb_detect,
        "static_detect_s": static_detect,
        "detect_ratio": static_detect / hb_detect,
        "heartbeat_makespan_s": hb_makespan,
        "static_makespan_s": static_makespan,
        "heartbeat_goodput": items / hb_makespan,
        "static_goodput": items / static_makespan,
        "goodput_ratio": static_makespan / hb_makespan,
    }


def run_bench(*, quick: bool = False) -> dict:
    items = 800 if quick else 4000
    num_units = 4 if quick else 8
    done_frac = 0.75
    round_items = items // 8
    # small enough that the survivors drain well before static detection
    # fires — the regime where the hostage chunk dominates the makespan
    churn_items = 60 if quick else 120
    doc = {
        "schema": BENCH_SCHEMA,
        "params": {
            "seed": 0,
            "num_units": num_units,
            "items": items,
            "heartbeat": HEARTBEAT_S,
            "patience": PATIENCE,
            "retry_interval": RETRY_INTERVAL_S,
            "max_retries": MAX_RETRIES,
            "done_frac": done_frac,
            "round_items": round_items,
            "churn_items": churn_items,
            "quick": quick,
        },
        "recovery": recovery_study(items=items, num_units=num_units,
                                   done_frac=done_frac,
                                   round_items=round_items),
        "churn": churn_study(items=churn_items, num_units=num_units),
    }
    return doc


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller spaces for a CI smoke run")
    ap.add_argument("--json", metavar="PATH",
                    help="write the artifact to PATH")
    args = ap.parse_args()
    doc = run_bench(quick=args.quick)
    rec, ch = doc["recovery"], doc["churn"]
    print(f"recovery: full {rec['full_recompute_s']:.1f}s vs resume "
          f"{rec['resume_s']:.1f}s -> {rec['recovery_ratio']:.2f}x "
          f"({rec['resume_items']}/{rec['full_recompute_items']} items re-run)")
    print(f"churn: detect {ch['heartbeat_detect_s']:.2f}s vs "
          f"{ch['static_detect_s']:.2f}s (ratio {ch['detect_ratio']:.1f}x), "
          f"goodput {ch['heartbeat_goodput']:.1f} vs "
          f"{ch['static_goodput']:.1f} items/s "
          f"(ratio {ch['goodput_ratio']:.2f}x)")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()

"""Roofline table: reads the dry-run artifacts (experiments/dryrun/*.json)
and emits the three-term analysis per (arch × shape × mesh)."""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

ART_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_records(mesh: Optional[str] = None) -> List[dict]:
    recs = []
    for f in sorted(ART_DIR.glob("*.json")):
        rec = json.loads(f.read_text())
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("tag"):
            continue  # perf-iteration variants are reported in §Perf
        recs.append(rec)
    return recs


def roofline_rows(mesh: str = "pod16x16"):
    rows = []
    for rec in load_records(mesh):
        cell = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
        if rec["status"] != "ok":
            rows.append((f"roofline_{cell}", 0.0, "SKIPPED:" + rec["reason"][:40]))
            continue
        r = rec["roofline"]
        rows.append((
            f"roofline_{cell}",
            r["bound_s"] * 1e6,
            f"us_bound;dom={r['dominant']};c={r['compute_s']:.3g}s;"
            f"m={r['memory_s']:.3g}s;x={r['collective_s']:.3g}s;"
            f"useful={r['useful_flops_ratio']:.3f};"
            f"fits={rec['memory']['fits']}",
        ))
    return rows


def summary_table(mesh: str = "pod16x16") -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO | peak GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records(mesh):
        if rec["status"] != "ok":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | skip | — | — | "
                f"{rec['reason'].split(';')[0][:60]} |"
            )
            continue
        r = rec["roofline"]
        m = rec["memory"]
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{m['peak_est_bytes'] / 2**30:.1f} | "
            f"{'✓' if m['fits'] else 'OVER'} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(summary_table())

"""Kernel micro-benchmarks.

Pallas kernels are validated in interpret mode (correctness; timings there
are Python-interpreter artifacts), so throughput is measured on the
jit-compiled XLA analogues of the same tilings — plus the flash kernel's
*structural* HBM-traffic advantage computed from its BlockSpec design
(the number the TPU roofline substitution in §Perf uses)."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_eneac import HotspotConfig
from repro.kernels.flash_attention.ops import kernel_flops, kernel_hbm_bytes
from repro.kernels.hotspot.ref import hotspot_step_ref
from repro.kernels.spmm.ref import make_problem, spmm_ell_ref, to_block_ell
from repro.kernels.spmm.ops import pad_rhs


def _time(fn, *args, reps=5) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def kernel_rows(*, quick: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    grid = 256 if quick else 1024
    cfg = HotspotConfig(grid=grid)
    t = 80.0 + 10 * jax.random.uniform(jax.random.PRNGKey(0), (grid, grid))
    p = jax.random.uniform(jax.random.PRNGKey(1), (grid, grid))
    step = jax.jit(lambda t, p: hotspot_step_ref(t, p, cfg))
    dt = _time(step, t, p)
    rows.append((f"hotspot_step_{grid}", dt * 1e6,
                 f"temps_per_ms={grid * grid / dt / 1e3:.0f}"))

    r = 2048 if quick else 8192
    prob = make_problem(r, 4096, 128, nnz_mean=16.0, seed=1)
    gather = jax.jit(spmm_ell_ref)
    dt = _time(gather, jnp.asarray(prob.vals), jnp.asarray(prob.cols),
               jnp.asarray(prob.rhs))
    rows.append((f"spmm_gather_{r}", dt * 1e6,
                 f"rows_per_ms={r / dt / 1e3:.1f}"))

    be = to_block_ell(prob)
    bvals = jnp.asarray(be.vals)
    bcols = jnp.asarray(be.colblocks)
    rhs_pad = jnp.asarray(pad_rhs(prob))

    @jax.jit
    def block_path(bvals, bcols, rhs_pad):
        nrb, K, RB, CB = bvals.shape
        b_blocks = rhs_pad.reshape(-1, CB, rhs_pad.shape[1])[bcols]
        return jnp.einsum("rkac,rkcn->ran", bvals, b_blocks)

    dt = _time(block_path, bvals, bcols, rhs_pad)
    rows.append((f"spmm_blockell_{r}", dt * 1e6,
                 f"rows_per_ms={r / dt / 1e3:.1f};fill={be.padding_ratio():.3f}"))

    # flash kernel structural numbers at prefill_32k scale (stablelm dims)
    fb = kernel_hbm_bytes(1, 32768, 32768, 32, 8, 160)
    xla_score_traffic = 6 * 32 * 32768 * 32768 * 4 / 16  # ≈6 crossings, TP/16
    rows.append((
        "flash_vs_xla_traffic_32k", fb / 1e9,
        f"GB_kernel;xla_score_GB={xla_score_traffic / 1e9:.0f};"
        f"reduction={xla_score_traffic / fb:.0f}x",
    ))
    return rows

"""Validate a committed ``BENCH_serving.json`` artifact.

    python tools/check_bench.py BENCH_serving.json [--require-continuous-wins]

Checks (all structural, so they hold for the *committed* artifact and
for a fresh ``benchmarks/bench_serving.py --loadgen --json`` run alike):

* ``schema`` is exactly ``bench_serving/v1``;
* ``scenario`` and ``engine`` blocks are present and seeded;
* every config entry carries ``policy``/``mode``/``backend`` and a
  ``metrics`` dict whose keys are exactly
  :data:`repro.serving.loadgen.METRIC_KEYS`;
* at least two policies and both refill modes are covered;
* with ``--require-continuous-wins``: for every (policy, backend) pair
  that has both modes, ``mode="continuous"`` strictly beats
  ``mode="static"`` on ``goodput_tokens_per_s`` — the paper's
  interrupt-beats-polling claim restated as a serving acceptance gate.
  CI applies this flag to the committed artifact (deterministic) and
  only schema-checks the fresh smoke run (hosted runners are too noisy
  to gate an ordering on a single quick run).

Exit code 0 on success, 1 with a diagnostic on any violation.
"""

from __future__ import annotations

import argparse
import json
import sys

# tools/ is not a package; resolve src/ relative to the repo root so the
# schema constant stays single-sourced even without PYTHONPATH.
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving.loadgen import METRIC_KEYS  # noqa: E402

SCHEMA = "bench_serving/v1"


def check(doc: dict, *, require_continuous_wins: bool = False) -> list:
    """Return a list of violation strings (empty = artifact is valid)."""
    errs = []
    if doc.get("schema") != SCHEMA:
        errs.append(f"schema is {doc.get('schema')!r}, want {SCHEMA!r}")
    for block in ("scenario", "engine"):
        if not isinstance(doc.get(block), dict):
            errs.append(f"missing {block!r} block")
    if isinstance(doc.get("scenario"), dict) and "seed" not in doc["scenario"]:
        errs.append("scenario has no seed — artifact is not reproducible")
    configs = doc.get("configs")
    if not isinstance(configs, list) or not configs:
        return errs + ["configs must be a non-empty list"]

    by_pair = {}
    for i, entry in enumerate(configs):
        for field in ("policy", "mode", "backend"):
            if not isinstance(entry.get(field), str):
                errs.append(f"configs[{i}] missing {field!r}")
        metrics = entry.get("metrics")
        if not isinstance(metrics, dict):
            errs.append(f"configs[{i}] missing metrics")
            continue
        missing = set(METRIC_KEYS) - set(metrics)
        extra = set(metrics) - set(METRIC_KEYS)
        if missing:
            errs.append(f"configs[{i}] metrics missing {sorted(missing)}")
        if extra:
            errs.append(f"configs[{i}] metrics has extra keys {sorted(extra)}")
        key = (entry.get("policy"), entry.get("backend"))
        by_pair.setdefault(key, {})[entry.get("mode")] = metrics

    policies = {p for p, _ in by_pair}
    modes = {m for pair in by_pair.values() for m in pair}
    if len(policies) < 2:
        errs.append(f"want >=2 policies, got {sorted(policies)}")
    if not {"static", "continuous"} <= modes:
        errs.append(f"want both refill modes, got {sorted(modes)}")

    if require_continuous_wins:
        for (policy, backend), pair in sorted(by_pair.items()):
            if not {"static", "continuous"} <= set(pair):
                continue
            cont = pair["continuous"].get("goodput_tokens_per_s", 0.0)
            stat = pair["static"].get("goodput_tokens_per_s", 0.0)
            if not cont > stat:
                errs.append(
                    f"{policy}/{backend}: continuous goodput "
                    f"{cont:.2f} tok/s does not beat static {stat:.2f}"
                )
    return errs


def main(argv: list) -> int:
    ap = argparse.ArgumentParser(
        description="Validate a BENCH_serving.json artifact")
    ap.add_argument("path", help="artifact to validate")
    ap.add_argument("--require-continuous-wins", action="store_true",
                    help="fail unless continuous beats static on goodput "
                         "for every (policy, backend) pair")
    args = ap.parse_args(argv)
    with open(args.path) as fh:
        doc = json.load(fh)
    errs = check(doc, require_continuous_wins=args.require_continuous_wins)
    for e in errs:
        print(f"check_bench: {e}", file=sys.stderr)
    if not errs:
        n = len(doc.get("configs", []))
        print(f"check_bench: OK — {n} configs, schema {SCHEMA}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""Validate a committed benchmark artifact (dispatches on ``schema``).

    python tools/check_bench.py BENCH_serving.json [--require-continuous-wins]
    python tools/check_bench.py BENCH_costmodel.json [--max-gap 0.10]

``bench_serving/v1`` checks (structural, so they hold for the
*committed* artifact and for a fresh ``benchmarks/bench_serving.py
--loadgen --json`` run alike):

* ``schema`` is exactly ``bench_serving/v1``;
* ``scenario`` and ``engine`` blocks are present and seeded;
* every config entry carries ``policy``/``mode``/``backend`` and a
  ``metrics`` dict whose keys are exactly
  :data:`repro.serving.loadgen.METRIC_KEYS`;
* every config entry completed at least one request — a run with
  ``completed == 0`` reports ``nan`` latency percentiles, and "no
  data" is a violation, never a pass;
* at least two policies and both refill modes are covered;
* with ``--require-continuous-wins``: for every (policy, backend) pair
  that has both modes, ``mode="continuous"`` strictly beats
  ``mode="static"`` on ``goodput_tokens_per_s`` — the paper's
  interrupt-beats-polling claim restated as a serving acceptance gate.
  CI applies this flag to the committed artifact (deterministic) and
  only schema-checks the fresh smoke run (hosted runners are too noisy
  to gate an ordering on a single quick run).

``bench_costmodel/v1`` checks (``benchmarks/table1_eneac.py
--costmodel``; the run is SimulatedClock-deterministic, so the gate
applies to fresh runs and the committed artifact alike):

* every config entry carries ``seed``/``units``/the three makespans and
  a ``gap`` consistent with ``learned_makespan / oracle_makespan - 1``;
* seeds are unique and ``max_gap``/``mean_gap`` match the entries;
* every per-seed ``gap`` is ≤ ``--max-gap`` (default 0.10) — the
  acceptance number: learned splits within 10% of oracle after one
  warmup run.

``bench_dispatch/v2`` checks (``benchmarks/bench_dispatch.py``): full
transport x mode coverage with positive metrics, loopback batched
``dispatch_us`` <= baseline, a ``speedups`` block consistent with the
configs, and a ``latency_aware`` block whose ratios are consistent with
their entries.  Performance gates: ``--min-speedup S`` (socket
batched/baseline ``chunks_per_sec`` >= S), ``--min-auto-ratio R``
(``batch_frames="auto"`` vs fixed on the flaky-delay transport >= R)
and ``--min-split-ratio R`` (throughput-only / latency-aware learned
makespan >= R — the latency terms must not make the split worse).

``bench_fleet/v1`` checks (``benchmarks/bench_fleet.py``): a seeded
``params`` block, a ``recovery`` study (checkpoint-backed resume vs
full recompute after mid-run fleet death; ``recovery_ratio`` must be
consistent and strictly > 1.0, and >= ``--min-recovery-ratio`` when
given) and a ``churn`` study (heartbeat-convicted membership vs static
membership under the same failure trace; detection-time and goodput
ratios must be consistent and >= 1.0).

``--schema NAME`` pins the expected schema so CI cannot silently
validate the wrong artifact kind.

Exit code 0 on success, 1 with a diagnostic on any violation.
"""

from __future__ import annotations

import argparse
import json
import sys

# tools/ is not a package; resolve src/ relative to the repo root so the
# schema constant stays single-sourced even without PYTHONPATH.
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving.loadgen import METRIC_KEYS  # noqa: E402

SCHEMA = "bench_serving/v1"
COSTMODEL_SCHEMA = "bench_costmodel/v1"
DISPATCH_SCHEMA = "bench_dispatch/v2"
FLEET_SCHEMA = "bench_fleet/v1"

_DISPATCH_TRANSPORTS = ("loopback", "socket", "flaky")
_DISPATCH_MODES = ("baseline", "cached", "batched")


def check_dispatch(doc: dict, *, min_speedup: float = 0.0,
                   min_auto_ratio: float = 0.0,
                   min_split_ratio: float = 0.0) -> list:
    """Return violation strings for a ``bench_dispatch/v2`` artifact.

    Structural checks hold for fresh ``--quick`` smoke runs and the
    committed artifact alike; the performance gates ride along:

    * loopback ``batched`` must not cost more per dispatched chunk than
      ``baseline`` (``dispatch_us`` ordering — the pinned local config
      where no network noise can excuse a regression);
    * with ``--min-speedup S``: socket batched/baseline
      ``chunks_per_sec`` >= S (CI applies 2.0 to the committed
      artifact only — ISSUE 8's acceptance line);
    * with ``--min-auto-ratio R``: ``batch_frames="auto"`` must reach at
      least R times the fixed-width chunks/s on the flaky-delay
      transport (CI applies 1.0 to the committed artifact — ISSUE 9's
      adaptive-batching acceptance line);
    * with ``--min-split-ratio R``: the throughput-only pre-split's
      makespan over the latency-aware one must be >= R (CI applies 1.0
      to the committed artifact — learned latency terms must beat the
      throughput-only learned split on the mixed local+remote set).
    """
    errs = []
    if doc.get("schema") != DISPATCH_SCHEMA:
        errs.append(f"schema is {doc.get('schema')!r}, want {DISPATCH_SCHEMA!r}")
    params = doc.get("params")
    if not isinstance(params, dict):
        errs.append("missing 'params' block")
    else:
        for field in ("n_chunks", "repeats", "batch_frames",
                      "payload_bytes", "seed"):
            if field not in params:
                errs.append(f"params missing {field!r}")
    configs = doc.get("configs")
    if not isinstance(configs, list) or not configs:
        return errs + ["configs must be a non-empty list"]

    by_key = {}
    for i, entry in enumerate(configs):
        key = (entry.get("transport"), entry.get("mode"))
        if key in by_key:
            errs.append(f"configs[{i}] duplicates {key}")
            continue
        by_key[key] = entry
        for field in ("dispatch_us", "wire_us", "chunks_per_sec", "wall_s"):
            v = entry.get(field)
            if not isinstance(v, (int, float)) or not v > 0:
                errs.append(f"configs[{i}] {key}: {field} must be positive, "
                            f"got {v!r}")
        if entry.get("mode") == "baseline":
            if entry.get("fn_cache") or entry.get("batch_frames") != 1:
                errs.append(f"configs[{i}] baseline must run fn_cache=off, "
                            "batch_frames=1")
        if entry.get("mode") == "batched" and not entry.get(
                "batch_frames", 0) >= 4:
            errs.append(f"configs[{i}] batched mode needs batch_frames>=4, "
                        f"got {entry.get('batch_frames')!r}")
    missing = [(t, m) for t in _DISPATCH_TRANSPORTS
               for m in _DISPATCH_MODES if (t, m) not in by_key]
    if missing:
        return errs + [f"missing configs: {missing}"]

    lo_base = by_key[("loopback", "baseline")]
    lo_batch = by_key[("loopback", "batched")]
    if not lo_batch["dispatch_us"] <= lo_base["dispatch_us"]:
        errs.append(
            f"loopback batched dispatch_us {lo_batch['dispatch_us']:.1f} "
            f"exceeds baseline {lo_base['dispatch_us']:.1f} — batching "
            "regressed per-chunk dispatch cost on the pinned local config"
        )
    speedups = doc.get("speedups")
    if not isinstance(speedups, dict):
        errs.append("missing 'speedups' block")
    else:
        for t in _DISPATCH_TRANSPORTS:
            want = (by_key[(t, "batched")]["chunks_per_sec"]
                    / by_key[(t, "baseline")]["chunks_per_sec"])
            got = speedups.get(t)
            if not isinstance(got, (int, float)) or abs(got - want) > 1e-6 * want:
                errs.append(f"speedups[{t!r}] {got!r} inconsistent with "
                            f"configs ({want:.4f})")
    if min_speedup > 0:
        sock = (by_key[("socket", "batched")]["chunks_per_sec"]
                / by_key[("socket", "baseline")]["chunks_per_sec"])
        if not sock >= min_speedup:
            errs.append(
                f"socket batched/baseline speedup {sock:.2f}x below the "
                f"required {min_speedup:.2f}x"
            )

    la = doc.get("latency_aware")
    if not isinstance(la, dict):
        return errs + ["missing 'latency_aware' block"]
    for sub in ("fixed", "auto"):
        entry = la.get(sub)
        if not isinstance(entry, dict):
            errs.append(f"latency_aware missing {sub!r} entry")
            continue
        for field in ("chunks_per_sec", "wall_s", "final_batch_frames"):
            v = entry.get(field)
            if not isinstance(v, (int, float)) or not v > 0:
                errs.append(f"latency_aware[{sub!r}]: {field} must be "
                            f"positive, got {v!r}")
    if isinstance(la.get("fixed"), dict) and isinstance(la.get("auto"), dict):
        want = (la["auto"].get("chunks_per_sec", 0.0)
                / max(la["fixed"].get("chunks_per_sec", 0.0), 1e-12))
        got = la.get("auto_ratio")
        if not isinstance(got, (int, float)) or abs(got - want) > 1e-6 * want:
            errs.append(f"latency_aware auto_ratio {got!r} inconsistent "
                        f"with entries ({want:.4f})")
        elif min_auto_ratio > 0 and not got >= min_auto_ratio:
            errs.append(
                f"flaky-delay auto/fixed ratio {got:.2f}x below the "
                f"required {min_auto_ratio:.2f}x — adaptive batching lost "
                "to the hand-tuned width"
            )
    split = la.get("split")
    if not isinstance(split, dict):
        errs.append("latency_aware missing 'split' study")
    else:
        t_only = split.get("throughput_only_makespan_s")
        lat = split.get("latency_aware_makespan_s")
        ratio = split.get("makespan_ratio")
        for field, v in (("throughput_only_makespan_s", t_only),
                         ("latency_aware_makespan_s", lat)):
            if not isinstance(v, (int, float)) or not v > 0:
                errs.append(f"latency_aware split: {field} must be "
                            f"positive, got {v!r}")
        if (isinstance(t_only, (int, float)) and isinstance(lat, (int, float))
                and lat > 0):
            want = t_only / lat
            if (not isinstance(ratio, (int, float))
                    or abs(ratio - want) > 1e-6 * want):
                errs.append(f"latency_aware split makespan_ratio {ratio!r} "
                            f"inconsistent with makespans ({want:.4f})")
            elif min_split_ratio > 0 and not ratio >= min_split_ratio:
                errs.append(
                    f"latency-aware learned split only reached {ratio:.2f}x "
                    f"the throughput-only makespan (required "
                    f">= {min_split_ratio:.2f}x)"
                )
    return errs


def check_costmodel(doc: dict, *, max_gap: float = 0.10) -> list:
    """Return violation strings for a ``bench_costmodel/v1`` artifact."""
    errs = []
    if doc.get("schema") != COSTMODEL_SCHEMA:
        errs.append(f"schema is {doc.get('schema')!r}, want {COSTMODEL_SCHEMA!r}")
    if not isinstance(doc.get("params"), dict):
        errs.append("missing 'params' block")
    configs = doc.get("configs")
    if not isinstance(configs, list) or not configs:
        return errs + ["configs must be a non-empty list"]

    seeds = []
    gaps = []
    for i, entry in enumerate(configs):
        ok = True
        for field in ("seed", "units", "warmup_makespan", "learned_makespan",
                      "oracle_makespan", "gap"):
            if field not in entry:
                errs.append(f"configs[{i}] missing {field!r}")
                ok = False
        if not ok:
            continue
        if not isinstance(entry["units"], dict) or not entry["units"]:
            errs.append(f"configs[{i}] units must be a non-empty dict")
            continue
        seeds.append(entry["seed"])
        oracle = entry["oracle_makespan"]
        if not oracle > 0:
            errs.append(f"configs[{i}] oracle_makespan must be positive")
            continue
        implied = entry["learned_makespan"] / oracle - 1.0
        if abs(implied - entry["gap"]) > 1e-9:
            errs.append(
                f"configs[{i}] gap {entry['gap']:.6f} inconsistent with "
                f"makespans (implied {implied:.6f})"
            )
        gaps.append(entry["gap"])
        if entry["gap"] > max_gap:
            errs.append(
                f"configs[{i}] (seed {entry['seed']}): learned is "
                f"{entry['gap']:.2%} over oracle, budget {max_gap:.0%}"
            )
    if len(set(seeds)) != len(seeds):
        errs.append("duplicate seeds in configs")
    if gaps:
        for field, value in (("max_gap", max(gaps)),
                             ("mean_gap", sum(gaps) / len(gaps))):
            if field in doc and abs(doc[field] - value) > 1e-9:
                errs.append(
                    f"{field} {doc[field]:.6f} inconsistent with configs "
                    f"({value:.6f})"
                )
    return errs


def check_fleet(doc: dict, *, min_recovery_ratio: float = 0.0) -> list:
    """Return violation strings for a ``bench_fleet/v1`` artifact.

    Structural checks (fresh smoke runs and the committed artifact
    alike): a seeded ``params`` block; a ``recovery`` study whose
    ``recovery_ratio`` equals ``full_recompute_s / resume_s`` and whose
    resume re-ran strictly fewer items than the full space; a ``churn``
    study whose detection and goodput ratios are consistent with their
    components.  Both runs are SimulatedClock-deterministic, so the
    ordering gates also apply everywhere:

    * ``recovery_ratio`` must exceed 1.0 — checkpoint-backed recovery
      strictly faster than recomputing the whole pre-split — and, with
      ``--min-recovery-ratio R``, at least R (CI pins the committed
      artifact's margin);
    * churn ``detect_ratio`` (static-membership detection time over
      heartbeat detection time) and ``goodput_ratio`` must be >= 1.0:
      heartbeat conviction never detects later than waiting out the
      retransmit budget, and never yields less goodput under churn.
    """
    errs = []
    if doc.get("schema") != FLEET_SCHEMA:
        errs.append(f"schema is {doc.get('schema')!r}, want {FLEET_SCHEMA!r}")
    params = doc.get("params")
    if not isinstance(params, dict):
        errs.append("missing 'params' block")
    else:
        for field in ("seed", "num_units", "items", "heartbeat", "patience"):
            if field not in params:
                errs.append(f"params missing {field!r}")

    rec = doc.get("recovery")
    if not isinstance(rec, dict):
        errs.append("missing 'recovery' study")
    else:
        for field in ("full_recompute_s", "resume_s", "full_recompute_items",
                      "resume_items", "recovery_ratio"):
            v = rec.get(field)
            if not isinstance(v, (int, float)) or not v > 0:
                errs.append(f"recovery: {field} must be positive, got {v!r}")
        if not errs:
            if not rec["resume_items"] < rec["full_recompute_items"]:
                errs.append(
                    f"recovery: resume re-ran {rec['resume_items']} of "
                    f"{rec['full_recompute_items']} items — the checkpoint "
                    "saved nothing"
                )
            want = rec["full_recompute_s"] / rec["resume_s"]
            got = rec["recovery_ratio"]
            if abs(got - want) > 1e-6 * want:
                errs.append(f"recovery_ratio {got!r} inconsistent with "
                            f"times ({want:.4f})")
            elif not got > 1.0:
                errs.append(
                    f"recovery_ratio {got:.3f} — checkpoint-backed resume "
                    "must be strictly faster than full recompute"
                )
            elif min_recovery_ratio > 0 and not got >= min_recovery_ratio:
                errs.append(
                    f"recovery_ratio {got:.2f}x below the required "
                    f"{min_recovery_ratio:.2f}x"
                )

    churn = doc.get("churn")
    if not isinstance(churn, dict):
        errs.append("missing 'churn' study")
        return errs
    for field in ("heartbeat_detect_s", "static_detect_s", "detect_ratio",
                  "heartbeat_goodput", "static_goodput", "goodput_ratio"):
        v = churn.get(field)
        if not isinstance(v, (int, float)) or not v > 0:
            errs.append(f"churn: {field} must be positive, got {v!r}")
            return errs
    want = churn["static_detect_s"] / churn["heartbeat_detect_s"]
    if abs(churn["detect_ratio"] - want) > 1e-6 * want:
        errs.append(f"churn detect_ratio {churn['detect_ratio']!r} "
                    f"inconsistent with detection times ({want:.4f})")
    elif not churn["detect_ratio"] >= 1.0:
        errs.append(
            f"churn detect_ratio {churn['detect_ratio']:.3f} — heartbeat "
            "conviction detected failures later than static membership"
        )
    want = churn["heartbeat_goodput"] / churn["static_goodput"]
    if abs(churn["goodput_ratio"] - want) > 1e-6 * want:
        errs.append(f"churn goodput_ratio {churn['goodput_ratio']!r} "
                    f"inconsistent with goodputs ({want:.4f})")
    elif not churn["goodput_ratio"] >= 1.0:
        errs.append(
            f"churn goodput_ratio {churn['goodput_ratio']:.3f} — heartbeat "
            "membership lost goodput vs static under the same churn"
        )
    return errs


def _no_data(metrics: dict) -> bool:
    """True when the run completed nothing (latency metrics are nan)."""
    if metrics.get("completed", 0) == 0:
        return True
    return any(
        isinstance(metrics.get(k), float) and metrics[k] != metrics[k]
        for k in ("mean_latency_s", "p50_latency_s", "p95_latency_s",
                  "p99_latency_s")
    )


def check(doc: dict, *, require_continuous_wins: bool = False) -> list:
    """Return a list of violation strings (empty = artifact is valid)."""
    errs = []
    if doc.get("schema") != SCHEMA:
        errs.append(f"schema is {doc.get('schema')!r}, want {SCHEMA!r}")
    for block in ("scenario", "engine"):
        if not isinstance(doc.get(block), dict):
            errs.append(f"missing {block!r} block")
    if isinstance(doc.get("scenario"), dict) and "seed" not in doc["scenario"]:
        errs.append("scenario has no seed — artifact is not reproducible")
    configs = doc.get("configs")
    if not isinstance(configs, list) or not configs:
        return errs + ["configs must be a non-empty list"]

    by_pair = {}
    for i, entry in enumerate(configs):
        for field in ("policy", "mode", "backend"):
            if not isinstance(entry.get(field), str):
                errs.append(f"configs[{i}] missing {field!r}")
        metrics = entry.get("metrics")
        if not isinstance(metrics, dict):
            errs.append(f"configs[{i}] missing metrics")
            continue
        missing = set(METRIC_KEYS) - set(metrics)
        extra = set(metrics) - set(METRIC_KEYS)
        if missing:
            errs.append(f"configs[{i}] metrics missing {sorted(missing)}")
        if extra:
            errs.append(f"configs[{i}] metrics has extra keys {sorted(extra)}")
        if _no_data(metrics):
            errs.append(
                f"configs[{i}] completed no requests (nan latencies) — "
                "no data is not a pass"
            )
        key = (entry.get("policy"), entry.get("backend"))
        by_pair.setdefault(key, {})[entry.get("mode")] = metrics

    policies = {p for p, _ in by_pair}
    modes = {m for pair in by_pair.values() for m in pair}
    if len(policies) < 2:
        errs.append(f"want >=2 policies, got {sorted(policies)}")
    if not {"static", "continuous"} <= modes:
        errs.append(f"want both refill modes, got {sorted(modes)}")

    if require_continuous_wins:
        for (policy, backend), pair in sorted(by_pair.items()):
            if not {"static", "continuous"} <= set(pair):
                continue
            cont = pair["continuous"].get("goodput_tokens_per_s", 0.0)
            stat = pair["static"].get("goodput_tokens_per_s", 0.0)
            if not cont > stat:
                errs.append(
                    f"{policy}/{backend}: continuous goodput "
                    f"{cont:.2f} tok/s does not beat static {stat:.2f}"
                )
    return errs


def main(argv: list) -> int:
    ap = argparse.ArgumentParser(
        description="Validate a committed benchmark artifact")
    ap.add_argument("path", help="artifact to validate")
    ap.add_argument("--require-continuous-wins", action="store_true",
                    help="bench_serving: fail unless continuous beats static "
                         "on goodput for every (policy, backend) pair")
    ap.add_argument("--max-gap", type=float, default=0.10,
                    help="bench_costmodel: per-seed learned-vs-oracle "
                         "makespan budget (default 0.10)")
    ap.add_argument("--schema", metavar="NAME",
                    help="fail unless the artifact declares exactly this "
                         "schema (e.g. bench_dispatch/v1)")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="bench_dispatch: required socket batched/baseline "
                         "chunks_per_sec ratio (0 = structural checks only)")
    ap.add_argument("--min-auto-ratio", type=float, default=0.0,
                    help="bench_dispatch: required batch_frames=auto vs "
                         "fixed chunks_per_sec ratio on the flaky-delay "
                         "transport (0 = structural checks only)")
    ap.add_argument("--min-split-ratio", type=float, default=0.0,
                    help="bench_dispatch: required throughput-only / "
                         "latency-aware learned-split makespan ratio "
                         "(0 = structural checks only)")
    ap.add_argument("--min-recovery-ratio", type=float, default=0.0,
                    help="bench_fleet: required full-recompute / "
                         "checkpoint-resume time ratio (the >1.0 strict "
                         "ordering is always enforced)")
    args = ap.parse_args(argv)
    with open(args.path) as fh:
        doc = json.load(fh)
    schema = doc.get("schema")
    if args.schema and schema != args.schema:
        print(f"check_bench: schema is {schema!r}, want {args.schema!r}",
              file=sys.stderr)
        return 1
    if schema == COSTMODEL_SCHEMA:
        errs = check_costmodel(doc, max_gap=args.max_gap)
    elif schema == DISPATCH_SCHEMA:
        errs = check_dispatch(doc, min_speedup=args.min_speedup,
                              min_auto_ratio=args.min_auto_ratio,
                              min_split_ratio=args.min_split_ratio)
    elif schema == FLEET_SCHEMA:
        errs = check_fleet(doc, min_recovery_ratio=args.min_recovery_ratio)
    else:
        errs = check(doc, require_continuous_wins=args.require_continuous_wins)
    for e in errs:
        print(f"check_bench: {e}", file=sys.stderr)
    if not errs:
        n = len(doc.get("configs", []))
        print(f"check_bench: OK — {n} configs, schema {schema}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""Extract and execute the ```python blocks in markdown docs.

    PYTHONPATH=src python tools/check_docs.py docs/*.md README.md

Within one file, blocks share a namespace and run top-to-bottom, so a
later snippet can use names a earlier one defined — docs read as one
continuous session.  A block fenced as anything other than ```python
(```text, ```bash, bare ```) is skipped.  Any exception fails the run
with the file, block number, and offending source, so documented
examples cannot rot.  CI runs this as the `docs` job.
"""

from __future__ import annotations

import re
import sys
import traceback
from pathlib import Path

FENCE = re.compile(r"^```(\w*)\s*$")


def extract_blocks(text: str) -> list[tuple[int, str]]:
    """Return (starting line number, source) for every ```python block."""
    blocks = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if m:
            lang, start = m.group(1), i + 1
            body = []
            i += 1
            while i < len(lines) and not FENCE.match(lines[i]):
                body.append(lines[i])
                i += 1
            if lang == "python":
                blocks.append((start + 1, "\n".join(body)))
        i += 1
    return blocks


def run_file(path: Path) -> int:
    blocks = extract_blocks(path.read_text())
    if not blocks:
        print(f"  {path}: no python blocks")
        return 0
    namespace: dict = {"__name__": f"docsnippet:{path.name}"}
    for n, (line, src) in enumerate(blocks, 1):
        try:
            code = compile(src, f"{path}:block{n}(line {line})", "exec")
            exec(code, namespace)
        except Exception:
            print(f"FAIL {path} block {n} (line {line}):", file=sys.stderr)
            print("-" * 60, file=sys.stderr)
            print(src, file=sys.stderr)
            print("-" * 60, file=sys.stderr)
            traceback.print_exc()
            return 1
    print(f"  {path}: {len(blocks)} block(s) ok")
    return 0


def main(argv: list[str]) -> int:
    paths = [Path(a) for a in argv] or sorted(Path("docs").glob("*.md"))
    missing = [p for p in paths if not p.exists()]
    if missing:
        print(f"missing: {missing}", file=sys.stderr)
        return 1
    print(f"checking {len(paths)} file(s)")
    return max((run_file(p) for p in paths), default=0)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""Run a command with a hard wall-clock timeout and a diagnostic dump.

    python tools/run_with_timeout.py --timeout 120 -- python -m pytest ...

The concurrency battery (tests/test_backends.py) exercises real threads,
condition variables, and elastic membership churn; its failure mode of
interest is a *deadlock*, which a plain CI job reports as a 6-hour hang
instead of a red X.  This wrapper turns hangs into failures:

* the child runs in its own process group with ``PYTHONFAULTHANDLER=1``;
* on timeout we first send SIGABRT so faulthandler dumps every thread's
  traceback to stderr (the evidence you need to debug a deadlock), wait a
  grace period, then SIGKILL the whole group;
* exit code is 124 on timeout (the ``timeout(1)`` convention), otherwise
  the child's own exit code.

CI's ``threads`` job wraps the battery with this; pytest's built-in
``--faulthandler-timeout`` complements it per-test (dump without kill).
"""

from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import time


def main(argv: list) -> int:
    ap = argparse.ArgumentParser(
        description="Run a command with a hard timeout + traceback dump")
    ap.add_argument("--timeout", type=float, required=True,
                    help="wall-clock budget in seconds")
    ap.add_argument("--grace", type=float, default=15.0,
                    help="seconds to wait after SIGABRT before SIGKILL")
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="command to run (prefix with --)")
    args = ap.parse_args(argv)
    cmd = args.cmd[1:] if args.cmd and args.cmd[0] == "--" else args.cmd
    if not cmd:
        ap.error("no command given")

    env = dict(os.environ, PYTHONFAULTHANDLER="1")
    proc = subprocess.Popen(cmd, env=env, start_new_session=True)
    deadline = time.monotonic() + args.timeout
    try:
        return proc.wait(timeout=args.timeout)
    except subprocess.TimeoutExpired:
        pass
    print(
        f"\n[run_with_timeout] command exceeded {args.timeout:.0f}s: "
        f"{' '.join(cmd)}\n[run_with_timeout] sending SIGABRT for a "
        "faulthandler traceback dump...",
        file=sys.stderr, flush=True,
    )
    try:
        os.killpg(proc.pid, signal.SIGABRT)
        proc.wait(timeout=args.grace)
    except (subprocess.TimeoutExpired, ProcessLookupError):
        try:
            os.killpg(proc.pid, signal.SIGKILL)
        except ProcessLookupError:
            pass
        proc.wait()
    print(
        f"[run_with_timeout] killed after "
        f"{time.monotonic() - deadline + args.timeout:.0f}s",
        file=sys.stderr, flush=True,
    )
    return 124


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

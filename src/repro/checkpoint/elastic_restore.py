"""Elastic restore: load a checkpoint onto a *different* mesh.

After a node failure the :class:`~repro.core.elastic.ElasticMeshManager`
produces a smaller mesh; the checkpoint holds full (unsharded) host
arrays, so restoring is: build the new mesh's shardings from the same
logical rules and ``jax.device_put`` each global array with its new
sharding.  DP-degree changes also rescale the data-pipeline shard count
and (optionally) the LR, both returned in the plan summary.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax

from ..core.elastic import RescalePlan
from ..parallel.mesh_rules import MeshRules

__all__ = ["reshard_tree", "elastic_restore_summary"]


def reshard_tree(host_tree, specs_tree, shapes_tree, rules: MeshRules):
    """Place host (global) arrays onto the mesh with rule-derived shardings."""
    shardings = rules.tree_shardings(specs_tree, shapes_tree)
    return jax.tree.map(
        lambda arr, sh: jax.device_put(arr, sh), host_tree, shardings
    )


def elastic_restore_summary(plan: RescalePlan, *, old_lr: float) -> Dict[str, Any]:
    """Bookkeeping deltas after a rescale: linear-scaled LR and the new
    data-shard count (stateless data pipeline keys on these)."""
    return {
        "new_mesh_shape": plan.new_shape,
        "dp_scale": plan.dp_scale,
        "new_lr": old_lr * plan.dp_scale,
        "lost_devices": list(plan.lost_devices),
        "needs_reshard": plan.needs_reshard,
    }

"""Checkpoint-backed coverage: resume a parallel_for after fleet death.

The transport/engine layers already survive *one worker* dying mid-run
(exact-once requeue to survivors).  This module covers the failure mode
above that: the whole run dies — driver crash, job preemption, every
worker gone — and a restart should pay only for the items that were
never finished, not recompute the full pre-split.

The checkpoint payload is deliberately a **fixed-shape done-bitmap**
(one ``bool`` per item of the original space), not a list of remaining
spans: :meth:`repro.checkpoint.Checkpointer.restore` verifies shapes
against a ``like_tree``, and a bitmap of ``num_items`` bools has the
same shape at every step no matter how coverage is distributed — so the
existing integrity-verified restore path works unmodified.

:func:`checkpointed_parallel_for` runs the space in *rounds*: take the
next slab of not-yet-done items, run one ``parallel_for`` over a
compact space remapped onto those global items, mark the bitmap, save
it asynchronously, repeat.  Within a round, worker loss is the engine's
exact-once problem; across process death, the latest bitmap bounds the
recompute to at most one round.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import numpy as np

from .checkpointer import Checkpointer

__all__ = [
    "CheckpointedRun",
    "CoverageMap",
    "checkpointed_parallel_for",
    "load_coverage",
    "save_coverage",
]

_TREE_KEY = "coverage_done"


class CoverageMap:
    """A done-bitmap over a flat item space ``[0, num_items)``."""

    def __init__(self, num_items: int,
                 done: Optional[np.ndarray] = None) -> None:
        if num_items <= 0:
            raise ValueError(f"num_items must be positive, got {num_items}")
        if done is None:
            done = np.zeros(num_items, dtype=bool)
        else:
            done = np.asarray(done, dtype=bool)
            if done.shape != (num_items,):
                raise ValueError(
                    f"done bitmap has shape {done.shape}, "
                    f"want ({num_items},)"
                )
            done = done.copy()
        self.num_items = int(num_items)
        self.done = done

    def mark(self, start: int, stop: int) -> None:
        if not (0 <= start <= stop <= self.num_items):
            raise ValueError(
                f"span [{start}, {stop}) outside [0, {self.num_items})"
            )
        self.done[start:stop] = True

    def mark_ids(self, ids: np.ndarray) -> None:
        self.done[np.asarray(ids, dtype=np.int64)] = True

    @property
    def items_done(self) -> int:
        return int(self.done.sum())

    @property
    def complete(self) -> bool:
        return bool(self.done.all())

    def remaining_ids(self) -> np.ndarray:
        """Global indices still uncovered, ascending."""
        return np.flatnonzero(~self.done)

    def remaining_spans(self) -> List[Tuple[int, int]]:
        """Uncovered items as maximal contiguous ``(start, stop)`` spans."""
        ids = self.remaining_ids()
        if ids.size == 0:
            return []
        breaks = np.flatnonzero(np.diff(ids) > 1)
        starts = np.concatenate(([0], breaks + 1))
        stops = np.concatenate((breaks, [ids.size - 1]))
        return [(int(ids[a]), int(ids[b]) + 1)
                for a, b in zip(starts, stops)]

    def tree(self) -> dict:
        """The checkpoint payload (fixed shape at every step)."""
        return {_TREE_KEY: self.done.copy()}


def save_coverage(ckpt: Checkpointer, step: int, cov: CoverageMap,
                  *, blocking: bool = False):
    """Async-save the bitmap through the standard checkpointer (tmp +
    atomic rename + manifest hashes); returns the completion event."""
    return ckpt.save(step, cov.tree(), blocking=blocking)


def load_coverage(ckpt: Checkpointer,
                  num_items: int) -> Optional[Tuple[CoverageMap, int]]:
    """The latest saved bitmap and its step, or None with no checkpoint.

    Restores through the verifying path against a fixed-shape
    ``like_tree``, so a bitmap saved for a *different* space size fails
    loudly instead of silently resuming the wrong run.
    """
    step = ckpt.latest_step()
    if step is None:
        return None
    like = CoverageMap(num_items).tree()
    tree, got_step = ckpt.restore(step, like)
    return CoverageMap(num_items, done=tree[_TREE_KEY]), int(got_step)


@dataclass
class CheckpointedRun:
    """What a :func:`checkpointed_parallel_for` call actually did."""

    num_items: int
    items_run: int          # items executed by THIS call (not restored ones)
    resumed_from_step: Optional[int]
    resumed_items_done: int  # items the restored bitmap already covered
    rounds: int
    last_step: int
    reports: List[object] = field(default_factory=list)  # per-round RunReport

    @property
    def resumed(self) -> bool:
        return self.resumed_from_step is not None


class _RemappedWork:
    """Compact-space chunk -> global-item spans -> the user's work_fn.

    A round's scheduler runs over ``[0, len(ids))``; this adapter turns
    each compact chunk into the (possibly several) contiguous global
    spans it covers and invokes the user's work function once per span,
    so user code only ever sees real item indices.
    """

    def __init__(self, work_fn: Callable, ids: np.ndarray) -> None:
        from repro.core.scheduler import Chunk
        self._chunk_cls = Chunk
        self.work_fn = work_fn
        self.ids = ids

    def __call__(self, chunk) -> None:
        gids = self.ids[chunk.start:chunk.stop]
        if gids.size == 0:
            return
        breaks = np.flatnonzero(np.diff(gids) > 1)
        starts = np.concatenate(([0], breaks + 1))
        stops = np.concatenate((breaks, [gids.size - 1]))
        for a, b in zip(starts, stops):
            self.work_fn(self._chunk_cls(start=int(gids[a]),
                                         stop=int(gids[b]) + 1,
                                         worker=chunk.worker))


def checkpointed_parallel_for(
    runtime,
    work_fn: Callable,
    num_items: int,
    *,
    checkpointer: Checkpointer,
    round_items: Optional[int] = None,
    resume: bool = True,
    **parallel_for_kwargs,
) -> CheckpointedRun:
    """``parallel_for`` with coverage checkpointing between rounds.

    The space is processed in rounds of at most ``round_items``
    (default: one quarter of the remainder, min 1 — four checkpoints
    for a fresh run).  After each round the bitmap is saved
    asynchronously at ``step = items_done`` (monotone by construction,
    so ``latest_step`` is also "most covered").  With ``resume=True``
    (the default) a compatible existing checkpoint seeds the bitmap and
    only the remaining items run; ``resume=False`` starts from zero.

    Remaining keyword arguments pass straight to
    :meth:`~repro.core.runtime.HeteroRuntime.parallel_for` (``policy``,
    ``acc_chunk``, ``engine``, ``backend`` ...).  ``item_cost`` under a
    SimulatedClock is remapped per round onto the surviving items.
    ``space``/``elastic`` are not supported here: rounds redefine the
    space, and a membership timeline's run-relative times would silently
    rebase every round.
    """
    for bad in ("space", "elastic", "num_items"):
        if bad in parallel_for_kwargs:
            raise ValueError(
                f"checkpointed_parallel_for does not accept {bad!r}"
            )
    item_cost = parallel_for_kwargs.pop("item_cost", None)
    if item_cost is not None and len(item_cost) != num_items:
        raise ValueError(
            f"item_cost has {len(item_cost)} entries for {num_items} items"
        )

    cov = CoverageMap(num_items)
    resumed_step: Optional[int] = None
    if resume:
        loaded = load_coverage(checkpointer, num_items)
        if loaded is not None:
            cov, resumed_step = loaded
    resumed_done = cov.items_done

    reports: List[object] = []
    items_run = 0
    rounds = 0
    last_step = resumed_step if resumed_step is not None else 0
    default_round = max((num_items - resumed_done + 3) // 4, 1)
    per_round = round_items if round_items is not None else default_round
    if per_round < 1:
        raise ValueError(f"round_items must be >= 1, got {per_round}")

    while not cov.complete:
        ids = cov.remaining_ids()[:per_round]
        kw = dict(parallel_for_kwargs)
        if item_cost is not None:
            kw["item_cost"] = [float(item_cost[int(g)]) for g in ids]
        report = runtime.parallel_for(
            _RemappedWork(work_fn, ids),
            num_items=int(ids.size),
            **kw,
        )
        cov.mark_ids(ids)
        items_run += int(ids.size)
        rounds += 1
        last_step = cov.items_done
        save_coverage(checkpointer, last_step, cov)
        reports.append(report)
    checkpointer.wait_all()

    return CheckpointedRun(
        num_items=num_items,
        items_run=items_run,
        resumed_from_step=resumed_step,
        resumed_items_done=resumed_done,
        rounds=rounds,
        last_step=last_step,
        reports=reports,
    )

"""Fault-tolerant checkpointing: async save, integrity-verified restore,
elastic (mesh-changing) restore, and coverage bitmaps that let a dead
run resume from its last checkpoint instead of recomputing."""

from .checkpointer import Checkpointer, CheckpointInfo
from .coverage import (
    CheckpointedRun,
    CoverageMap,
    checkpointed_parallel_for,
    load_coverage,
    save_coverage,
)
from .elastic_restore import elastic_restore_summary, reshard_tree

__all__ = [
    "Checkpointer",
    "CheckpointInfo",
    "reshard_tree",
    "elastic_restore_summary",
    "CoverageMap",
    "CheckpointedRun",
    "checkpointed_parallel_for",
    "save_coverage",
    "load_coverage",
]

"""Fault-tolerant checkpointing: async save, integrity-verified restore,
elastic (mesh-changing) restore."""

from .checkpointer import Checkpointer, CheckpointInfo
from .elastic_restore import elastic_restore_summary, reshard_tree

__all__ = ["Checkpointer", "CheckpointInfo", "reshard_tree", "elastic_restore_summary"]

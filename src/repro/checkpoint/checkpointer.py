"""Fault-tolerant asynchronous checkpointing.

ENEAC's interrupt discipline applied to state persistence: the training
loop never blocks on serialization.  ``save()`` snapshots device arrays to
host (the only synchronous part), hands the write to a background thread,
and returns; the completion event fires when the manifest is durably on
disk.  Restart-safety comes from write-to-temp + atomic rename + manifest
integrity hashes; the newest *complete* checkpoint wins at restore, so a
mid-write crash falls back to the previous step.

Layout (one directory per step):
    <dir>/step_000100.tmp/...      (in-flight)
    <dir>/step_000100/
        manifest.json              {step, tree structure, shapes, hashes}
        arr_00000.npy ...          one file per leaf
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from ..core.interrupts import CompletionEvent

__all__ = ["Checkpointer", "CheckpointInfo"]


@dataclasses.dataclass(frozen=True)
class CheckpointInfo:
    step: int
    path: Path
    wall_time: float


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(p), v) for p, v in flat]


class Checkpointer:
    def __init__(self, directory: str | Path, *, keep: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: List[threading.Thread] = []

    # ------------------------------------------------------------------
    # save
    # ------------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = False) -> CompletionEvent:
        """Async checkpoint; returns the completion event (interrupt analogue)."""
        # device→host snapshot must happen before training mutates buffers
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        done = CompletionEvent()
        t = threading.Thread(
            target=self._write, args=(step, host_tree, done),
            name=f"ckpt-{step}", daemon=True,
        )
        with self._lock:
            self._pending.append(t)
        t.start()
        if blocking:
            done.wait()
        return done

    def _write(self, step: int, host_tree, done: CompletionEvent) -> None:
        t0 = time.perf_counter()
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves = _tree_paths(host_tree)
        manifest = {"step": step, "leaves": []}
        for i, (path, arr) in enumerate(leaves):
            arr = np.asarray(arr)
            fname = f"arr_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {
                    "path": path,
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
                }
            )
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()
        done.fire(CheckpointInfo(step=step, path=final,
                                 wall_time=time.perf_counter() - t0))

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("step_????????"))
        for old in ckpts[: max(0, len(ckpts) - self.keep)]:
            shutil.rmtree(old, ignore_errors=True)

    def wait_all(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, []
        for t in pending:
            t.join()

    # ------------------------------------------------------------------
    # restore
    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("step_????????"))
        for c in reversed(ckpts):
            if (c / "manifest.json").exists():
                return int(c.name.split("_")[1])
        return None

    def restore(self, step: Optional[int], like_tree, *, verify: bool = True):
        """Restore into the structure of ``like_tree`` (host numpy leaves).

        Shape mismatches raise — elastic reshard (different mesh) goes
        through :mod:`repro.checkpoint.elastic_restore`, which operates on
        the global arrays this produces.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = self.dir / f"step_{step:08d}"
        with open(path / "manifest.json") as f:
            manifest = json.load(f)
        by_path = {m["path"]: m for m in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
        out = []
        for kp, like in flat:
            key = jax.tree_util.keystr(kp)
            meta = by_path.get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(path / meta["file"])
            if verify:
                h = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
                if h != meta["sha256"]:
                    raise IOError(f"checksum mismatch for {key} in step {step}")
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != expected {like.shape}"
                )
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]

"""Optimizer substrate: AdamW, schedules, clipping, gradient compression."""

from .adamw import AdamW, AdamWState
from .clipping import clip_by_global_norm, global_norm
from .schedule import constant, warmup_cosine, warmup_linear_decay

__all__ = [
    "AdamW",
    "AdamWState",
    "clip_by_global_norm",
    "global_norm",
    "warmup_cosine",
    "warmup_linear_decay",
    "constant",
]

"""AdamW with multi-precision state and decoupled weight decay.

Pure-functional (optax-style): ``init(params) -> state``,
``update(grads, state, params, lr) -> (updates, state)``.  Moments are kept
in fp32 regardless of param dtype (bf16 training standard); the returned
updates are cast back to the param dtype.  State shardings mirror the
param shardings (ZeRO-style: FSDP-sharded params ⇒ FSDP-sharded moments),
which `parallel/sharding.py` wires automatically since state is a pytree
with the same structure as params.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "AdamW"]


class AdamWState(NamedTuple):
    step: jax.Array       # () int32
    mu: object            # pytree like params (fp32)
    nu: object            # pytree like params (fp32)


class AdamW:
    def __init__(
        self,
        b1: float = 0.9,
        b2: float = 0.95,
        eps: float = 1e-8,
        weight_decay: float = 0.1,
        *,
        decay_mask=None,       # fn(path_tuple, leaf) -> bool; default: ndim >= 2
        state_dtype=jnp.float32,   # bf16 moments halve optimizer HBM (grok-scale)
    ) -> None:
        self.b1, self.b2, self.eps, self.wd = b1, b2, eps, weight_decay
        self.decay_mask = decay_mask or (lambda path, x: x.ndim >= 2)
        self.state_dtype = state_dtype

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, self.state_dtype), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def abstract_state(self, abstract_params) -> AdamWState:
        z = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, self.state_dtype), abstract_params
        )
        return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=z, nu=z)

    def state_specs(self, param_specs) -> AdamWState:
        """Logical-axes tree for the optimizer state (mirrors params)."""
        return AdamWState(step=(), mu=param_specs, nu=jax.tree.map(
            lambda s: s, param_specs,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)))

    def update(self, grads, state: AdamWState, params, lr) -> Tuple[object, AdamWState]:
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(path, g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = mf / c1
            vhat = vf / c2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.wd and self.decay_mask(path, p):
                u = u + self.wd * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

        flat = jax.tree_util.tree_flatten_with_path(grads)[0]
        paths = [p for p, _ in flat]
        gl = [g for _, g in flat]
        ml = jax.tree.leaves(state.mu)
        vl = jax.tree.leaves(state.nu)
        pl = jax.tree.leaves(params)
        outs = [upd(path, g, m, v, p) for path, g, m, v, p in zip(paths, gl, ml, vl, pl)]
        treedef = jax.tree.structure(grads)
        updates = jax.tree.unflatten(treedef, [o[0] for o in outs])
        mu = jax.tree.unflatten(treedef, [o[1] for o in outs])
        nu = jax.tree.unflatten(treedef, [o[2] for o in outs])
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    @staticmethod
    def apply_updates(params, updates):
        return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)

"""Int8 error-feedback gradient compression for the data-parallel axis.

On 1000+-node jobs the DP gradient reduction is the dominant cross-slice
collective.  Quantizing gradients to int8 with per-tensor scales cuts those
bytes 4× (bf16→int8×2 for the scale overhead ≈ ~2×–4×); the residual
(quantization error) is fed back into the next step's gradient so the
*accumulated* update is unbiased (error-feedback / EF-SGD, standard in
gradient-compression literature).

This composes with the ENEAC view: the DP all-reduce is the "data port"
between compute units, and compression is the HP→HPC-style port upgrade —
same schedule, fewer bytes on the wire.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "init_state", "compress", "decompress", "ef_compress_tree"]


class CompressionState(NamedTuple):
    residual: object   # pytree like grads (fp32 error feedback)


def init_state(params) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def abstract_state(abstract_params) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params
        )
    )


def compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """fp → (int8 values, fp32 scale).  Symmetric per-tensor quantization."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, state: CompressionState):
    """Apply error-feedback quantization to every leaf.

    Returns (quantized-but-dequantized grads ready for the reduction,
    new state carrying the residuals).  The caller reduces the returned
    grads over DP; on the wire the int8+scale pair is what moves (XLA int8
    all-reduce), here represented by the dequantized values so the math
    stays exact w.r.t. what the wire format preserves.
    """

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = compress(gf)
        deq = decompress(q, s)
        return deq.astype(g.dtype), gf - deq

    pairs = jax.tree.map(one, grads, state.residual)
    deq = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return deq, CompressionState(residual=res)

"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state.  The single-pod production mesh is
16×16 = 256 chips ("data", "model"); the multi-pod mesh adds a leading
"pod" axis: 2×16×16 = 512 chips.  The dry-run uses
``--xla_force_host_platform_device_count=512`` placeholder devices; real
deployments get the same shapes from the TPU runtime.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "HardwareSpec", "TPU_V5E"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2), axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for CPU tests (requires host-device-count override)."""
    return jax.make_mesh(shape, axes)


class HardwareSpec:
    """Roofline constants for the target part."""

    def __init__(self, name: str, peak_flops: float, hbm_bw: float, ici_bw: float,
                 hbm_bytes: float, vmem_bytes: float) -> None:
        self.name = name
        self.peak_flops = peak_flops      # FLOP/s bf16 per chip
        self.hbm_bw = hbm_bw              # bytes/s per chip
        self.ici_bw = ici_bw              # bytes/s per link
        self.hbm_bytes = hbm_bytes        # capacity per chip
        self.vmem_bytes = vmem_bytes


# Assignment-mandated constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

"""Jittable training / serving steps + their sharding resolution.

``make_train_step`` returns the full production step (fwd + bwd + clip +
AdamW + apply) plus the in/out shardings resolved from the mesh rules —
the exact object the dry-run lowers and the trainer executes.

``make_decode_step`` / ``make_prefill_step`` are the serving analogues.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig
from ..models import Model
from ..optim import AdamW, clip_by_global_norm
from ..parallel.mesh_rules import MeshRules, use_rules

__all__ = ["TrainStepBundle", "make_train_step", "make_decode_step", "make_prefill_step",
           "batch_shardings"]

GRAD_CLIP = 1.0


def _batch_specs(cfg: ModelConfig, kind: str) -> Dict[str, tuple]:
    specs = {}
    if kind == "train":
        specs = {"tokens": ("act_batch", None), "labels": ("act_batch", None),
                 "mask": ("act_batch", None)}
    elif kind == "prefill":
        specs = {"tokens": ("act_batch", None)}
    if cfg.family == "encdec" and kind in ("train", "prefill"):
        specs["frames"] = ("act_batch", None, "act_embed")
    if cfg.family == "vlm" and kind in ("train", "prefill"):
        specs["image_embeds"] = ("act_batch", None, "act_embed")
    return specs


def batch_shardings(model: Model, shape: InputShape, rules: MeshRules):
    """NamedShardings for the input batch of a given shape."""
    specs = _batch_specs(model.cfg, shape.kind)
    abstract = model.input_specs(shape)
    if shape.kind in ("train", "prefill"):
        return {
            k: rules.sharding(specs[k], abstract["batch"][k].shape) for k in specs
        }
    raise ValueError("decode shardings are handled by make_decode_step")


class TrainStepBundle:
    """Everything needed to lower/execute one training step."""

    def __init__(self, step_fn, in_shardings, out_shardings, donate_argnums):
        self.step_fn = step_fn
        self.in_shardings = in_shardings
        self.out_shardings = out_shardings
        self.donate_argnums = donate_argnums

    def jit(self):
        return jax.jit(
            self.step_fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )


def default_microbatches(cfg: ModelConfig, shape: InputShape, rules: MeshRules,
                         *, target_tokens_per_device: int = 8192) -> int:
    """Pick the grad-accum count so one microbatch's activations fit HBM.

    The microbatches ARE the ENEAC iteration space: the hetero trainer
    assigns different counts per DP group (see core/hetero.py); this picks
    the homogeneous default.
    """
    if cfg.parallel.microbatches > 1:
        return cfg.parallel.microbatches
    dp = 1
    for ax in ("pod", "data"):
        if ax in rules.mesh.axis_names:
            dp *= rules.mesh.shape[ax]
    tokens_per_device = shape.global_batch * shape.seq_len // dp
    mb = max(1, tokens_per_device // target_tokens_per_device)
    # microbatch must divide the per-DP-group batch
    per_group = max(1, shape.global_batch // dp)
    while per_group % mb and mb > 1:
        mb -= 1
    return mb


def make_train_step(
    model: Model,
    optimizer: AdamW,
    rules: MeshRules,
    shape: InputShape,
    *,
    lr: float = 3e-4,
    loss_chunk: int = 1024,
    microbatches: Optional[int] = None,
) -> TrainStepBundle:
    cfg = model.cfg
    mb = microbatches if microbatches is not None else default_microbatches(cfg, shape, rules)
    # each microbatch must still shard over the full DP extent
    dp = 1
    for ax in ("pod", "data"):
        if ax in rules.mesh.axis_names:
            dp *= rules.mesh.shape[ax]
    while mb > 1 and (shape.global_batch % mb or (shape.global_batch // mb) % dp):
        mb -= 1

    def one_loss(params, batch):
        return model.loss_fn(params, batch, loss_chunk=loss_chunk)

    def train_step(params, opt_state, batch):
        with use_rules(rules):
            if mb > 1:
                # gradient accumulation over the ENEAC microbatch chunks
                def split(x):
                    b = x.shape[0]
                    return x.reshape(mb, b // mb, *x.shape[1:])

                acc_dtype = (
                    jnp.bfloat16
                    if cfg.parallel.grad_accum_dtype == "bfloat16"
                    else jnp.float32
                )
                mbatch = {k: split(v) for k, v in batch.items()}
                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                                  params)
                m0 = {"loss": jnp.zeros(()), "ce_loss": jnp.zeros(())}

                def acc_body(carry, xs):
                    gacc, macc = carry
                    (loss, metrics), grads = jax.value_and_grad(
                        one_loss, has_aux=True)(params, xs)
                    gacc = jax.tree.map(
                        lambda a, g: a + (g.astype(a.dtype) / mb), gacc, grads)
                    macc = {k: macc[k] + metrics[k] / mb for k in macc}
                    return (gacc, macc), 0.0

                (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), mbatch)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    one_loss, has_aux=True)(params, batch)
                metrics = {"loss": metrics["loss"], "ce_loss": metrics["ce_loss"]}
            grads, gnorm = clip_by_global_norm(grads, GRAD_CLIP)
            updates, opt_state = optimizer.update(grads, opt_state, params,
                                                  jnp.asarray(lr, jnp.float32))
            params = AdamW.apply_updates(params, updates)
            metrics = dict(metrics)
            metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    aparams = model.abstract_params()
    pspecs = model.param_specs()
    p_sh = rules.tree_shardings(pspecs, aparams)
    astate = optimizer.abstract_state(aparams)
    sspecs = optimizer.state_specs(pspecs)
    o_sh = jax.tree.map(
        lambda axes, sds: rules.sharding(axes, sds.shape),
        sspecs,
        astate,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )
    b_sh = batch_shardings(model, shape, rules)
    metric_sh = None  # replicated scalars; let XLA infer
    return TrainStepBundle(
        step_fn=train_step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, metric_sh),
        donate_argnums=(0, 1),
    )


def make_decode_step(model: Model, rules: MeshRules, shape: InputShape) -> TrainStepBundle:
    """serve_step: one new token against a seq_len-sized cache."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len

    def serve_step(params, tokens, positions, caches):
        with use_rules(rules):
            logits, new_caches = model.decode_step(params, tokens, positions, caches)
        return logits, new_caches

    aparams = model.abstract_params()
    p_sh = rules.tree_shardings(model.param_specs(), aparams)
    acaches = model.abstract_caches(B, S)
    cspecs = model.cache_specs(B, S)
    c_sh = jax.tree.map(
        lambda axes, sds: rules.sharding(axes, sds.shape),
        cspecs,
        acaches,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )
    tok_sh = rules.sharding(("act_batch", None), (B, 1))
    logit_sh = rules.sharding(("act_batch", "act_vocab"), (B, cfg.padded_vocab))
    return TrainStepBundle(
        step_fn=serve_step,
        in_shardings=(p_sh, tok_sh, tok_sh, c_sh),
        out_shardings=(logit_sh, c_sh),
        donate_argnums=(3,),
    )


def make_prefill_step(model: Model, rules: MeshRules, shape: InputShape) -> TrainStepBundle:
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len

    def prefill_step(params, batch):
        with use_rules(rules):
            logits, caches = model.prefill(params, batch, max_len=S)
        return logits, caches

    aparams = model.abstract_params()
    p_sh = rules.tree_shardings(model.param_specs(), aparams)
    b_sh = batch_shardings(model, shape, rules)
    acaches = model.abstract_caches(B, S)
    cspecs = model.cache_specs(B, S)
    c_sh = jax.tree.map(
        lambda axes, sds: rules.sharding(axes, sds.shape),
        cspecs,
        acaches,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )
    logit_sh = rules.sharding(("act_batch", "act_vocab"), (B, cfg.padded_vocab))
    return TrainStepBundle(
        step_fn=prefill_step,
        in_shardings=(p_sh, b_sh),
        out_shardings=(logit_sh, c_sh),
        donate_argnums=(),
    )

"""Launchers: production mesh, multi-pod dry-run, trainer, server, perf.

NOTE: ``dryrun`` and ``perf`` set XLA_FLAGS on import (512 placeholder
devices) and must be imported only as entry points, never from library
code — everything else here is import-safe.
"""

from .mesh import TPU_V5E, HardwareSpec, make_production_mesh, make_test_mesh

__all__ = ["make_production_mesh", "make_test_mesh", "HardwareSpec", "TPU_V5E"]

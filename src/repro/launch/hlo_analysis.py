"""Post-optimization HLO analysis: loop-corrected FLOPs, HBM traffic, and
collective bytes — the three roofline numerators.

Why this exists: ``compiled.cost_analysis()`` visits a ``lax.scan``'s while
body ONCE (verified empirically on this jax build), so any scanned-layer
model under-reports FLOPs/bytes by ~num_layers×.  This module parses
``compiled.as_text()`` instead:

1. builds the computation call graph (entry → while bodies → fusions),
2. extracts while-loop trip counts from the loop condition's comparison
   constant (scan lowers to ``compare(induction_var, constant(N)), LT``),
3. multiplies per-op costs by the product of enclosing trip counts:
   * **dot FLOPs** — 2 · |output| · contracted-dim product (fusion-resident
     dots inherit the fusion call site's multiplier),
   * **HBM traffic** — operand+output bytes *at fusion boundaries* (XLA's
     fusion is precisely the unit of HBM round-trips; ops inside fused
     computations move no HBM bytes),
   * **collective bytes** — operand bytes of all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute (+ their async
     ``-start`` forms), per device, post-SPMD.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["HloReport", "analyze_hlo", "COLLECTIVE_OPS"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> float:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class _Op:
    name: str
    out_type: str
    opcode: str
    operands: List[str]
    tail: str   # attribute text after the operand list


@dataclasses.dataclass
class _Computation:
    name: str
    ops: Dict[str, _Op]
    order: List[str]
    is_fusion: bool


@dataclasses.dataclass
class HloReport:
    dot_flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, float]
    collective_count: int
    trip_counts: Dict[str, int]
    notes: List[str]
    # top collective sources: (kind, operand-type, multiplier, total bytes)
    top_collectives: List[Tuple[str, str, float, float]] = dataclasses.field(
        default_factory=list
    )
    # top HBM-traffic sources: (opcode, out-type, multiplier, total bytes)
    top_traffic: List[Tuple[str, str, float, float]] = dataclasses.field(
        default_factory=list
    )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*\((.*?)\)\s*->\s*(.+?)\s*\{")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-_]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\/: ]+?))\s+([\w\-]+)\((.*)$"
)


def _split_operands(text: str) -> Tuple[List[str], str]:
    """Split 'a, b, c), attrs' respecting nesting → (operand names, tail)."""
    depth = 0
    for i, ch in enumerate(text):
        if ch == "(" or ch == "{" or ch == "[":
            depth += 1
        elif ch == "}" or ch == "]":
            depth -= 1
        elif ch == ")":
            if depth == 0:
                ops_text = text[:i]
                tail = text[i + 1:]
                names = []
                for tok in _iter_top_level(ops_text):
                    tok = tok.strip()
                    m = re.search(r"%([\w.\-_]+)\s*$", tok)
                    if m:
                        names.append(m.group(1))
                    else:
                        m2 = re.match(r"^([\w.\-_]+)$", tok)
                        if m2:
                            names.append(m2.group(1))
                return names, tail
            depth -= 1
    return [], text


def _iter_top_level(text: str):
    depth = 0
    cur = []
    for ch in text:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            yield "".join(cur)
            cur = []
        else:
            cur.append(ch)
    if cur:
        yield "".join(cur)


def _parse_module(text: str) -> Tuple[Dict[str, _Computation], Optional[str]]:
    comps: Dict[str, _Computation] = {}
    entry: Optional[str] = None
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.strip().endswith("{"):
                name = m.group(1)
                is_entry = line.strip().startswith("ENTRY")
                cur = _Computation(
                    name=name, ops={}, order=[],
                    is_fusion="fused_computation" in name,
                )
                if is_entry:
                    entry = name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, out_type, opcode, rest = m.groups()
            operands, tail = _split_operands(rest)
            cur.ops[name] = _Op(name, out_type.strip(), opcode, operands, tail)
            cur.order.append(name)
    return comps, entry


def _trip_count(cond: _Computation, body_name: str, notes: List[str]) -> int:
    """Scan conditions lower to ``compare(ind_var, constant(N)), LT`` — the
    largest integer constant in the condition computation is the bound."""
    consts = []
    for op in cond.ops.values():
        if op.opcode == "constant" and op.out_type.split("[")[0] in ("s32", "u32", "s64"):
            m = re.match(r"^\s*(\d+)", ",".join(op.operands) or "")
            if m:
                consts.append(int(m.group(1)))
    if not consts:
        notes.append(f"no trip count found for {body_name}; assuming 1")
        return 1
    return max(consts)


def _dot_flops(op: _Op, defs: Dict[str, str]) -> float:
    out_elems = _shape_elems(op.out_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.tail)
    if not m or not op.operands:
        return 2.0 * out_elems  # degenerate
    lhs_type = defs.get(op.operands[0], "")
    sm = _SHAPE_RE.search(lhs_type)
    if not sm or not sm.group(2):
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in sm.group(2).split(",")]
    contracted = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            contracted *= lhs_dims[idx]
    return 2.0 * out_elems * contracted


_SKIP_TRAFFIC = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "bitcast-convert", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "while", "conditional", "call",
}


def _op_traffic(op: _Op, defs: Dict[str, str], comps: Dict[str, "_Computation"]) -> float:
    """HBM bytes touched by one top-level op.

    Sliced accesses are charged at the *touched region*, not the resident
    buffer: a dynamic-slice of one layer out of a (L, d, ff) stack reads
    d·ff elements, and an in-place dynamic-update-slice writes the update
    region only (XLA aliases donated buffers).  Fusion operands that are
    only dynamic-sliced/gathered inside the fusion are likewise charged at
    their sliced size — this mirrors how the TPU actually streams from HBM.
    """
    out_b = _shape_bytes(op.out_type)
    if op.opcode == "dynamic-slice":
        return 2.0 * out_b                       # read slice + write result
    if op.opcode == "dynamic-update-slice":
        upd = _shape_bytes(defs.get(op.operands[1], "")) if len(op.operands) > 1 else out_b
        return 2.0 * upd                         # read-modify-write the slot
    if op.opcode == "gather":
        idx = _shape_bytes(defs.get(op.operands[1], "")) if len(op.operands) > 1 else 0.0
        return 2.0 * out_b + idx                 # random reads ≈ output size
    if op.opcode == "scatter":
        upd = _shape_bytes(defs.get(op.operands[2], "")) if len(op.operands) > 2 else out_b
        return 3.0 * upd                         # read+write slots + updates
    if op.opcode == "broadcast":
        return out_b
    if op.opcode == "fusion":
        b = out_b
        called = re.search(r"calls=%?([\w.\-_]+)", op.tail)
        fcomp = comps.get(called.group(1)) if called else None
        sliced_params = _fusion_sliced_params(fcomp) if fcomp else {}
        for i, o in enumerate(op.operands):
            if i in sliced_params:
                b += sliced_params[i]
            else:
                b += _shape_bytes(defs.get(o, ""))
        return b
    b = out_b
    for o in op.operands:
        b += _shape_bytes(defs.get(o, ""))
    return b


def _fusion_sliced_params(fcomp: "_Computation") -> Dict[int, float]:
    """Map fusion-parameter index → touched bytes, for params whose only
    uses inside the fusion are dynamic-slice / gather ops."""
    param_names: Dict[str, int] = {}
    for op in fcomp.ops.values():
        if op.opcode == "parameter":
            m = re.match(r"^\s*(\d+)", ",".join(op.operands) or "")
            if m:
                param_names[op.name] = int(m.group(1))
    uses: Dict[str, List[_Op]] = defaultdict(list)
    for op in fcomp.ops.values():
        for o in op.operands:
            if o in param_names:
                uses[o].append(op)
    out: Dict[int, float] = {}
    for pname, idx in param_names.items():
        ops = uses.get(pname, [])
        if ops and all(
            u.opcode in ("dynamic-slice", "gather") and u.operands and u.operands[0] == pname
            for u in ops
        ):
            out[idx] = sum(_shape_bytes(u.out_type) for u in ops)
    return out


def analyze_hlo(text: str, *, trip_count_hints: Optional[Dict[str, int]] = None) -> HloReport:
    comps, entry = _parse_module(text)
    notes: List[str] = []
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else ""
        notes.append("no ENTRY found; using largest computation")

    # defs: op name -> out type (global; HLO op names are unique per module)
    defs: Dict[str, str] = {}
    for c in comps.values():
        for op in c.ops.values():
            defs[op.name] = op.out_type

    # multipliers via worklist from entry
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    trip_counts: Dict[str, int] = {}
    work = [entry]
    seen_edges = set()
    while work:
        cname = work.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops.values():
            called: List[Tuple[str, float]] = []
            if op.opcode == "while":
                cm = re.search(r"condition=%?([\w.\-_]+)", op.tail)
                bm = re.search(r"body=%?([\w.\-_]+)", op.tail)
                if bm:
                    body = bm.group(1)
                    tc = (trip_count_hints or {}).get(body)
                    if tc is None and cm and cm.group(1) in comps:
                        tc = _trip_count(comps[cm.group(1)], body, notes)
                    tc = tc or 1
                    trip_counts[body] = tc
                    called.append((body, m * tc))
                    if cm:
                        called.append((cm.group(1), 0.0))  # condition: negligible
            else:
                for attr in ("calls", "to_apply", "branch_computations",
                             "true_computation", "false_computation"):
                    mm = re.search(attr + r"=\{?%?([\w.\-_,% ]+)\}?", op.tail)
                    if mm:
                        for nm in re.findall(r"%?([\w.\-_]+)", mm.group(1)):
                            if nm in comps:
                                called.append((nm, m))
            for nm, nmult in called:
                mult[nm] += nmult
                edge = (cname, nm)
                if edge not in seen_edges:
                    seen_edges.add(edge)
                    work.append(nm)

    dot_flops = 0.0
    hbm = 0.0
    coll_bytes = 0.0
    coll_by_kind: Dict[str, float] = defaultdict(float)
    coll_count = 0
    coll_sources: List[Tuple[str, str, float, float]] = []
    traffic_sources: List[Tuple[str, str, float, float]] = []

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        for op in comp.ops.values():
            base = op.opcode.replace("-start", "")
            if op.opcode.endswith("-done"):
                continue
            if base in COLLECTIVE_OPS:
                b = sum(_shape_bytes(defs.get(o, "")) for o in op.operands)
                if b == 0:
                    b = _shape_bytes(op.out_type)
                # XLA:CPU's float-normalization pass promotes bf16
                # all-reduces to f32 ("..._promoted" reducers) because the
                # host backend lacks native bf16 arithmetic; the TPU target
                # reduces in bf16, so count promoted reductions at their
                # pre-promotion width.
                if "promoted" in op.tail:
                    b *= 0.5
                coll_bytes += m * b
                coll_by_kind[base] += m * b
                coll_count += int(m) if m >= 1 else 1
                opnd = defs.get(op.operands[0], op.out_type) if op.operands else op.out_type
                coll_sources.append((base, opnd.strip(), m, m * b))
            if op.opcode in ("dot", "convolution"):
                dot_flops += m * _dot_flops(op, defs)
            # HBM traffic at fusion boundaries (skip inside fused comps)
            if not comp.is_fusion and op.opcode not in _SKIP_TRAFFIC:
                t = _op_traffic(op, defs, comps)
                hbm += m * t
                traffic_sources.append((op.opcode, op.out_type[:64], m, m * t))

    # dots inside fusions: count with the fusion's multiplier (handled above
    # since fused computations get mult from their call sites via "calls=")
    coll_sources.sort(key=lambda t: -t[3])
    traffic_sources.sort(key=lambda t: -t[3])
    return HloReport(
        dot_flops=dot_flops,
        hbm_bytes=hbm,
        collective_bytes=coll_bytes,
        collective_by_kind=dict(coll_by_kind),
        collective_count=coll_count,
        trip_counts=trip_counts,
        notes=notes,
        top_collectives=coll_sources[:12],
        top_traffic=traffic_sources[:12],
    )

"""Production training driver: ENEAC hetero microbatching + fault tolerance.

Wires every subsystem together:
  * mesh + rule-derived shardings          (parallel/)
  * jitted train step w/ grad accumulation (launch/steps.py)
  * async data prefetch                    (data/prefetch.py)
  * async checkpointing + restart          (checkpoint/)
  * straggler detection → microbatch rebalancing (core/straggler.py)
  * simulated failure → elastic rescale    (core/elastic.py)

Runs end-to-end on CPU with a small mesh for the examples/tests; the same
driver lowers unchanged on real pods (devices come from the runtime).

CLI:
  python -m repro.launch.train --arch tinyllama-1.1b --steps 50 \
      --global-batch 8 --seq-len 128 --smoke
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get_config
from ..configs.base import InputShape
from ..core.hetero import HeterogeneousPartitioner, ThroughputTracker
from ..core.straggler import StragglerDetector
from ..data import Prefetcher, SyntheticTokens
from ..models import make_model
from ..optim import AdamW, warmup_cosine
from ..checkpoint import Checkpointer
from ..parallel.mesh_rules import MeshRules
from .steps import make_train_step

__all__ = ["TrainLoopConfig", "run_training"]


@dataclasses.dataclass
class TrainLoopConfig:
    arch: str
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    warmup: int = 10
    smoke: bool = True                  # reduced model dims (CPU-runnable)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    resume: bool = False
    microbatches: int = 1
    log_every: int = 10
    seed: int = 0


def run_training(cfg: TrainLoopConfig, *, mesh=None) -> Dict[str, float]:
    model_cfg = get_config(cfg.arch)
    if cfg.smoke:
        model_cfg = model_cfg.smoke()
    model = make_model(model_cfg)
    shape = InputShape("custom", cfg.seq_len, cfg.global_batch, "train")

    if mesh is None:
        n = len(jax.devices())
        mesh = jax.make_mesh((n, 1), ("data", "model")) if n > 1 else \
            jax.make_mesh((1, 1), ("data", "model"))
    rules = MeshRules(mesh, model_cfg.parallel)

    optimizer = AdamW(
        state_dtype=jnp.bfloat16
        if model_cfg.parallel.opt_state_dtype == "bfloat16"
        else jnp.float32
    )
    bundle = make_train_step(
        model, optimizer, rules, shape, lr=cfg.lr,
        microbatches=cfg.microbatches, loss_chunk=0,
    )
    step_fn = bundle.jit()

    key = jax.random.PRNGKey(cfg.seed)
    params = model.init(key)
    opt_state = optimizer.init(params)
    start_step = 0

    ckpt = Checkpointer(cfg.ckpt_dir) if cfg.ckpt_dir else None
    if ckpt and cfg.resume and ckpt.latest_step() is not None:
        host_params = jax.tree.map(np.asarray, params)
        host_opt = jax.tree.map(np.asarray, opt_state)
        (restored_p, restored_o), start_step = ckpt.restore(
            None, (host_params, host_opt)
        )
        params = jax.tree.map(jnp.asarray, restored_p)
        opt_state = jax.tree.map(
            lambda o, r: jnp.asarray(r, o.dtype), opt_state, restored_o
        )

    source = SyntheticTokens(model_cfg.padded_vocab, cfg.seq_len, seed=cfg.seed)

    def make_batch(step: int):
        b = source.batch(step, shard=0, num_shards=1, per_shard=cfg.global_batch)
        return {
            "tokens": jnp.asarray(b.tokens),
            "labels": jnp.asarray(b.labels),
            "mask": jnp.asarray(b.mask),
        }

    prefetch = Prefetcher(make_batch, depth=2, start_step=start_step)
    detector = StragglerDetector()
    tracker = ThroughputTracker()

    losses = []
    t_start = time.perf_counter()
    with mesh:
        try:
            for step in range(start_step, cfg.steps):
                _, batch = prefetch.get()
                t0 = time.perf_counter()
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                tracker.update("pod0", cfg.global_batch * cfg.seq_len, dt)
                detector.observe({"pod0": dt})
                losses.append(loss)
                if step % cfg.log_every == 0 or step == cfg.steps - 1:
                    print(
                        f"step {step:5d}  loss {loss:.4f}  "
                        f"gnorm {float(metrics['grad_norm']):.3f}  "
                        f"{cfg.global_batch * cfg.seq_len / dt:,.0f} tok/s"
                    )
                if ckpt and (step + 1) % cfg.ckpt_every == 0:
                    ckpt.save(step + 1, (
                        jax.tree.map(np.asarray, params),
                        jax.tree.map(np.asarray, opt_state),
                    ))
        finally:
            prefetch.close()
            if ckpt:
                ckpt.wait_all()

    wall = time.perf_counter() - t_start
    return {
        "first_loss": losses[0],
        "final_loss": losses[-1],
        "mean_tok_per_s": cfg.steps * cfg.global_batch * cfg.seq_len / wall,
        "steps": len(losses),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    out = run_training(TrainLoopConfig(
        arch=args.arch, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, lr=args.lr, smoke=args.smoke,
        ckpt_dir=args.ckpt_dir, resume=args.resume,
        microbatches=args.microbatches,
    ))
    print({k: round(v, 4) if isinstance(v, float) else v for k, v in out.items()})


if __name__ == "__main__":
    main()

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: hypothesis → change → re-lower → measure.

Runs tagged dry-run variants for the three selected cells and prints the
roofline-term deltas.  Results land in experiments/dryrun/*__<tag>.json and
the summary feeds EXPERIMENTS.md §Perf.

Cells (selected from the baseline table):
  A. stablelm-12b × train_4k      — worst compute/bound fraction among
                                    dense trainers (memory-dominated)
  B. llama3.2-3b × prefill_32k    — the most collective-bound cell
  C. qwen3-moe-30b-a3b × train_4k — most representative of the paper's
                                    technique (irregular routing, capacity
                                    chunks, fallback path)

``python -m repro.launch.perf [--cell A|B|C]``
"""

import argparse
import dataclasses
import json
from pathlib import Path

from ..configs import get_config
from .dryrun import DEFAULT_OUT, run_cell

import re


def _parallel(cfg, **kw):
    return {"parallel": dataclasses.replace(cfg.parallel, **kw)}


def flash_substitution(rec: dict, cfg, shape_name: str, microbatches: int) -> dict:
    """Kernel-substitution analysis: replace the XLA online-softmax
    attention's HBM traffic with the Pallas flash kernel's structural
    traffic (Q+K+V+O once; K/V VMEM-resident — see kernels/flash_attention).

    The XLA attention-interior traffic is identified from the recorded
    top-traffic table: entries whose trailing dims are score blocks
    (q_chunk × kv_chunk).  That is a LOWER bound (only top-12 entries are
    recorded), so the reported gain is conservative.
    """
    from ..configs import SHAPES
    from ..kernels.flash_attention.ops import kernel_hbm_bytes

    shape = SHAPES[shape_name]
    interior = 0.0
    pat = re.compile(r"\[(?:\d+,)*(\d+),(\d+)\]")
    for opcode, typ, mult, tot in rec["hlo"]["top_traffic"]:
        m = pat.search(typ)
        if not m:
            continue
        a, b = int(m.group(1)), int(m.group(2))
        if a in (1024, 2048) and b in (1024, 2048, shape.seq_len):
            interior += tot
    # kernel traffic per device: all layers × microbatches, sharded by
    # (dp × tp) like the XLA path
    n_attn = cfg.attn_layer_count() if cfg.family == "hybrid" else cfg.num_layers
    dp = 16
    tp = 16
    per_mb_tokens = shape.global_batch * shape.seq_len // microbatches
    kern = n_attn * microbatches * kernel_hbm_bytes(
        1, per_mb_tokens // dp, per_mb_tokens // dp, cfg.num_heads // 1,
        cfg.num_kv_heads, cfg.head_dim,
        backward=(shape.kind == "train"),
    ) / tp
    hbm = rec["hlo"]["hbm_bytes_per_device"]
    adj = hbm - interior + kern
    return {
        "xla_attention_interior_bytes": interior,
        "kernel_bytes": kern,
        "memory_s_adjusted": adj / 819e9,
        "memory_s_before": rec["roofline"]["memory_s"],
    }


def show(label: str, rec: dict) -> None:
    r = rec["roofline"]
    m = rec["memory"]
    print(
        f"  {label:28s} c/m/x = {r['compute_s']:8.3f}/{r['memory_s']:8.3f}/"
        f"{r['collective_s']:8.3f} s  dom={r['dominant']:10s} "
        f"peak={m['peak_est_bytes'] / 2**30:5.1f}GiB useful={r['useful_flops_ratio']:.3f}"
    )


def cell_A(out: Path):
    print("=== Cell A: stablelm-12b × train_4k (memory-dominated dense train)")
    cfg = get_config("stablelm-12b")
    rec0 = run_cell("stablelm-12b", "train_4k", False, out, tag="perf-baseline")
    show("baseline", rec0)
    rec1 = run_cell("stablelm-12b", "train_4k", False, out,
                    overrides=_parallel(cfg, sequence_parallel=True), tag="perf-sp")
    show("+sequence-parallel", rec1)
    rec2 = run_cell("stablelm-12b", "train_4k", False, out,
                    overrides=_parallel(cfg, sequence_parallel=True,
                                        replicate_kv=True),
                    tag="perf-sp-kvrep")
    show("+replicate-kv", rec2)
    best = min((rec0, rec1, rec2), key=lambda r: r["roofline"]["bound_s"])
    sub = flash_substitution(best, cfg, "train_4k", 8)
    print(f"  flash-kernel substitution    m = {sub['memory_s_before']:.3f}s → "
          f"{sub['memory_s_adjusted']:.3f}s "
          f"(interior {sub['xla_attention_interior_bytes']/1e12:.2f} TB → "
          f"kernel {sub['kernel_bytes']/1e9:.1f} GB)")
    (out / "perf_cellA_flashsub.json").write_text(json.dumps(sub, indent=1))


def cell_B(out: Path):
    print("=== Cell B: llama3.2-3b × prefill_32k (most collective-bound)")
    cfg = get_config("llama3.2-3b")
    rec0 = run_cell("llama3.2-3b", "prefill_32k", False, out, tag="perf-baseline")
    show("baseline", rec0)
    rec1 = run_cell("llama3.2-3b", "prefill_32k", False, out,
                    overrides=_parallel(cfg, replicate_kv=True), tag="perf-kvrep")
    show("+replicate-kv", rec1)
    rec2 = run_cell("llama3.2-3b", "prefill_32k", False, out,
                    overrides=_parallel(cfg, replicate_kv=True,
                                        sequence_parallel=True),
                    tag="perf-kvrep-sp")
    show("+sequence-parallel", rec2)
    best = min((rec0, rec1, rec2), key=lambda r: r["roofline"]["bound_s"])
    sub = flash_substitution(best, cfg, "prefill_32k", 1)
    print(f"  flash-kernel substitution    m = {sub['memory_s_before']:.3f}s → "
          f"{sub['memory_s_adjusted']:.3f}s")
    (out / "perf_cellB_flashsub.json").write_text(json.dumps(sub, indent=1))


def cell_C(out: Path):
    print("=== Cell C: qwen3-moe-30b-a3b × train_4k (ENEAC-representative)")
    cfg = get_config("qwen3-moe-30b-a3b")
    rec0 = run_cell("qwen3-moe-30b-a3b", "train_4k", False, out,
                    overrides=_parallel(cfg, moe_dispatch="gspmd"),
                    tag="perf-gspmd")
    show("baseline (gspmd dispatch)", rec0)
    rec1 = run_cell("qwen3-moe-30b-a3b", "train_4k", False, out,
                    tag="perf-local")
    show("+shard_map local dispatch", rec1)
    rec2 = run_cell("qwen3-moe-30b-a3b", "train_4k", False, out,
                    overrides=_parallel(cfg, capacity_factor=1.0),
                    tag="perf-cap1.0")
    show("+capacity-factor 1.0", rec2)
    rec3 = run_cell("qwen3-moe-30b-a3b", "train_4k", False, out,
                    overrides=_parallel(cfg, moe_fallback=False),
                    tag="perf-nofallback")
    show("drop-overflow (no ENEAC CC)", rec3)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=("A", "B", "C"), default=None)
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    cells = {"A": cell_A, "B": cell_B, "C": cell_C}
    for k, fn in cells.items():
        if args.cell in (None, k):
            fn(args.out)


if __name__ == "__main__":
    main()

"""Serving driver: batched requests through the continuous-batching engine.

CLI:
  python -m repro.launch.serve --arch tinyllama-1.1b --requests 16 --slots 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import make_model
from ..serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--mode", choices=("continuous", "static"), default="continuous")
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.smoke()
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    engine = ServingEngine(model, params, slots=args.slots, max_len=args.max_len,
                           mode=args.mode)
    for i in range(args.requests):
        plen = int(rng.integers(4, 16))
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(4, args.max_new)),
        ))
    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0
    rep = engine.throughput_report()
    print(f"{len(results)} requests, {rep['tokens']} tokens, "
          f"{rep['steps']} decode steps, {rep['tokens_per_step']:.2f} tok/step, "
          f"{rep['tokens'] / wall:.1f} tok/s wall ({args.mode})")


if __name__ == "__main__":
    main()

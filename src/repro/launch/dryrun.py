import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and only the dry-run wants 512 placeholder host devices.

Per cell this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. resolves shardings from the mesh rules,
  3. ``jit(step).lower(**input_specs).compile()`` — any sharding mismatch,
     compile-time OOM, or unsupported collective fails the cell,
  4. records ``memory_analysis()`` / ``cost_analysis()`` plus the
     loop-corrected HLO report (FLOPs / HBM traffic / collective bytes)
     into ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod | --both-meshes]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import ARCH_NAMES, SHAPES, cell_status, get_config
from ..models import make_model
from ..optim import AdamW
from ..parallel.mesh_rules import MeshRules
from .hlo_analysis import analyze_hlo
from .mesh import TPU_V5E, make_production_mesh
from .steps import make_decode_step, make_prefill_step, make_train_step

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             *, overrides=None, tag: str = "") -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    runnable, reason = cell_status(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "tag": tag,
        "status": "skip",
        "reason": reason,
    }
    if not runnable:
        _write(out_dir, cell_id, rec)
        return rec

    model = make_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rules = MeshRules(mesh, cfg.parallel)

    t0 = time.time()
    import jax.numpy as jnp
    opt_dtype = jnp.bfloat16 if cfg.parallel.opt_state_dtype == "bfloat16" else jnp.float32
    with mesh:
        if shape.kind == "train":
            opt = AdamW(state_dtype=opt_dtype)
            bundle = make_train_step(model, opt, rules, shape)
            args = (
                model.abstract_params(),
                opt.abstract_state(model.abstract_params()),
                model.input_specs(shape)["batch"],
            )
        elif shape.kind == "prefill":
            bundle = make_prefill_step(model, rules, shape)
            args = (model.abstract_params(), model.input_specs(shape)["batch"])
        else:  # decode
            bundle = make_decode_step(model, rules, shape)
            spec = model.input_specs(shape)
            args = (model.abstract_params(), spec["tokens"], spec["positions"],
                    spec["caches"])
        lowered = bundle.jit().lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    rep = analyze_hlo(compiled.as_text())
    hw = TPU_V5E

    model_fl = model.model_flops(shape)
    flops_dev = rep.dot_flops
    compute_s = flops_dev / hw.peak_flops
    memory_s = rep.hbm_bytes / hw.hbm_bw
    collective_s = rep.collective_bytes / hw.ici_bw
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]

    rec.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        n_devices=n_dev,
        memory={
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_est_bytes": int(ma.argument_size_in_bytes + ma.temp_size_in_bytes),
            "hbm_capacity": int(hw.hbm_bytes),
            "fits": bool(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes < hw.hbm_bytes
            ),
        },
        cost_analysis={
            "flops_uncorrected": float(ca.get("flops", 0.0)),
            "bytes_accessed_uncorrected": float(ca.get("bytes accessed", 0.0)),
        },
        hlo={
            "dot_flops_per_device": flops_dev,
            "hbm_bytes_per_device": rep.hbm_bytes,
            "collective_bytes_per_device": rep.collective_bytes,
            "collective_by_kind": rep.collective_by_kind,
            "top_collectives": rep.top_collectives,
            "top_traffic": rep.top_traffic,
            "trip_counts": rep.trip_counts,
            "notes": rep.notes,
        },
        roofline={
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "bound_s": max(compute_s, memory_s, collective_s),
            "model_flops": model_fl,
            "useful_flops_ratio": model_fl / max(flops_dev * n_dev, 1.0),
        },
    )
    _write(out_dir, cell_id, rec)
    return rec


def _write(out_dir: Path, cell_id: str, rec: dict) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    with open(out_dir / f"{cell_id}.json", "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()

    cells = []
    archs = ARCH_NAMES if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        label = f"{a} × {s} × {'2x16x16' if mp else '16x16'}"
        try:
            rec = run_cell(a, s, mp, args.out)
            if rec["status"] == "skip":
                print(f"SKIP {label}: {rec['reason']}")
                continue
            r = rec["roofline"]
            fits = "fits" if rec["memory"]["fits"] else "OVER-HBM"
            print(
                f"OK   {label}: compile {rec['compile_s']}s, "
                f"peak {(rec['memory']['peak_est_bytes'])/2**30:.1f}GiB ({fits}), "
                f"terms c/m/x = {r['compute_s']:.3f}/{r['memory_s']:.3f}/"
                f"{r['collective_s']:.3f}s → {r['dominant']}"
            )
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            failures += 1
            print(f"FAIL {label}: {type(e).__name__}: {e}")
            traceback.print_exc(limit=3)
    print(f"\n{len(cells) - failures}/{len(cells)} cells passed")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""The paper's own benchmark configurations (ENEAC §4).

HOTSPOT: Rodinia thermal stencil, 2048×2048 chip grid, iteration space =
2048 rows.  SPMM: 29957×29957 sparse × 29957×100 dense, iteration space =
29957 rows.  Table-1 sweeps FPGA chunk sizes; the throughput cliff sits at
chunk > 1/4 of the space (512 rows HOTSPOT, 8192 rows SPMM).
"""

from dataclasses import dataclass
from typing import Tuple

__all__ = ["HotspotConfig", "SpmmConfig", "HOTSPOT", "SPMM", "TABLE1_CONFIGS"]


@dataclass(frozen=True)
class HotspotConfig:
    grid: int = 2048            # chip is grid × grid points
    iterations: int = 2048      # parallel rows
    sim_steps: int = 8          # time steps per run (paper loops the solver)
    # physical constants from the Rodinia kernel
    t_chip: float = 0.0005
    chip_height: float = 0.016
    chip_width: float = 0.016
    max_pd: float = 3.0e6
    precision: float = 0.001
    spec_heat_si: float = 1.75e6
    k_si: float = 100.0
    amb_temp: float = 80.0
    # chunk sweep (paper Fig. 4a): cliff above 512 (= grid/4)
    chunk_sweep: Tuple[int, ...] = (32, 64, 128, 256, 512, 1024)


@dataclass(frozen=True)
class SpmmConfig:
    rows: int = 29957
    cols: int = 29957
    dense_cols: int = 100
    nnz_per_row_mean: float = 120.0   # irregular: lognormal row lengths
    nnz_per_row_sigma: float = 1.0
    seed: int = 1234
    chunk_sweep: Tuple[int, ...] = (512, 1024, 2048, 4096, 8192, 16384)


HOTSPOT = HotspotConfig()
SPMM = SpmmConfig()

# Table-1 platform configurations, reproduced on the TPU mapping:
#   CC   = VPU/gather path (jnp row-wise)           [CPU cores]
#   HP   = Pallas kernel, HBM re-fetch per step     [non-cacheable port]
#   HPC  = Pallas kernel, VMEM-resident revisiting  [cache-coherent port]
#   +INT = completion-driven AsyncEngine            [interrupt mechanism]
TABLE1_CONFIGS = (
    ("1", "4CC", "cc", None, False),
    ("2", "4HPACC", "acc", "hp", False),
    ("3", "4HPCACC", "acc", "hpc", False),
    ("4", "4CC+4HPACC", "hybrid", "hp", False),
    ("5", "4CC+4HPACC+INT", "hybrid", "hp", True),
    ("6", "4CC+4HPCACC", "hybrid", "hpc", False),
    ("7", "4CC+4HPCACC+INT", "hybrid", "hpc", True),
)

"""Config registry: ``get_config(name)`` / ``--arch <id>`` resolution."""

from typing import Dict, List

from .base import SHAPES, InputShape, ModelConfig, ParallelConfig, cell_status

from .stablelm_12b import CONFIG as _stablelm
from .tinyllama_1_1b import CONFIG as _tinyllama
from .qwen3_14b import CONFIG as _qwen3
from .llama3_2_3b import CONFIG as _llama3
from .whisper_large_v3 import CONFIG as _whisper
from .mamba2_130m import CONFIG as _mamba2
from .grok_1_314b import CONFIG as _grok
from .qwen3_moe_30b_a3b import CONFIG as _qwen3moe
from .llama3_2_vision_90b import CONFIG as _vision
from .recurrentgemma_9b import CONFIG as _rgemma

_REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _stablelm,
        _tinyllama,
        _qwen3,
        _llama3,
        _whisper,
        _mamba2,
        _grok,
        _qwen3moe,
        _vision,
        _rgemma,
    )
}

ARCH_NAMES: List[str] = list(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    return dict(_REGISTRY)


__all__ = [
    "ModelConfig",
    "ParallelConfig",
    "InputShape",
    "SHAPES",
    "cell_status",
    "get_config",
    "all_configs",
    "ARCH_NAMES",
]

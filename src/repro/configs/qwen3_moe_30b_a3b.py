"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) moe_d_ff=768
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]

128 experts / 16-way model axis = 8 experts per shard ⇒ true expert
parallelism with all-to-all dispatch.  This is the cell most representative
of the paper's technique (irregular routing + capacity chunks + fallback)."""

from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=6144,          # dense-equivalent ffn (used only by fallback sizing)
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    num_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    # shard_map local dispatch: per-DP-shard routing, 8 experts/model-shard
    parallel=ParallelConfig(moe_dispatch="local"),
)

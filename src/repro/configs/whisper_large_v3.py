"""whisper-large-v3 [audio] — 32L d_model=1280 20H (kv=20, full MHA)
d_ff=5120 vocab=51866 — enc-dec, conv frontend STUB.  [arXiv:2212.04356]

Backbone only per the assignment: ``input_specs()`` supplies precomputed
frame embeddings of shape (batch, frames, d_model) standing in for the
conv1d+GELU frontend; 32 encoder + 32 decoder layers, learned positions,
no RoPE (flagged via rope_theta=0)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,            # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    rope_theta=0.0,           # learned absolute positions
)

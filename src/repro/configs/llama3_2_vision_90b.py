"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256 — cross-attn image layers.
[hf:meta-llama/Llama-3.2-11B-Vision family; unverified]

Backbone only: the vision tower is a STUB; ``input_specs()`` provides
precomputed patch embeddings (batch, 1024, d_model).  Cross-attention
blocks every 5th layer (20 of 100), gated, llama-3.2-vision style."""

from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5e5,
    cross_attn_every=5,
    num_image_tokens=1024,
    # 90B dense on 256 chips: bf16 moments + deeper grad accumulation +
    # sequence-parallel activations (17.0 → 13.4 GiB peak: the difference
    # between OVER-HBM and fitting — §Perf cell A generalized)
    parallel=ParallelConfig(opt_state_dtype="bfloat16", microbatches=16,
                            sequence_parallel=True),
)

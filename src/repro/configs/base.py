"""Configuration system: model configs, input shapes, parallelism knobs.

Every assigned architecture is a :class:`ModelConfig`; every benchmark
shape is an :class:`InputShape`; the pairing rules (which shapes an arch
runs, and why a cell is skipped) live in :func:`cell_status`.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = [
    "ModelConfig",
    "InputShape",
    "ParallelConfig",
    "SHAPES",
    "cell_status",
    "VOCAB_PAD",
]

VOCAB_PAD = 256  # vocab padded to a multiple of this (TP divisibility)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    """Distribution strategy knobs (resolved against a mesh at lower time)."""

    fsdp: bool = True                  # shard weights over "data" (ZeRO-3)
    tensor_parallel: bool = True       # shard heads/ffn/vocab over "model"
    sequence_parallel: bool = False    # Megatron-SP activation sharding
    pipeline_stages: int = 1           # >1 ⇒ pipeline over "pod"
    remat: str = "block"               # "none" | "block" | "full"
    grad_reduce: str = "reduce_scatter"  # "all_reduce" | "reduce_scatter"
    grad_compression: bool = False     # int8 error-feedback DP compression
    microbatches: int = 1              # grad-accum chunks (ENEAC iteration space)
    opt_state_dtype: str = "float32"   # "bfloat16" halves AdamW HBM (314B-scale)
    moe_dispatch: str = "gspmd"        # "gspmd" (global, baseline) | "local"
                                       # (shard_map per-DP-shard routing)
    grad_accum_dtype: str = "float32"  # bf16 halves the grad-accum resident
    replicate_kv: bool = False         # replicate K/V projections instead of
                                       # sharding fused kv_dim across head
                                       # boundaries (GQA half-head pathology)
    scan_layers: bool = True           # lax.scan over block groups
    moe_fallback: bool = True          # ENEAC dense fallback (False = drop)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture (exact dims from the assignment table)."""

    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 ⇒ d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim (0 ⇒ d_ff)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # --- hybrid (RecurrentGemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru","rglru","attn")
    window: int = 0                        # local attention window
    lru_width: int = 0                     # 0 ⇒ d_model

    # --- enc-dec (Whisper backbone) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500     # nominal frame count (stub frontend)

    # --- VLM ---
    cross_attn_every: int = 0   # cross-attn block every N layers
    num_image_tokens: int = 1024

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    parallel: ParallelConfig = field(default_factory=ParallelConfig)

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k-token context?  SSM state is O(1);
        RG-LRU + windowed local attention is O(window).  Everything else
        holds a dense KV cache with full attention."""
        return self.family in ("ssm", "hybrid")

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    # -- parameter count (for 6ND and memory estimates) --------------------
    def param_count(self) -> int:
        d, L, V = self.d_model, self.num_layers, self.padded_vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, st, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            # in_proj (z,x,B,C,dt) + conv + out_proj + A,D + norm
            per = d * (2 * di + 2 * st + nh) + self.conv_width * (di + 2 * st) \
                + di * d + 2 * nh + di + d
            return emb + L * per + d
        att = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qk_norm:
            att += 2 * self.head_dim
        dense_ffn = 3 * d * self.d_ff  # SwiGLU
        norms = 2 * d
        if self.family == "moe":
            eff = self.moe_d_ff or self.d_ff
            moe = self.num_experts * 3 * d * eff + d * self.num_experts
            if self.parallel.moe_fallback:
                moe += 3 * d * eff  # shared fallback FFN (the CC path)
            per = att + moe + norms
        elif self.family == "hybrid":
            # pattern mix of rglru + local-attn blocks
            lw = self.lru_width or d
            rglru = d * 2 * lw + lw * d + self.conv_width * lw + 3 * lw \
                + lw * 2 * lw // 8  # gates (block-diagonal, 8 blocks)
            n_attn = self.attn_layer_count()
            n_rec = self.num_layers - n_attn
            per = 0  # accounted below
            total = n_attn * (att + dense_ffn + norms) + n_rec * (rglru + dense_ffn + norms)
            return emb + total + d
        elif self.family == "encdec":
            # decoder layers have an extra cross-attention
            enc_per = att + dense_ffn + norms
            dec_per = 2 * att + dense_ffn + 3 * d
            return emb + self.encoder_layers * enc_per + L * dec_per + 2 * d
        elif self.family == "vlm":
            n_cross = self.cross_attn_layer_count()
            n_self = self.num_layers - n_cross
            cross = att + dense_ffn + norms + 2 * d  # gate params
            return emb + n_self * (att + dense_ffn + norms) + n_cross * (att + dense_ffn + norms + cross) + d
        else:
            per = att + dense_ffn + norms
        return emb + L * per + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts + fallback)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        eff = self.moe_d_ff or self.d_ff
        att = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        active_moe = self.experts_per_token * 3 * d * eff + d * self.num_experts
        if self.parallel.moe_fallback:
            active_moe += 3 * d * eff
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return emb + L * (att + active_moe + 2 * d) + d

    def attn_layer_count(self) -> int:
        if self.family != "hybrid" or not self.block_pattern:
            return self.num_layers
        pat = self.block_pattern
        full, rem = divmod(self.num_layers, len(pat))
        return full * pat.count("attn") + sum(1 for b in pat[:rem] if b == "attn")

    def cross_attn_layer_count(self) -> int:
        if self.family != "vlm" or not self.cross_attn_every:
            return 0
        return self.num_layers // self.cross_attn_every

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- reduced config for CPU smoke tests --------------------------------
    def smoke(self) -> "ModelConfig":
        """Same family/wiring, tiny dims — used by per-arch smoke tests."""
        pat = self.block_pattern
        n_layers = max(len(pat), 2) if pat else 2
        if self.family == "vlm":
            n_layers = max(n_layers, self.cross_attn_every or 2)
        kv = min(self.num_kv_heads, 2) or 1
        heads = max(2 * kv, 2)
        hd = 8
        return self.replace(
            num_layers=n_layers,
            d_model=heads * hd,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=4 * heads * hd if self.d_ff else 0,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=32 if self.moe_d_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=8,
            ssm_chunk=8,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16,
            window=8 if self.window else 0,
            lru_width=0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            num_image_tokens=8 if self.family == "vlm" else self.num_image_tokens,
            dtype="float32",
            param_dtype="float32",
        )


def cell_status(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """(runnable, reason).  Encodes the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "skip: 500k-token decode requires sub-quadratic attention; "
            f"{cfg.name} is full-attention ({cfg.family})"
        )
    return True, "run"

"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1, MQA)
d_ff=12288 vocab=256000 — RG-LRU + local attention, pattern 2:1.
[arXiv:2402.19427]

Block pattern (rglru, rglru, attn) repeated; 38 layers = 12 full patterns
+ 2 trailing rglru blocks.  Local attention window 2048 ⇒ sub-quadratic:
runs the long_500k cell (KV cache is window-sized, RG-LRU state is O(1))."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn"),
    window=2048,
    lru_width=4096,
    tie_embeddings=True,
)

"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]

8 experts < 16-way model axis ⇒ expert weights are tensor-parallel over
d_ff (2048/shard) with experts replicated along the expert dim — the
mesh_rules pick this automatically (see parallel/mesh_rules.py)."""

from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    num_experts=8,
    experts_per_token=2,
    moe_d_ff=32768,
    # 314B on 256 chips: fp32 moments alone are 2.5 TB ⇒ bf16 moments;
    # 32 grad-accum microbatches bound the dispatch working set.
    parallel=ParallelConfig(
        opt_state_dtype="bfloat16", microbatches=16, moe_dispatch="local",
        grad_accum_dtype="bfloat16", sequence_parallel=True,
    ),
)

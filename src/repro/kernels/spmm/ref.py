"""Pure-jnp oracles + host-side format builders for SPMM.

The paper's SPMM: sparse (29957×29957) × dense (29957×100), iteration
space = matrix rows, irregular nnz/row.  TPU-native layouts:

* **ELL** (row-major, for the CC/VPU gather path): per-row padded
  ``(R, maxnnz)`` value/col arrays.
* **Block-ELL** (for the ACC/MXU path): rows grouped in blocks of 8,
  columns in blocks of 128; per row-block the list of occupied column
  blocks, padded to the per-matrix max (irregularity shows up as padding —
  the exact trade the paper's ACC chunking makes).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "SpmmProblem", "make_problem", "spmm_dense_ref", "spmm_ell_ref",
    "BlockEll", "to_block_ell",
]

ROW_BLOCK = 8
COL_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class SpmmProblem:
    """ELL-format sparse matrix + dense RHS."""

    vals: np.ndarray      # (R, maxnnz) f32, zero-padded
    cols: np.ndarray      # (R, maxnnz) int32, padded with 0 (vals 0 ⇒ no-op)
    nnz: np.ndarray       # (R,) int32
    n_cols: int
    rhs: np.ndarray       # (C, N) f32

    @property
    def rows(self) -> int:
        return self.vals.shape[0]


def make_problem(
    rows: int, cols: int, n_dense: int, *,
    nnz_mean: float = 8.0, nnz_sigma: float = 1.0, seed: int = 0,
) -> SpmmProblem:
    """Lognormal nnz/row — the irregular workload of the paper's §4."""
    rng = np.random.default_rng(seed)
    nnz = np.minimum(
        np.maximum(rng.lognormal(np.log(nnz_mean), nnz_sigma, rows).astype(np.int64), 1),
        cols,
    )
    maxnnz = int(nnz.max())
    vals = np.zeros((rows, maxnnz), np.float32)
    colix = np.zeros((rows, maxnnz), np.int32)
    for r in range(rows):
        k = int(nnz[r])
        colix[r, :k] = np.sort(rng.choice(cols, size=k, replace=False)).astype(np.int32)
        vals[r, :k] = rng.standard_normal(k).astype(np.float32)
    rhs = rng.standard_normal((cols, n_dense)).astype(np.float32)
    return SpmmProblem(vals=vals, cols=colix, nnz=nnz.astype(np.int32),
                       n_cols=cols, rhs=rhs)


def spmm_dense_ref(p: SpmmProblem) -> np.ndarray:
    """Densify + matmul — the ground-truth oracle (small problems only)."""
    dense = np.zeros((p.rows, p.n_cols), np.float32)
    for r in range(p.rows):
        k = int(p.nnz[r])
        np.add.at(dense[r], p.cols[r, :k], p.vals[r, :k])
    return dense @ p.rhs


def spmm_ell_ref(vals: jax.Array, cols: jax.Array, rhs: jax.Array) -> jax.Array:
    """Row-gather path (the CC/VPU analogue): y = Σ_j vals[:, j]·rhs[cols[:, j]]."""
    gathered = rhs[cols]                      # (R, maxnnz, N)
    return jnp.einsum("rk,rkn->rn", vals, gathered)


@dataclasses.dataclass(frozen=True)
class BlockEll:
    vals: np.ndarray    # (n_rb, K, ROW_BLOCK, COL_BLOCK) f32
    colblocks: np.ndarray  # (n_rb, K) int32 — column-block index
    counts: np.ndarray  # (n_rb,) int32 — occupied column blocks
    rows: int
    n_cols: int

    @property
    def n_row_blocks(self) -> int:
        return self.vals.shape[0]

    @property
    def k_max(self) -> int:
        return self.vals.shape[1]

    def padding_ratio(self) -> float:
        dense_elems = self.counts.sum() * ROW_BLOCK * COL_BLOCK
        nnz = np.count_nonzero(self.vals)
        return float(nnz) / max(dense_elems, 1)


def to_block_ell(p: SpmmProblem, *, k_cap: int = 0) -> BlockEll:
    """Host-side packing (part of the benchmark's data pipeline).

    ``k_cap`` bounds column blocks per row block (the ACC chunk-capacity
    knob); overflowing blocks are DROPPED here — the hybrid executor routes
    such rows to the gather path instead, ENEAC-style.
    """
    R = p.rows
    rpad = (ROW_BLOCK - R % ROW_BLOCK) % ROW_BLOCK
    n_rb = (R + rpad) // ROW_BLOCK
    cpad_cols = ((p.n_cols + COL_BLOCK - 1) // COL_BLOCK) * COL_BLOCK

    blocks = [dict() for _ in range(n_rb)]
    for r in range(R):
        rb, ri = divmod(r, ROW_BLOCK)
        k = int(p.nnz[r])
        for j in range(k):
            c = int(p.cols[r, j])
            cb, ci = divmod(c, COL_BLOCK)
            blk = blocks[rb].setdefault(cb, np.zeros((ROW_BLOCK, COL_BLOCK), np.float32))
            blk[ri, ci] += p.vals[r, j]

    K = max((len(b) for b in blocks), default=1) or 1
    if k_cap:
        K = min(K, k_cap)
    vals = np.zeros((n_rb, K, ROW_BLOCK, COL_BLOCK), np.float32)
    colblocks = np.zeros((n_rb, K), np.int32)
    counts = np.zeros((n_rb,), np.int32)
    for rb, b in enumerate(blocks):
        items = sorted(b.items())[:K]
        counts[rb] = len(items)
        for k_, (cb, blk) in enumerate(items):
            colblocks[rb, k_] = cb
            vals[rb, k_] = blk
    return BlockEll(vals=vals, colblocks=colblocks, counts=counts,
                    rows=R, n_cols=cpad_cols)

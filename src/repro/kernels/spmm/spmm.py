"""Block-ELL SPMM Pallas TPU kernel — the paper's irregular benchmark.

The accelerator (ACC/MXU) path: the dense RHS stays VMEM-resident (HPC
analogue — 29957×128 f32 ≈ 15 MiB) while row-blocks of the sparse matrix
stream through.  Each grid step processes one (8, 128·K) row block: a
``fori_loop`` over its occupied column blocks issues (8,128)·(128,N) MXU
matmuls with dynamic RHS slicing.  Irregularity (variable K per row block)
is masked against the per-block count — the cost of a row block is its
*max* K, exactly the padding/imbalance trade the MultiDynamic scheduler's
chunk-size knob controls.

The HP variant streams the RHS block-by-block from HBM (``pl.ANY`` memory
space + explicit async copies), modelling the paper's non-cacheable-port
configuration.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import BlockEll, COL_BLOCK, ROW_BLOCK

__all__ = ["spmm_block_ell_pallas"]


def _spmm_kernel(count_ref, cols_ref, vals_ref, rhs_ref, out_ref, *, k_max: int):
    """One row block: out (RB, N) = Σ_k vals[k] @ rhs[colblock_k]."""
    n = out_ref.shape[-1]
    count = count_ref[0]

    def body(k, acc):
        cb = cols_ref[0, k]
        b_blk = rhs_ref[pl.dslice(cb * COL_BLOCK, COL_BLOCK), :]
        contrib = jnp.dot(
            vals_ref[0, k], b_blk, preferred_element_type=jnp.float32
        )
        return acc + jnp.where(k < count, 1.0, 0.0) * contrib

    acc = jax.lax.fori_loop(0, k_max, body, jnp.zeros((ROW_BLOCK, n), jnp.float32))
    out_ref[0, ...] = acc


def spmm_block_ell_pallas(
    ell: "BlockEllArrays",
    rhs: jax.Array,               # (C_pad, N) f32 — VMEM-resident
    *,
    interpret: bool = True,
) -> jax.Array:
    """Returns (n_rb · ROW_BLOCK, N)."""
    n_rb, k_max = ell.colblocks.shape
    c_pad, n = rhs.shape
    kernel = functools.partial(_spmm_kernel, k_max=k_max)
    return pl.pallas_call(
        kernel,
        grid=(n_rb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, k_max), lambda i: (i, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, k_max, ROW_BLOCK, COL_BLOCK), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((c_pad, n), lambda i: (0, 0)),   # resident (HPC)
        ],
        out_specs=pl.BlockSpec((1, ROW_BLOCK, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rb, ROW_BLOCK, n), jnp.float32),
        interpret=interpret,
    )(ell.counts, ell.colblocks, ell.vals, rhs).reshape(n_rb * ROW_BLOCK, n)


class BlockEllArrays:
    """Device-array view of a host BlockEll."""

    def __init__(self, be: BlockEll):
        self.vals = jnp.asarray(be.vals)
        self.colblocks = jnp.asarray(be.colblocks)
        self.counts = jnp.asarray(be.counts)
        self.rows = be.rows
        self.n_cols = be.n_cols

"""SPMM execution paths + the ENEAC hybrid executor wiring.

Paths (Table-1 columns):
* ``cc``  — ELL gather path (jnp; VPU on TPU, vectorized loops on CPU).
* ``acc`` — block-ELL Pallas MXU kernel (RHS VMEM-resident).
* hybrid — MultiDynamic split: densest row-prefix on the ACC path, sparse
  tail on the CC path (rows pre-sorted by density; the split point is the
  scheduler's decision, see :class:`repro.core.parallel_for.HybridExecutor`).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.parallel_for import HybridExecutor, SplitDecision
from .ref import BlockEll, ROW_BLOCK, SpmmProblem, spmm_ell_ref, to_block_ell
from .spmm import BlockEllArrays, spmm_block_ell_pallas

__all__ = ["spmm_cc", "spmm_acc", "density_order", "make_hybrid_executor"]


@jax.jit
def spmm_cc(vals: jax.Array, cols: jax.Array, rhs: jax.Array) -> jax.Array:
    return spmm_ell_ref(vals, cols, rhs)


def spmm_acc(ell: BlockEllArrays, rhs_padded: jax.Array, *, interpret: bool = True):
    return spmm_block_ell_pallas(ell, rhs_padded, interpret=interpret)


def density_order(p: SpmmProblem) -> np.ndarray:
    """Row order, densest first — prefix split ⇒ ACC gets MXU-worthy rows."""
    return np.argsort(-p.nnz, kind="stable")


def pad_rhs(p: SpmmProblem) -> np.ndarray:
    from .ref import COL_BLOCK

    c_pad = ((p.n_cols + COL_BLOCK - 1) // COL_BLOCK) * COL_BLOCK
    n = p.rhs.shape[1]
    n_pad = ((n + 127) // 128) * 128
    out = np.zeros((c_pad, n_pad), np.float32)
    out[: p.n_cols, :n] = p.rhs
    return out


def make_hybrid_executor(
    p: SpmmProblem,
    *,
    mode: str = "parallel",
    interpret: bool = True,
    dense_quantum: int = ROW_BLOCK,
) -> Tuple[HybridExecutor, np.ndarray]:
    """Build the two path callables over the density-sorted row space.

    Returns (executor, row_order).  ``executor.run()`` computes the full
    product; results come back in sorted-row order (invert with row_order).
    """
    order = density_order(p)
    vals_s = jnp.asarray(p.vals[order])
    cols_s = jnp.asarray(p.cols[order])
    rhs = jnp.asarray(p.rhs)
    rhs_pad = jnp.asarray(pad_rhs(p))
    n = p.rhs.shape[1]
    R = p.rows

    # Pre-packed block-ELL prefixes are rebuilt per split in production;
    # for the benchmark we pack once at full size and slice row blocks.
    sorted_problem = SpmmProblem(
        vals=p.vals[order], cols=p.cols[order], nnz=p.nnz[order],
        n_cols=p.n_cols, rhs=p.rhs,
    )
    be = to_block_ell(sorted_problem)
    ell = BlockEllArrays(be)

    def dense_fn(n_rows: int):
        if n_rows <= 0:
            return None
        nrb = (n_rows + ROW_BLOCK - 1) // ROW_BLOCK
        sub = BlockEllArrays.__new__(BlockEllArrays)
        sub.vals = ell.vals[:nrb]
        sub.colblocks = ell.colblocks[:nrb]
        sub.counts = ell.counts[:nrb]
        sub.rows = n_rows
        sub.n_cols = ell.n_cols
        out = spmm_acc(sub, rhs_pad, interpret=interpret)
        return jax.block_until_ready(out[:n_rows, :n])

    def sparse_fn(n_rows: int):
        if n_rows <= 0:
            return None
        out = spmm_cc(vals_s[R - n_rows:], cols_s[R - n_rows:], rhs)
        return jax.block_until_ready(out)

    def merge_fn(dense_res, sparse_res):
        parts = [r for r in (dense_res, sparse_res) if r is not None]
        return jnp.concatenate(parts, axis=0)

    execr = HybridExecutor(
        dense_fn, sparse_fn, merge_fn, num_items=R, mode=mode,
        dense_quantum=dense_quantum,
    )
    return execr, order

from . import ops, ref

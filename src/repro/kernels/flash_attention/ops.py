"""Jit'd wrapper + analytic HBM-traffic model for the flash kernel.

``flash_attention`` is the public entry (falls back to the oracle for
shapes the kernel doesn't tile).  ``kernel_hbm_bytes`` is the traffic the
kernel performs by construction — Q, K, V read once, O written once —
used by the roofline's kernel-substitution analysis (§Perf): on real TPU
this kernel replaces the XLA online-softmax path whose score blocks
round-trip HBM.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import mha_ref

__all__ = ["flash_attention", "kernel_hbm_bytes", "kernel_flops"]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_block", "kv_block", "interpret")
)
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0,
    q_block: int = 128, kv_block: int = 128, interpret: bool = True,
) -> jax.Array:
    b, sq, h, d = q.shape
    sk = k.shape[1]
    if sq % min(q_block, sq) or sk % min(kv_block, sk):
        return mha_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, interpret=interpret,
    )


def kernel_hbm_bytes(
    batch: int, sq: int, sk: int, heads: int, kv_heads: int, head_dim: int,
    *, bytes_per_el: int = 2, backward: bool = False,
) -> float:
    """HBM traffic of the kernel by construction (K/V VMEM-resident):
    forward reads Q,K,V and writes O; backward re-reads Q,K,V,O,dO and
    writes dQ,dK,dV (+ fp32 logsumexp stats, negligible)."""
    q_b = batch * sq * heads * head_dim * bytes_per_el
    kv_b = 2 * batch * sk * kv_heads * head_dim * bytes_per_el
    o_b = q_b
    fwd = q_b + kv_b + o_b
    if not backward:
        return fwd
    bwd = (2 * q_b + kv_b) + (q_b + kv_b)  # reads (Q,K,V,O,dO) + writes (dQ,dK,dV)
    return fwd + bwd


def kernel_flops(
    batch: int, sq: int, sk: int, heads: int, head_dim: int,
    *, causal: bool = True, backward: bool = False,
) -> float:
    """MXU FLOPs: 2·(QK^T) + 2·(PV) per head, halved by causal skipping."""
    full = 2.0 * 2.0 * batch * heads * sq * sk * head_dim
    if causal and sq == sk:
        full *= 0.5
    return full * (3.5 if backward else 1.0)

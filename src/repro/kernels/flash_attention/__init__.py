from . import ops, ref

"""Pure-jnp oracle for flash attention (GQA, causal/local/full)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["mha_ref"]


def mha_ref(
    q: jax.Array,          # (B, Sq, H, D)
    k: jax.Array,          # (B, Sk, KVH, D)
    v: jax.Array,          # (B, Sk, KVH, D)
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = d**-0.5 if scale is None else scale
    qg = q.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal or window:
        qp = jnp.arange(sq)[:, None]
        kp = jnp.arange(k.shape[1])[None, :]
        m = jnp.ones((sq, k.shape[1]), bool)
        if causal:
            m &= kp <= qp
        if window:
            m &= kp > qp - window
        s = jnp.where(m[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)

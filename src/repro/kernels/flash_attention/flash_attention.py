"""Flash attention Pallas TPU kernel (forward), GQA-aware.

TPU-native schedule (this is the production replacement for the XLA
online-softmax path in ``models/attention.py``, whose score-block HBM
traffic dominates the measured memory roofline term):

* grid = (B, KVH, n_q): one program per (batch, kv-head, query block);
* K/V for the program's kv-head are **VMEM-resident** across the q loop
  (the revisiting/HPC discipline: index_map is constant in the q axis, so
  Mosaic keeps the buffer resident instead of re-streaming — HBM traffic
  becomes Q+K+V+O exactly);
* all G query heads sharing the kv-head are processed together as rows of
  a (G·qb, D) block — MXU-shaped matmuls even for small qb;
* online softmax (running max / denom / accumulator, fp32) over kv blocks
  with a ``fori_loop``; causal programs stop the loop at the diagonal
  block (no wasted FLOPs on fully-masked blocks — the XLA path cannot
  skip them);
* local (windowed) masks supported for the hybrid arch's attention.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window,
                  q_block, kv_block, seq_k, groups):
    iq = pl.program_id(2)
    d = q_ref.shape[-1]
    # q block rows = G heads × q_block positions
    q = q_ref[0, 0, 0].astype(jnp.float32) * scale        # (G*qb, D)

    n_kv_total = seq_k // kv_block
    if causal:
        # last kv block the diagonal touches
        limit = jnp.minimum(((iq + 1) * q_block + kv_block - 1) // kv_block,
                            n_kv_total)
    else:
        limit = n_kv_total

    q_pos = iq * q_block + jax.lax.broadcasted_iota(
        jnp.int32, (groups, q_block), 1
    ).reshape(groups * q_block)

    def body(ik, carry):
        m_run, l_run, acc = carry
        kblk = k_ref[0, 0, pl.dslice(ik * kv_block, kv_block), :].astype(jnp.float32)
        vblk = v_ref[0, 0, pl.dslice(ik * kv_block, kv_block), :].astype(jnp.float32)
        s = jnp.dot(q, kblk.T, preferred_element_type=jnp.float32)  # (G*qb, kvb)
        k_pos = ik * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_block), 1
        )
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos[:, None]
        if window:
            mask &= k_pos > (q_pos[:, None] - window)
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        pmat = jnp.exp(s - m_new[:, None])
        l_new = l_run * alpha + jnp.sum(pmat, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            pmat, vblk, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    rows = groups * q_block
    m0 = jnp.full((rows,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((rows,), jnp.float32)
    a0 = jnp.zeros((rows, d), jnp.float32)
    m_f, l_f, acc = jax.lax.fori_loop(0, limit, body, (m0, l0, a0))
    o_ref[0, 0, 0] = (acc / jnp.maximum(l_f, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,           # (B, Sq, H, D)
    k: jax.Array,           # (B, Sk, KVH, D)
    v: jax.Array,           # (B, Sk, KVH, D)
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = d**-0.5 if scale is None else scale
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0, (sq, q_block, sk, kv_block)
    n_q = sq // q_block

    # layout: (B, KVH, G·Sq, D) with G-major rows per q block
    qr = q.reshape(b, sq, kvh, g, d).transpose(0, 2, 3, 1, 4)   # (B,KVH,G,Sq,D)
    qr = qr.reshape(b, kvh, g * sq, d)
    # group rows by q block: (B, KVH, n_q, G*qb, D)
    qr = qr.reshape(b, kvh, g, n_q, q_block, d).transpose(0, 1, 3, 2, 4, 5)
    qr = qr.reshape(b, kvh, n_q, g * q_block, d)
    kr = k.transpose(0, 2, 1, 3)                                # (B,KVH,Sk,D)
    vr = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, seq_k=sk, groups=g,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, n_q),
        in_specs=[
            pl.BlockSpec((1, 1, 1, g * q_block, d), lambda ib, ih, iq: (ib, ih, iq, 0, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda ib, ih, iq: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, sk, d), lambda ib, ih, iq: (ib, ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, g * q_block, d), lambda ib, ih, iq: (ib, ih, iq, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, n_q, g * q_block, d), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)

    # undo layout: (B,KVH,n_q,G,qb,D) → (B,Sq,H,D)
    out = out.reshape(b, kvh, n_q, g, q_block, d).transpose(0, 1, 3, 2, 4, 5)
    out = out.reshape(b, kvh, g, sq, d).transpose(0, 3, 1, 2, 4)
    return out.reshape(b, sq, h, d)

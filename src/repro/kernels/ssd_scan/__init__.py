from . import ops, ref

"""Oracle for the SSD (Mamba2) chunked scan kernel — re-exports the model
implementation, which is itself validated against decode parity."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...models.ssm import _ssd_chunked

__all__ = ["ssd_ref"]


def ssd_ref(x, log_a, Bm, Cm, chunk: int):
    """x: (B,S,H,P) pre-scaled by dt; log_a: (B,S,H); Bm/Cm: (B,S,N).

    Returns (y, final_state) — the pure-jnp chunked SSD evaluation."""
    return _ssd_chunked(x, log_a, Bm, Cm, chunk)

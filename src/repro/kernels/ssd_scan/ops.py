"""Jit'd wrapper for the SSD scan kernel."""

from __future__ import annotations

import functools

import jax

from .ref import ssd_ref
from .ssd_scan import ssd_scan_pallas

__all__ = ["ssd_scan", "ssd_ref"]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, log_a, Bm, Cm, *, chunk: int = 64, interpret: bool = True):
    """(y, final_state) — Pallas path; falls back to the oracle when the
    sequence doesn't tile."""
    if x.shape[1] % chunk:
        return ssd_ref(x, log_a, Bm, Cm, chunk)
    return ssd_scan_pallas(x, log_a, Bm, Cm, chunk=chunk, interpret=interpret)

"""SSD (Mamba2) chunked-scan Pallas TPU kernel.

The model's XLA path (``models/ssm._ssd_chunked``) materializes the
(B, n_chunks, Q, Q, H) decay tensor L at fusion boundaries — the SSM
analogue of the attention score-block traffic.  This kernel keeps the
whole per-(batch, head) chunk pipeline in VMEM:

* grid = (B, H): one program per (batch, head) — the recurrent state
  (P, N) lives in VMEM registers across the *sequential* chunk loop,
  which is the data dependency the algorithm fundamentally has;
* per chunk: the (Q, Q) decay/score matrices, the (Q, N) B/C blocks and
  the (Q, P) x block are VMEM-resident; two MXU matmuls (C·Bᵀ ⊙ L)·x and
  C·h per chunk plus rank-1 state updates;
* HBM traffic = x, B, C, dt read once and y written once — O(S) instead
  of O(S·Q) boundary crossings.

Time-sequential chunk recurrence is expressed with ``fori_loop`` carrying
the (P, N) state, exactly like the flash kernel carries (m, l, acc).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_scan_pallas"]


def _ssd_kernel(x_ref, loga_ref, b_ref, c_ref, y_ref, hout_ref, *, chunk, n_chunks):
    p = x_ref.shape[-1]
    n = b_ref.shape[-1]

    def body(ic, h):
        sl = pl.dslice(ic * chunk, chunk)
        xb = x_ref[0, 0, sl, :].astype(jnp.float32)          # (Q, P)
        la = loga_ref[0, 0, sl].astype(jnp.float32)          # (Q,)
        bb = b_ref[0, sl, :].astype(jnp.float32)             # (Q, N)
        cb = c_ref[0, sl, :].astype(jnp.float32)             # (Q, N)

        cs = jnp.cumsum(la)                                  # (Q,)
        # intra-chunk: L[i,j] = exp(cs_i − cs_j) for i ≥ j
        seg = cs[:, None] - cs[None, :]
        li = jnp.tril(jnp.exp(seg))                          # (Q, Q)
        s = jnp.dot(cb, bb.T, preferred_element_type=jnp.float32) * li
        y_intra = jnp.dot(s, xb, preferred_element_type=jnp.float32)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.exp(cs)[:, None] * jnp.dot(
            cb, h.T, preferred_element_type=jnp.float32
        )                                                    # (Q, P)
        y_ref[0, 0, sl, :] = (y_intra + y_inter).astype(y_ref.dtype)
        # state update: h' = exp(cs_Q)·h + Σ_j exp(cs_Q − cs_j) x_j ⊗ B_j
        decay_out = jnp.exp(cs[-1] - cs)                     # (Q,)
        h_new = jnp.exp(cs[-1]) * h + jnp.dot(
            (xb * decay_out[:, None]).T, bb,
            preferred_element_type=jnp.float32,
        )                                                    # (P, N)
        return h_new

    h0 = jnp.zeros((p, n), jnp.float32)
    h_final = jax.lax.fori_loop(0, n_chunks, body, h0)
    hout_ref[0, 0] = h_final


def ssd_scan_pallas(
    x: jax.Array,       # (B, S, H, P) — pre-scaled by dt
    log_a: jax.Array,   # (B, S, H)
    Bm: jax.Array,      # (B, S, N)
    Cm: jax.Array,      # (B, S, N)
    *,
    chunk: int = 64,
    interpret: bool = True,
):
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    xr = x.transpose(0, 2, 1, 3)           # (B, H, S, P)
    lar = log_a.transpose(0, 2, 1)         # (B, H, S)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, s, p), lambda ib, ih: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, s), lambda ib, ih: (ib, ih, 0)),
            pl.BlockSpec((1, s, n), lambda ib, ih: (ib, 0, 0)),
            pl.BlockSpec((1, s, n), lambda ib, ih: (ib, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, s, p), lambda ib, ih: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda ib, ih: (ib, ih, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xr, lar, Bm, Cm)
    return y.transpose(0, 2, 1, 3), h_final

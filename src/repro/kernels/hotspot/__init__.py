from . import ops, ref

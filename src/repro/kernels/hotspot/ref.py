"""Pure-jnp oracle for the HOTSPOT stencil (Rodinia thermal simulation).

One time step of the explicit solver on a (R, C) grid:

  T'   = T + dt/Cap · ( (T[r,c-1] + T[r,c+1] − 2T)/Rx
                      + (T[r-1,c] + T[r+1,c] − 2T)/Ry
                      + (T_amb − T)/Rz + P )

Boundary cells clamp their missing neighbours to themselves (Rodinia's
edge handling).  Constants follow the Rodinia kernel, parameterized by
:class:`repro.configs.paper_eneac.HotspotConfig`.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ...configs.paper_eneac import HotspotConfig

__all__ = [
    "hotspot_coefficients",
    "hotspot_step_coeffs",
    "hotspot_step_ref",
    "hotspot_ref",
]


def hotspot_coefficients(cfg: HotspotConfig, rows: int, cols: int) -> Tuple[float, ...]:
    grid_h = cfg.chip_height / rows
    grid_w = cfg.chip_width / cols
    cap = cfg.spec_heat_si * cfg.t_chip * grid_w * grid_h
    rx = grid_w / (2.0 * cfg.k_si * cfg.t_chip * grid_h)
    ry = grid_h / (2.0 * cfg.k_si * cfg.t_chip * grid_w)
    rz = cfg.t_chip / (cfg.k_si * grid_h * grid_w)
    max_slope = cfg.max_pd / (cfg.spec_heat_si * cfg.t_chip)
    dt = cfg.precision / max_slope
    return cap, rx, ry, rz, dt


def hotspot_step_coeffs(
    temp: jax.Array, power: jax.Array, amb_temp: float,
    cap: float, rx: float, ry: float, rz: float, dt: float,
) -> jax.Array:
    """One explicit step with the coefficients given outright.

    Factored out of :func:`hotspot_step_ref` so chunked execution (a row
    band plus halo rows) can run the *identical* elementwise expression
    with the full grid's coefficients — which is what makes banded
    evaluation bitwise equal to the whole-grid step (see
    ``kernels/hotspot/ops.py::hotspot_step_banded``).
    """
    t = temp
    up = jnp.concatenate([t[:1], t[:-1]], axis=0)
    down = jnp.concatenate([t[1:], t[-1:]], axis=0)
    left = jnp.concatenate([t[:, :1], t[:, :-1]], axis=1)
    right = jnp.concatenate([t[:, 1:], t[:, -1:]], axis=1)
    delta = (dt / cap) * (
        power
        + (left + right - 2.0 * t) / rx
        + (up + down - 2.0 * t) / ry
        + (amb_temp - t) / rz
    )
    return t + delta


def hotspot_step_ref(temp: jax.Array, power: jax.Array, cfg: HotspotConfig) -> jax.Array:
    rows, cols = temp.shape
    cap, rx, ry, rz, dt = hotspot_coefficients(cfg, rows, cols)
    return hotspot_step_coeffs(temp, power, cfg.amb_temp, cap, rx, ry, rz, dt)


def hotspot_ref(temp: jax.Array, power: jax.Array, cfg: HotspotConfig, steps: int) -> jax.Array:
    def body(t, _):
        return hotspot_step_ref(t, power, cfg), None

    out, _ = jax.lax.scan(body, temp, None, length=steps)
    return out

"""Jit'd wrappers for the HOTSPOT kernels + the CC (VPU/jnp) path.

``hotspot(mode=...)`` selects the Table-1 execution path:

* ``"cc"``  — jnp/XLA path (the paper's CPU-core path; XLA:CPU compiles it
  to vectorized loops, XLA:TPU to VPU code).
* ``"hp"``  — Pallas row-tiled kernel, HBM round-trip per time step.
* ``"hpc"`` — Pallas VMEM-resident kernel, all steps fused.

``rows_slice`` runs the stencil on a chunk of rows only — the unit of work
the MultiDynamic scheduler hands out (a chunk of the 2048-row iteration
space).  Chunks carry one halo row on each side so chunked execution is
exact.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...configs.paper_eneac import HotspotConfig
from .hotspot import hotspot_hp_step_pallas, hotspot_hpc_pallas
from .ref import hotspot_coefficients, hotspot_ref, hotspot_step_coeffs, hotspot_step_ref

__all__ = ["hotspot", "hotspot_rows_chunk", "hotspot_step_banded"]


def hotspot(
    temp: jax.Array,
    power: jax.Array,
    cfg: HotspotConfig,
    steps: int,
    *,
    mode: str = "hpc",
    interpret: bool = True,
) -> jax.Array:
    if mode == "cc":
        return hotspot_ref(temp, power, cfg, steps)
    if mode == "hpc":
        return hotspot_hpc_pallas(temp, power, cfg, steps, interpret=interpret)
    if mode == "hp":
        t = temp
        for _ in range(steps):
            t = hotspot_hp_step_pallas(t, power, cfg, interpret=interpret)
        return t
    raise ValueError(f"mode must be cc|hp|hpc, got {mode!r}")


@functools.partial(jax.jit, static_argnames=("cfg", "grid"))
def hotspot_step_banded(
    temp_band: jax.Array,   # band rows plus any halo rows already included
    power_band: jax.Array,  # same shape as temp_band
    cfg: HotspotConfig,
    grid: tuple,            # (R, C) of the FULL grid
) -> jax.Array:
    """One step on a row band, bitwise equal to the whole-grid step.

    The scheduler's unit of work for the hotspot row space: the caller
    slices ``temp``/``power`` to the band *plus one halo row on each
    interior side* and keeps only the band rows of the result.  Using the
    full grid's coefficients (not the band's) is what makes this exactly
    the rows the whole-grid :func:`~repro.kernels.hotspot.ref.
    hotspot_step_ref` would produce — the invariant the
    runtime-parity test pins under real-thread dispatch.
    """
    cap, rx, ry, rz, dt = hotspot_coefficients(cfg, grid[0], grid[1])
    return hotspot_step_coeffs(temp_band, power_band, cfg.amb_temp,
                               cap, rx, ry, rz, dt)


@functools.partial(jax.jit, static_argnames=("cfg", "steps"))
def hotspot_rows_chunk(
    temp_halo: jax.Array,   # (chunk+2, C) — chunk rows plus one halo row each side
    power: jax.Array,       # (chunk, C)
    cfg: HotspotConfig,
    steps: int,
) -> jax.Array:
    """CC-path work unit for the scheduler: evolve a row chunk.

    Note: for multi-step evolution the halo must be ``steps`` rows deep for
    exactness; the benchmark uses steps-deep halos when steps > 1.
    """
    t = temp_halo
    for _ in range(steps):
        stepped = hotspot_step_ref(t, jnp.pad(power, ((1, 1), (0, 0))), cfg)
        t = t.at[1:-1].set(stepped[1:-1])
    return t[1:-1]

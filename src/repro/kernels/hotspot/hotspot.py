"""HOTSPOT stencil Pallas TPU kernels — the paper's regular benchmark.

Two variants map the paper's AXI-port study onto the TPU memory hierarchy:

* :func:`hotspot_hpc_kernel` — the **HPC (cache-coherent) analogue**: the
  whole temperature grid is VMEM-resident; all ``steps`` time iterations
  run inside ONE ``pallas_call`` with a double buffer, so HBM is touched
  exactly twice (initial load, final store).  A 2048² f32 grid is 16 MiB
  plus one scratch copy — comfortably inside a v5e's 128 MiB VMEM.
* :func:`hotspot_hp_kernel` — the **HP (non-cacheable) analogue**: one
  ``pallas_call`` per time step, row-block tiled; the grid round-trips
  through HBM every step, and the halo rows are delivered as separately
  materialized shifted copies (mirroring the paper's intermediate
  software buffers on the HP port path).

Both compute the identical update as :mod:`.ref` (same coefficients).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...configs.paper_eneac import HotspotConfig
from .ref import hotspot_coefficients

__all__ = ["hotspot_hpc_pallas", "hotspot_hp_step_pallas"]


def _step_math(t, up, down, left, right, power, coeff, amb):
    cap_inv_dt, rx_inv, ry_inv, rz_inv = coeff
    return t + cap_inv_dt * (
        power
        + (left + right - 2.0 * t) * rx_inv
        + (up + down - 2.0 * t) * ry_inv
        + (amb - t) * rz_inv
    )


def _shift_rows(t, direction):
    if direction == "up":  # neighbour above: row r-1 (clamped)
        return jnp.concatenate([t[:1], t[:-1]], axis=0)
    return jnp.concatenate([t[1:], t[-1:]], axis=0)


def _shift_cols(t, direction):
    if direction == "left":
        return jnp.concatenate([t[:, :1], t[:, :-1]], axis=1)
    return jnp.concatenate([t[:, 1:], t[:, -1:]], axis=1)


# ---------------------------------------------------------------------------
# HPC variant: VMEM-resident, all time steps fused in-kernel
# ---------------------------------------------------------------------------
def _hpc_kernel(temp_ref, power_ref, out_ref, scratch_ref, *, steps, coeff, amb):
    scratch_ref[...] = temp_ref[...]

    def body(i, _):
        t = scratch_ref[...]
        up = _shift_rows(t, "up")
        down = _shift_rows(t, "down")
        left = _shift_cols(t, "left")
        right = _shift_cols(t, "right")
        scratch_ref[...] = _step_math(t, up, down, left, right, power_ref[...],
                                      coeff, amb)
        return 0

    jax.lax.fori_loop(0, steps, body, 0)
    out_ref[...] = scratch_ref[...]


def hotspot_hpc_pallas(
    temp: jax.Array, power: jax.Array, cfg: HotspotConfig, steps: int,
    *, interpret: bool = True,
) -> jax.Array:
    rows, cols = temp.shape
    cap, rx, ry, rz, dt = hotspot_coefficients(cfg, rows, cols)
    coeff = (dt / cap, 1.0 / rx, 1.0 / ry, 1.0 / rz)
    kernel = functools.partial(_hpc_kernel, steps=steps, coeff=coeff, amb=cfg.amb_temp)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((rows, cols), lambda i: (0, 0)),
            pl.BlockSpec((rows, cols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cols), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), temp.dtype),
        scratch_shapes=[pltpu.VMEM((rows, cols), temp.dtype)],
        interpret=interpret,
    )(temp, power)


# ---------------------------------------------------------------------------
# HP variant: one step per call, row-block tiled, HBM round-trip per step
# ---------------------------------------------------------------------------
def _hp_kernel(t_ref, up_ref, down_ref, power_ref, out_ref, *, coeff, amb):
    t = t_ref[...]
    left = _shift_cols(t, "left")
    right = _shift_cols(t, "right")
    out_ref[...] = _step_math(t, up_ref[...], down_ref[...], left, right,
                              power_ref[...], coeff, amb)


def hotspot_hp_step_pallas(
    temp: jax.Array, power: jax.Array, cfg: HotspotConfig,
    *, block_rows: int = 256, interpret: bool = True,
) -> jax.Array:
    """One time step; halos come in as shifted copies (HP-port buffers)."""
    rows, cols = temp.shape
    br = min(block_rows, rows)
    while rows % br:
        br //= 2
    cap, rx, ry, rz, dt = hotspot_coefficients(cfg, rows, cols)
    coeff = (dt / cap, 1.0 / rx, 1.0 / ry, 1.0 / rz)
    up = _shift_rows(temp, "up")      # materialized in HBM: the HP-port
    down = _shift_rows(temp, "down")  # intermediate-buffer penalty
    kernel = functools.partial(_hp_kernel, coeff=coeff, amb=cfg.amb_temp)
    spec = pl.BlockSpec((br, cols), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), temp.dtype),
        interpret=interpret,
    )(temp, up, down, power)

"""Pallas TPU kernels for the perf-critical compute layers.

* hotspot/        — paper benchmark 1 (regular stencil), HP/HPC variants
* spmm/           — paper benchmark 2 (irregular), block-ELL MXU + gather
* ssd_scan/        — Mamba2 chunked-scan kernel (state VMEM-resident)
* flash_attention — production attention (replaces the XLA online-softmax
                    path whose score-block HBM traffic dominates §Roofline)

All kernels: pl.pallas_call + explicit BlockSpec VMEM tiling, ops.py jit'd
wrapper, ref.py pure-jnp oracle, validated with interpret=True on CPU.
"""

"""JAX version compatibility for manual-partitioning entry points.

``shard_map`` has moved twice: it started life as
``jax.experimental.shard_map.shard_map`` (replication checking via
``check_rep``), and newer releases expose it as ``jax.shard_map`` with the
argument renamed to ``check_vma``.  The repo targets whichever jax the
container bakes in, so every internal call site goes through this shim
instead of either spelling.
"""

from __future__ import annotations

import functools

import jax

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Dispatch to the native ``shard_map`` of the installed jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )

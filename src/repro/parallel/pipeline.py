"""Pipeline parallelism over the pod axis (GPipe schedule, shard_map).

On the 2×16×16 multi-pod mesh the "pod" axis can carry pipeline stages
instead of data parallelism: layer-stacked parameters split contiguously
over the axis (stage s holds layers [s·L/S, (s+1)·L/S)), and microbatches
stream through stages with ``ppermute`` hand-offs — cross-pod traffic
becomes one (B_μ, S, d) activation per microbatch per boundary instead of
the full gradient reduction, which is the right trade when inter-pod
links are the scarce resource (DCN-connected pods).

The schedule is the classic GPipe loop: ``n_micro + n_stages − 1`` ticks;
stage 0 injects microbatch t at tick t, stage s computes tick t's work on
the activation received at tick t−1, the last stage emits outputs.
Bubble fraction = (S−1)/(T+S−1), amortized by the ENEAC microbatch count.

This module is deliberately self-contained (stage_fn is any
layers-partitioned apply) and is exercised by an 8-device CPU test; the
dry-run meshes use DP over the pod axis by default (ParallelConfig
``pipeline_stages > 1`` opts a run into PP).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map

__all__ = ["pipeline_apply", "stage_partition"]


def stage_partition(num_layers: int, num_stages: int) -> list[tuple[int, int]]:
    """Contiguous layer ranges per stage (front-loaded remainder)."""
    base, rem = divmod(num_layers, num_stages)
    out = []
    start = 0
    for s in range(num_stages):
        n = base + (1 if s < rem else 0)
        out.append((start, start + n))
        start += n
    return out


def pipeline_apply(
    stacked_params,                 # pytree, leaves (L, ...) — split over axis
    x_micro: jax.Array,             # (n_micro, B_mu, ...) microbatched input
    layer_fn: Callable,             # (params_slice, x) -> x   (one layer)
    mesh: Mesh,
    *,
    axis: str = "pod",
) -> jax.Array:
    """Run the GPipe schedule; returns (n_micro, B_mu, ...) outputs.

    ``stacked_params`` leaves must have leading dim L divisible by the
    axis size; each stage scans its local L/S layers.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]

    def stage_body(params_local, xs):
        """Scan this stage's local layers over one activation."""
        def body(x, p):
            return layer_fn(p, x), None
        y, _ = jax.lax.scan(body, xs, params_local)
        return y

    def pipelined(params_local, x_local):
        stage = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(x_local[0])            # inter-stage register
        outs = jnp.zeros_like(x_local)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if still in range)
            inject = x_local[jnp.minimum(t, n_micro - 1)]
            x_in = jnp.where(stage == 0, inject, buf)
            y = stage_body(params_local, x_in)
            # hand off: stage s -> s+1 (last stage's output is the result)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            # the last stage finished microbatch t-(S-1) at tick t
            mb_done = t - (n_stages - 1)
            write = jnp.logical_and(stage == n_stages - 1, mb_done >= 0)
            idx = jnp.clip(mb_done, 0, n_micro - 1)
            outs = jnp.where(
                write,
                jax.lax.dynamic_update_index_in_dim(outs, y, idx, 0),
                outs,
            )
            return nxt, outs

        buf, outs = jax.lax.fori_loop(0, n_micro + n_stages - 1, tick,
                                      (buf, outs))
        # replicate results from the last stage (masked psum = broadcast);
        # callers want them replicated across the pipeline axis for the loss
        outs = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    other_axes = [a for a in mesh.axis_names if a != axis]
    param_spec = jax.tree.map(lambda _: P(axis), stacked_params)
    return shard_map(
        pipelined,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stacked_params), P()),
        out_specs=P(),
        check_vma=False,
    )(stacked_params, x_micro)

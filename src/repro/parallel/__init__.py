"""Distribution layer: mesh rules, sharding, pipeline parallelism,
compressed collectives."""

from .collectives import compressed_psum, compressed_psum_tree
from .mesh_rules import MeshRules, current_rules, shard_hint, use_rules
from .pipeline import pipeline_apply, stage_partition

__all__ = [
    "MeshRules",
    "use_rules",
    "current_rules",
    "shard_hint",
    "pipeline_apply",
    "stage_partition",
    "compressed_psum",
    "compressed_psum_tree",
]

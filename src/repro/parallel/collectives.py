"""Collective helpers: compressed gradient reduction for the DP axis.

At 1000+-node scale the cross-slice (DCN) gradient reduction dominates;
``compressed_psum_tree`` quantizes each gradient leaf to int8 (+fp32
scale) *before* the wire, reduces the int32-accumulated quanta, and
dequantizes — 4× fewer bytes over the slow links at <1% relative error,
with the residual handled by the caller's error-feedback state
(:mod:`repro.optim.compression`).  Used inside shard_map contexts (the
hetero trainer's manual-grad path); GSPMD-derived reductions keep XLA's
native schedule.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum", "compressed_psum_tree"]


def compressed_psum(x: jax.Array, axis: str) -> jax.Array:
    """All-reduce with int8 on-wire representation.

    Each participant quantizes with its own scale; scales are maxed across
    the axis first so quanta are commensurable, then the int32 sum of int8
    payloads is dequantized.  Bytes on the wire: 1×int8 payload + one
    scalar, vs 4×fp32 (or 2×bf16) for the plain psum.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis)                       # shared grid
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)         # int payload
    return (total.astype(jnp.float32) * scale).astype(x.dtype)


def compressed_psum_tree(tree, axis: str):
    return jax.tree.map(lambda g: compressed_psum(g, axis), tree)

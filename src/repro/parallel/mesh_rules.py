"""Logical-axis → mesh-axis sharding rules (MaxText-style, divisibility-aware).

Parameters and activations are annotated with *logical* axis names
(``"embed"``, ``"qheads"``, ``"act_batch"`` …).  :class:`MeshRules`
resolves them against a concrete mesh:

* each logical name has a priority-ordered tuple of candidate mesh axes;
* a candidate is used only if it exists in the mesh, is not already used
  by another dim of the same tensor, and divides the dim size evenly —
  so e.g. grok-1's 8 experts silently fall back from expert-parallel to
  tensor-parallel over the expert FFN dim (documented in the config), and
  a batch of 1 (long_500k) falls back to replication;
* :class:`~repro.configs.base.ParallelConfig` switches (fsdp /
  tensor_parallel / sequence_parallel) prune the rule table.

This keeps *every* (arch × shape × mesh) cell compilable from one rule set
— the property the multi-pod dry-run certifies.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ParallelConfig

__all__ = ["MeshRules", "use_rules", "current_rules", "shard_hint"]

Axes = Tuple[Optional[str], ...]

# logical axis → candidate mesh axes (priority order).  A tuple value of
# length > 1 with all candidates taken means the dim is sharded over the
# product of those axes (e.g. batch over pod×data).
_DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": (),               # sequence dim; ("model",) under SP
    "act_embed": (),             # hidden dim of activations: replicated
    "act_heads": ("model",),
    "act_kv": ("model",),
    "act_mlp": ("model",),
    "act_experts": ("model",),
    # expert-capacity chunks stay token-parallel over the DP axes — critical
    # when the expert count doesn't divide the model axis (grok-1: 8 experts
    # vs 16-way model ⇒ E unshardable; without this the (E, C, d) dispatch
    # batch replicates, measured 130 GiB/device at grok train_4k scale)
    "act_capacity": ("pod", "data"),
    "act_vocab": ("model",),
    # parameters
    "vocab": ("model",),
    "embed": ("data", "pod"),    # FSDP shard of the contracting dim; the pod
                                 # axis joins on multi-pod meshes (ZeRO over
                                 # 32 ways — how 314B-scale moments fit)
    "qheads": ("model",),
    "kvheads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "expert_embed": ("data", "pod"),
    "expert_mlp": ("model",),
    "lru": ("model",),
    "ssm_inner": ("model",),
    "ssm_state": (),
    "ssm_heads": (),
    "conv_ch": ("model",),
    "heads_vec": (),             # per-head scales (qk-norm etc.)
    "stack": (),                 # scan-stacked layer dim
    "window": (),
    "img_tokens": (),
}


class MeshRules:
    def __init__(self, mesh: Mesh, parallel: ParallelConfig) -> None:
        self.mesh = mesh
        self.parallel = parallel
        rules = dict(_DEFAULT_RULES)
        if not parallel.fsdp:
            rules["embed"] = ()
            rules["expert_embed"] = ()
        if parallel.replicate_kv:
            # kv_dim / 16 < head_dim for most GQA archs ⇒ sharding splits
            # heads; replicating the (small) K/V projections removes the
            # per-chunk half-head all-gathers GSPMD otherwise inserts
            rules["kvheads"] = ()
            rules["act_kv"] = ()
        if not parallel.tensor_parallel:
            for k, v in rules.items():
                rules[k] = tuple(a for a in v if a != "model")
        if parallel.sequence_parallel:
            rules["act_seq"] = ("model",)
        self.rules = rules

    # -- core resolution ----------------------------------------------------
    def spec(self, axes: Axes, shape: Optional[Sequence[int]] = None) -> P:
        """Resolve logical axes (+ optional dim sizes for divisibility)."""
        used: set = set()
        out = []
        for i, ax in enumerate(axes):
            if ax is None:
                out.append(None)
                continue
            if ax not in self.rules:
                raise KeyError(f"unknown logical axis {ax!r}")
            chosen = []
            for cand in self.rules[ax]:
                if cand not in self.mesh.axis_names or cand in used:
                    continue
                size = self.mesh.shape[cand]
                dim = shape[i] if shape is not None else None
                cur = 1
                for c in chosen:
                    cur *= self.mesh.shape[c]
                if dim is not None and dim % (cur * size) != 0:
                    continue
                chosen.append(cand)
            for c in chosen:
                used.add(c)
            if not chosen:
                out.append(None)
            elif len(chosen) == 1:
                out.append(chosen[0])
            else:
                out.append(tuple(chosen))
        return P(*out)

    def sharding(self, axes: Axes, shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))

    # -- tree-level ---------------------------------------------------------
    def tree_shardings(self, specs_tree, shapes_tree):
        """Map a pytree of logical-axes tuples (+ matching abstract shapes)
        to NamedShardings for jit in_shardings / out_shardings."""
        return jax.tree.map(
            lambda axes, sds: self.sharding(axes, sds.shape),
            specs_tree,
            shapes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
        )

    def constrain(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        return jax.lax.with_sharding_constraint(x, self.sharding(tuple(axes), x.shape))


# ---------------------------------------------------------------------------
# ambient rules (so model code can hint shardings without plumbing)
# ---------------------------------------------------------------------------
_ACTIVE: contextvars.ContextVar[Optional[MeshRules]] = contextvars.ContextVar(
    "repro_mesh_rules", default=None
)
_HINTS_DISABLED: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_hints_disabled", default=False
)


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    token = _ACTIVE.set(rules)
    try:
        yield rules
    finally:
        _ACTIVE.reset(token)


@contextlib.contextmanager
def hints_disabled():
    """Suppress shard hints — required inside shard_map bodies, where values
    are per-device blocks and global sharding constraints are meaningless."""
    token = _HINTS_DISABLED.set(True)
    try:
        yield
    finally:
        _HINTS_DISABLED.reset(token)


def current_rules() -> Optional[MeshRules]:
    return _ACTIVE.get()


def shard_hint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes; no-op outside a mesh."""
    rules = _ACTIVE.get()
    if rules is None or _HINTS_DISABLED.get():
        return x
    return rules.constrain(x, *axes)

"""Attention: GQA with optional qk-norm, RoPE, local windows, KV caches.

Three execution paths, one math:

* ``dense`` — plain einsum softmax; used for short sequences and as the
  reference oracle.
* ``chunked`` — lax.scan over query chunks with a bounded (chunk × S)
  score buffer; exact (not approximate) and keeps the working set
  VMEM-scale for the 32k shapes.  This is the XLA-lowered production path
  the roofline reads; the Pallas flash kernel (kernels/flash_attention)
  is the TPU-native replacement, validated against the same oracle.
* ``decode`` — single-token query against a (possibly rolling) KV cache.

Weights use fused 2D layouts — wq: (d_model, H·hd) — so tensor-parallel
sharding divides the fused head axis evenly for every assigned arch
(including qwen3-14b's 40 heads, which do NOT divide a 16-way mesh axis,
while 40·128 = 5120 does).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.mesh_rules import shard_hint
from .layers import Builder, apply_rope, rms_norm

__all__ = ["attention_params", "KVCache", "attention", "init_kv_cache"]

_NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attention_params(b: Builder, cfg: ModelConfig, *, bias: bool = False):
    """Q/K/V/O projections (+ qk-norm scales) under the current scope."""
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": b.param("wq", (d, qd), ("embed", "qheads")),
        "wk": b.param("wk", (d, kvd), ("embed", "kvheads")),
        "wv": b.param("wv", (d, kvd), ("embed", "kvheads")),
        "wo": b.param("wo", (qd, d), ("qheads", "embed")),
    }
    if bias:
        p["bq"] = b.param("bq", (qd,), ("qheads",), init="zeros")
        p["bk"] = b.param("bk", (kvd,), ("kvheads",), init="zeros")
        p["bv"] = b.param("bv", (kvd,), ("kvheads",), init="zeros")
        p["bo"] = b.param("bo", (d,), ("embed",), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = b.param("q_norm", (cfg.head_dim,), ("heads_vec",), init="zeros")
        p["k_norm"] = b.param("k_norm", (cfg.head_dim,), ("heads_vec",), init="zeros")
    return p


class KVCache(NamedTuple):
    """Fused-layout cache: (B, S_cache, KVH*hd).  For windowed attention
    S_cache = window and writes wrap (rolling buffer).

    ``length`` is PER-SEQUENCE (B,) — continuous-batching serving refills
    one slot while its neighbours are mid-generation, so every sequence
    has its own write position and validity horizon."""

    k: jax.Array
    v: jax.Array
    length: jax.Array  # (B,) int32 — tokens cached per sequence

    def uniform_length(self):
        return self.length[0]


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, window: int = 0):
    s = min(window, max_len) if window else max_len
    shape = (batch, s, cfg.kv_dim)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return KVCache(
        k=jnp.zeros(shape, dt),
        v=jnp.zeros(shape, dt),
        length=jnp.zeros((batch,), jnp.int32),
    )


def kv_cache_specs(cfg: ModelConfig, batch: int = 0, max_len: int = 0, window: int = 0):
    """Logical-axes mirror of the cache (for mesh-rule resolution)."""
    return KVCache(
        k=("act_batch", None, "act_kv"),
        v=("act_batch", None, "act_kv"),
        length=("act_batch",),
    )


def abstract_kv_cache(cfg: ModelConfig, batch: int, max_len: int, window: int = 0):
    s = min(window, max_len) if window else max_len
    shape = (batch, s, cfg.kv_dim)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return KVCache(
        k=jax.ShapeDtypeStruct(shape, dt),
        v=jax.ShapeDtypeStruct(shape, dt),
        length=jax.ShapeDtypeStruct((batch,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# core math
# ---------------------------------------------------------------------------
def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def _gqa_scores(q, k):
    """q: (B,Sq,KV,G,hd)  k: (B,Sk,KV,hd) → (B,KV,G,Sq,Sk) fp32."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)


def _gqa_out(w, v):
    """w: (B,KV,G,Sq,Sk)  v: (B,Sk,KV,hd) → (B,Sq,KV,G,hd)."""
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(w.dtype))


def _softmax(scores, mask):
    scores = jnp.where(mask, scores, _NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m))
    s = jnp.sum(e, axis=-1, keepdims=True)
    return e / s


def _causal_mask(q_pos, k_pos, window: int = 0):
    """(…,Sq,Sk) bool; window > 0 also lower-bounds (local attention)."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _dense_attention(q, k, v, cfg, *, causal: bool, window: int, q_offset=0):
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    scores = _gqa_scores(q, k) * (cfg.head_dim**-0.5)
    if causal:
        qp = jnp.arange(sq) + q_offset
        kp = jnp.arange(sk)
        mask = _causal_mask(qp, kp, window)[None, None, None]
    else:
        mask = jnp.ones((1, 1, 1, sq, sk), bool)
    w = _softmax(scores, mask)
    return _gqa_out(w, v)


def _chunked_attention(
    q, k, v, cfg, *, causal: bool, window: int, chunk: int, kv_chunk: int = 2048
):
    """Exact online-softmax attention, double-chunked (flash-style in XLA).

    Outer ``lax.scan`` over query chunks, inner scan over KV chunks with a
    running (max, denom, accumulator) — the score buffer is bounded at
    (B, KV, G, q_chunk, kv_chunk) regardless of sequence length.  This is
    the XLA-lowered production path; kernels/flash_attention is the
    TPU-native Pallas version of the same schedule.
    """
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    assert sq % chunk == 0, (sq, chunk)
    kvc = min(kv_chunk, sk)
    if sk % kvc:
        kvc = sk  # fallback: single kv chunk
    n_q = sq // chunk
    n_kv = sk // kvc
    scale = cfg.head_dim**-0.5
    qs = q.reshape(b, n_q, chunk, kvh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(b, n_kv, kvc, kvh, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n_kv, kvc, kvh, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint  # flash-style backward: recompute scores per q-chunk
    def q_body(_, q_xs):
        qi, q_idx = q_xs
        qp = q_idx * chunk + jnp.arange(chunk)

        def kv_body(carry, kv_xs):
            m_run, l_run, acc = carry
            ki, vi, kv_idx = kv_xs
            kp = kv_idx * kvc + jnp.arange(kvc)
            s = _gqa_scores(qi, ki) * scale                    # (B,KV,G,qc,kvc)
            if causal:
                mask = _causal_mask(qp, kp, window)[None, None, None]
            else:
                mask = jnp.ones((1, 1, 1, chunk, kvc), bool)
            s = jnp.where(mask, s, _NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vi.astype(p.dtype)
            )
            return (m_new, l_new, acc), 0.0

        m0 = jnp.full((b, kvh, g, chunk), _NEG_INF)
        l0 = jnp.zeros((b, kvh, g, chunk))
        a0 = jnp.zeros((b, kvh, g, chunk, hd))
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (ks, vs, jnp.arange(n_kv))
        )
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]         # (B,KV,G,qc,hd)
        return 0, out.transpose(0, 3, 1, 2, 4)                 # (B,qc,KV,G,hd)

    _, outs = jax.lax.scan(q_body, 0, (qs, jnp.arange(n_q)))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kvh, g, hd)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------
def attention(
    p,
    x: jax.Array,                      # (B, S, d)
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,
    kv_x: Optional[jax.Array] = None,  # cross-attention source
    causal: bool = True,
    window: int = 0,
    cache: Optional[KVCache] = None,
    cache_update: bool = True,
    q_chunk: int = 1024,
    rope: bool = True,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """Unified attention.  Modes:

    * training/prefill: ``cache is None`` or prefill-populates the cache;
    * decode: ``x`` is (B, 1, d) and ``cache.length`` marks the write slot;
    * cross: ``kv_x`` given ⇒ non-causal, no rope, cache holds kv_x keys.
    """
    b, s, d = x.shape
    kvsrc = kv_x if kv_x is not None else x
    is_cross = kv_x is not None

    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = shard_hint(q, "act_batch", None, "act_heads")
    q = _split_heads(q, cfg.num_heads, cfg.head_dim)

    decode = cache is not None and s == 1 and not is_cross
    reuse_cross = is_cross and cache is not None and cache_update is False

    if reuse_cross:
        k_f, v_f = cache.k, cache.v
    else:
        k_f = kvsrc @ p["wk"]
        v_f = kvsrc @ p["wv"]
        if "bk" in p:
            k_f, v_f = k_f + p["bk"], v_f + p["bv"]
        k_f = shard_hint(k_f, "act_batch", None, "act_kv")
        v_f = shard_hint(v_f, "act_batch", None, "act_kv")

    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    k = k_f.reshape(b, -1, kvh, hd)
    v = v_f.reshape(b, -1, kvh, hd)
    qh = q  # (B,S,H,hd)
    if cfg.qk_norm:
        qh = rms_norm(qh, p["q_norm"], cfg.norm_eps)
        if not reuse_cross:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if positions is None:
        if decode:
            positions = cache.length[:, None]
        else:
            positions = jnp.arange(s)[None, :]
    if rope and cfg.rope_theta and not is_cross:
        qh = apply_rope(qh, positions, cfg.rope_theta)
        if not reuse_cross:
            k = apply_rope(k, positions, cfg.rope_theta)

    g = cfg.num_heads // max(cfg.num_kv_heads, 1)
    qg = qh.reshape(b, s, kvh, g, hd)

    new_cache = cache
    if decode:
        # per-sequence write slots (continuous batching: every slot has its
        # own horizon); rolling for windowed caches
        cache_len = cache.k.shape[1]
        slot = cache.length % cache_len if window else cache.length    # (B,)
        rows = jnp.arange(b)
        kf_new = cache.k.at[rows, slot].set(
            k.reshape(b, kvh * hd).astype(cache.k.dtype)
        )
        vf_new = cache.v.at[rows, slot].set(
            v.reshape(b, kvh * hd).astype(cache.v.dtype)
        )
        new_cache = KVCache(kf_new, vf_new, cache.length + 1)
        k_all = kf_new.reshape(b, cache_len, kvh, hd)
        v_all = vf_new.reshape(b, cache_len, kvh, hd)
        # mask: valid cached positions only, per sequence
        kp = jnp.arange(cache_len)[None, :]                            # (1, Sk)
        if window:
            valid = kp < jnp.minimum(cache.length + 1, cache_len)[:, None]
        else:
            valid = kp <= cache.length[:, None]                        # (B, Sk)
        scores = _gqa_scores(qg, k_all) * (hd**-0.5)
        w = _softmax(scores, valid[:, None, None, None, :])
        out = _gqa_out(w, v_all)
    else:
        if cache is not None and not is_cross and cache_update:
            # prefill: populate cache with the (window-tail of) *processed*
            # K/V — post qk-norm and post-RoPE, matching what decode writes.
            k_proc = k.reshape(b, -1, kvh * hd)
            cache_len = cache.k.shape[1]
            if window and s > cache_len:
                # rolling layout: token t lives at slot t % window, so the
                # decode-time writer evicts the oldest token, not arbitrary.
                k_tail = jnp.roll(k_proc[:, -cache_len:, :], s % cache_len, axis=1)
                v_tail = jnp.roll(v_f[:, -cache_len:, :], s % cache_len, axis=1)
            else:
                k_tail, v_tail = k_proc, v_f
            kf_new = jax.lax.dynamic_update_slice(
                cache.k, k_tail.astype(cache.k.dtype), (0, 0, 0)
            )
            vf_new = jax.lax.dynamic_update_slice(
                cache.v, v_tail.astype(cache.v.dtype), (0, 0, 0)
            )
            new_cache = KVCache(kf_new, vf_new, jnp.full((b,), s, jnp.int32))
        elif is_cross and cache_update and cache is not None:
            new_cache = KVCache(k_f.astype(cache.k.dtype), v_f.astype(cache.v.dtype),
                                jnp.full((b,), k_f.shape[1], jnp.int32))
        if s > q_chunk and s % q_chunk == 0:
            out = _chunked_attention(qg, k, v, cfg, causal=causal and not is_cross,
                                     window=window, chunk=q_chunk)
        else:
            out = _dense_attention(qg, k, v, cfg, causal=causal and not is_cross,
                                   window=window)

    out = out.reshape(b, s, cfg.q_dim).astype(x.dtype)
    out = shard_hint(out, "act_batch", None, "act_heads")
    y = out @ p["wo"]
    if "bo" in p:
        y = y + p["bo"]
    return shard_hint(y, "act_batch", "act_seq", "act_embed"), new_cache

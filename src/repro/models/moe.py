"""Mixture-of-Experts layer with ENEAC capacity-chunk dispatch.

The routing plan comes from :mod:`repro.core.moe_dispatch`: experts are the
accelerators (fixed ``capacity`` chunk each), the shared fallback FFN is the
CPU-core path absorbing overflow.  Expert weights are annotated with
logical axes so the mesh rules pick expert-parallelism when the expert
count divides the model axis (qwen3-moe: 128/16) and fall back to
tensor-parallel expert FFNs otherwise (grok-1: 8 experts).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import moe_dispatch as md
from ..parallel.compat import shard_map
from ..parallel.mesh_rules import shard_hint
from .layers import Builder
from .ffn import ffn, ffn_params

__all__ = ["moe_params", "moe_ffn", "moe_capacity"]


def moe_capacity(cfg: ModelConfig, tokens: int) -> int:
    """Static per-expert chunk (the ACC chunk size) for `tokens` per step."""
    c = int(cfg.parallel.capacity_factor * tokens * cfg.experts_per_token / cfg.num_experts)
    # round up to an MXU-friendly multiple
    return max(8, ((c + 7) // 8) * 8)


def moe_params(b: Builder, cfg: ModelConfig):
    d = cfg.d_model
    eff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    p = {
        "router": b.param("router", (d, E), ("embed", None), scale=0.02),
        "w1": b.param("w1", (E, d, eff), ("experts", "expert_embed", "expert_mlp")),
        "w3": b.param("w3", (E, d, eff), ("experts", "expert_embed", "expert_mlp")),
        "w2": b.param("w2", (E, eff, d), ("experts", "expert_mlp", "expert_embed")),
    }
    if cfg.parallel.moe_fallback:
        with b.scope("fallback"):
            p["fallback"] = ffn_params(b, d, eff)
    return p


def _expert_ffn(p, xe: jax.Array) -> jax.Array:
    """xe: (E, C, d) → (E, C, d), batched SwiGLU over experts (MXU path).

    Sharding: experts over the model axis where divisible (EP), capacity
    chunks over the DP axes always — expert weights are FSDP+TP sharded,
    so the partitioner all-gathers weights (normal FSDP) while tokens stay
    distributed.
    """
    xe = shard_hint(xe, "act_experts", "act_capacity", None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w1"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w3"]
    )
    # ff stays tensor-parallel when the expert dim couldn't take the model
    # axis (grok: 8 experts vs 16) — act_mlp resolves to None automatically
    # when "model" is already consumed by act_experts (qwen3-moe).
    h = shard_hint(h, "act_experts", "act_capacity", "act_mlp")
    out = jnp.einsum("ecf,efd->ecd", h, p["w2"])
    return shard_hint(out, "act_experts", "act_capacity", None)


def moe_ffn(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """x: (B, S, d) → (B, S, d), plus aux metrics/losses.

    Two dispatch strategies (``cfg.parallel.moe_dispatch``):

    * ``"gspmd"`` — global sort-based dispatch under pjit; the partitioner
      derives the collectives.  Simple, but GSPMD materializes replicated
      (E, C, d) buffers for the cross-shard gathers at 100B+ scale.
    * ``"local"`` — shard_map per-DP-shard routing (production path): each
      DP shard routes its own tokens with its own capacity chunk (exactly
      one ENEAC worker per shard).  Activations are TP-replicated within a
      model group, so each device serves the experts (or expert shards) it
      owns and the combine reduces to the same psum a dense FFN needs —
      zero extra collectives, zero cross-device scatters.
    """
    from ..parallel.mesh_rules import current_rules

    rules = current_rules()
    if cfg.parallel.moe_dispatch == "local" and rules is not None:
        return _moe_ffn_local(p, x, cfg, rules)
    b_, s_, d = x.shape
    T = b_ * s_
    xt = x.reshape(T, d)
    xt = shard_hint(xt, "act_batch", None)         # tokens stay DP-sharded

    router_logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    router_logits = shard_hint(router_logits, "act_batch", None)
    routing = md.route_topk(router_logits, cfg.experts_per_token)
    capacity = moe_capacity(cfg, T)
    plan = md.make_dispatch_plan(
        routing.expert_ids, routing.expert_probs, cfg.num_experts, capacity
    )

    xe = md.dispatch(xt, plan)                     # (E, C, d) — ACC chunks
    xe = shard_hint(xe, "act_experts", "act_capacity", None)  # EP all-to-all
    ye = _expert_ffn(p, xe)                        # expert (accelerator) path

    if cfg.parallel.moe_fallback and "fallback" in p:
        yf = ffn(p["fallback"], x).reshape(T, d)   # CC path: dense fallback
    else:
        yf = jnp.zeros_like(xt)                    # paper-less baseline: drop

    out = md.combine(ye, yf, plan).reshape(b_, s_, d)
    load, overflow = md.expert_load_stats(plan)
    aux = {
        "moe_aux_loss": routing.aux_loss,
        "moe_z_loss": routing.router_z_loss,
        "moe_overflow_frac": overflow,
        "moe_load_max": jnp.max(load),
    }
    return out.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# shard_map local dispatch (production path)
# ---------------------------------------------------------------------------
def _moe_ffn_local(p, x: jax.Array, cfg: ModelConfig, rules) -> Tuple[jax.Array, dict]:
    from jax.sharding import PartitionSpec as P

    from ..parallel.mesh_rules import hints_disabled

    mesh = rules.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_ax = "model" if "model" in mesh.axis_names else None
    E = cfg.num_experts
    eff = cfg.moe_d_ff or cfg.d_ff
    ep = bool(model_ax) and E % mesh.shape[model_ax] == 0  # expert parallel?

    # progressive divisibility (a batch of 1 falls back to replication)
    batch_spec = rules.spec(("act_batch", None, None), x.shape)

    # weight specs mirror the true param shardings (from the mesh rules)
    w_in_shape = (E, cfg.d_model, eff)
    w_out_shape = (E, eff, cfg.d_model)
    w_in_spec = rules.spec(("experts", "expert_embed", "expert_mlp"), w_in_shape)
    w_out_spec = rules.spec(("experts", "expert_mlp", "expert_embed"), w_out_shape)
    router_spec = P(None, None)
    fb_specs = (
        {
            "w1": rules.spec(("embed", "mlp"), (cfg.d_model, eff)),
            "w3": rules.spec(("embed", "mlp"), (cfg.d_model, eff)),
            "w2": rules.spec(("mlp", "embed"), (eff, cfg.d_model)),
        }
        if cfg.parallel.moe_fallback and "fallback" in p
        else None
    )

    def _regather(w, spec, axes_to_gather):
        """Un-shard FSDP'd dims (standard per-layer weight gather)."""
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for name in names:
                if name in axes_to_gather:
                    w = jax.lax.all_gather(w, name, axis=dim, tiled=True)
        return w

    fsdp_axes = set(dp_axes)

    def local_fn(router_w, w1, w3, w2, fb, xb):
        with hints_disabled():
            bb, ss, d = xb.shape
            T = bb * ss
            xt = xb.reshape(T, d)
            w1 = _regather(w1, w_in_spec, fsdp_axes)
            w3 = _regather(w3, w_in_spec, fsdp_axes)
            w2 = _regather(w2, w_out_spec, fsdp_axes)
            if fb is not None:
                fb = dict(fb)
                fb["w1"] = _regather(fb["w1"], fb_specs["w1"], fsdp_axes)
                fb["w3"] = _regather(fb["w3"], fb_specs["w3"], fsdp_axes)
                fb["w2"] = _regather(fb["w2"], fb_specs["w2"], fsdp_axes)

            logits = xt.astype(jnp.float32) @ router_w.astype(jnp.float32)
            routing = md.route_topk(logits, cfg.experts_per_token)
            capacity = moe_capacity(cfg, T)
            plan = md.make_dispatch_plan(
                routing.expert_ids, routing.expert_probs, E, capacity
            )
            # experts on this shard: all E (TP over ff) or the local slice (EP)
            if ep:
                e_loc = w1.shape[0]
                idx = jax.lax.axis_index(model_ax)
                lo = idx * e_loc
                sub_plan = md.DispatchPlan(
                    slot_token=jax.lax.dynamic_slice_in_dim(plan.slot_token, lo, e_loc, 0),
                    slot_valid=jax.lax.dynamic_slice_in_dim(plan.slot_valid, lo, e_loc, 0),
                    slot_index=plan.slot_index,
                    expert_ids=plan.expert_ids,
                    gate=plan.gate,
                    overflow=plan.overflow,
                    num_experts=e_loc,
                    capacity=capacity,
                )
                xe = md.dispatch(xt, sub_plan)                      # (E_loc, C, d)
            else:
                xe = md.dispatch(xt, plan)                          # (E, C, d)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w1)) * jnp.einsum(
                "ecd,edf->ecf", xe, w3
            )
            ye = jnp.einsum("ecf,efd->ecd", h, w2)                  # partial if !ep

            if fb is not None:
                hf = jax.nn.silu(xt @ fb["w1"]) * (xt @ fb["w3"])
                yf = hf @ fb["w2"]                                  # partial over model
            else:
                yf = jnp.zeros_like(xt)

            # In both layouts each model shard holds a PARTIAL result —
            # EP: only its experts' rows populated (fallback ff-sliced);
            # TP: ff-partial sums for experts and fallback alike —
            # so ONE psum over the model axis completes the combine.  This
            # is the same collective a dense FFN needs: local dispatch adds
            # zero extra communication.
            if ep:
                ye = _place_rows(ye, E, lo)
            out = md.combine(ye, yf, plan)
            if model_ax:
                out = jax.lax.psum(out, model_ax)
            load, overflow_frac = md.expert_load_stats(plan)
            aux = (
                routing.aux_loss,
                routing.router_z_loss,
                overflow_frac,
                jnp.max(load),
            )
            if dp_axes:
                aux = tuple(jax.lax.pmean(a, dp_axes) for a in aux)
            return out.reshape(bb, ss, d).astype(xb.dtype), *aux

    fb_arg = p.get("fallback") if fb_specs is not None else None
    out, aux_l, z_l, ov, lm = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(router_spec, w_in_spec, w_in_spec, w_out_spec,
                  fb_specs, batch_spec),
        out_specs=(batch_spec, P(), P(), P(), P()),
        check_vma=False,
    )(p["router"], p["w1"], p["w3"], p["w2"], fb_arg, x)
    aux = {
        "moe_aux_loss": aux_l,
        "moe_z_loss": z_l,
        "moe_overflow_frac": ov,
        "moe_load_max": lm,
    }
    return out, aux


def _place_rows(ye: jax.Array, total: int, lo) -> jax.Array:
    """Embed (E_loc, C, d) at row offset ``lo`` of a zero (E, C, d)."""
    out = jnp.zeros((total, *ye.shape[1:]), ye.dtype)
    return jax.lax.dynamic_update_slice_in_dim(out, ye, lo, axis=0)

"""Shared model primitives + the parameter builder.

One ``build`` function per model family constructs parameters through a
:class:`Builder`, which produces — from the *same* code path — either real
initialized arrays (:class:`ArrayBuilder`), ``ShapeDtypeStruct`` stand-ins
for dry-run lowering (:class:`AbstractBuilder`), or logical-axis
PartitionSpecs (:class:`SpecBuilder`).  This guarantees the param tree, its
abstract shapes, and its sharding specs can never drift apart.
"""

from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Builder",
    "ArrayBuilder",
    "AbstractBuilder",
    "SpecBuilder",
    "rms_norm",
    "layer_norm",
    "apply_rope",
    "sinusoidal_positions",
    "cross_entropy_loss",
    "DTYPES",
]

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}


# ---------------------------------------------------------------------------
# parameter builder
# ---------------------------------------------------------------------------
class Builder:
    """Records a path scope; subclasses decide what a leaf is."""

    def __init__(self) -> None:
        self._scope: list[str] = []

    @contextmanager
    def scope(self, name: str):
        self._scope.append(name)
        try:
            yield self
        finally:
            self._scope.pop()

    @property
    def path(self) -> str:
        return "/".join(self._scope)

    def param(
        self,
        name: str,
        shape: Sequence[int],
        axes: Sequence[Optional[str]],
        *,
        init: str = "normal",
        scale: Optional[float] = None,
        dtype: Optional[Any] = None,
    ):
        raise NotImplementedError


class ArrayBuilder(Builder):
    """Real initialization.  Deterministic: the key for each param is the
    root key folded with a stable hash of its path, so adding params never
    reshuffles others."""

    def __init__(self, key: jax.Array, param_dtype) -> None:
        super().__init__()
        self.key = key
        self.param_dtype = param_dtype

    def _key_for(self, path: str) -> jax.Array:
        h = 0
        for ch in path:
            h = (h * 131 + ord(ch)) % (2**31 - 1)
        return jax.random.fold_in(self.key, h)

    def param(self, name, shape, axes, *, init="normal", scale=None, dtype=None):
        dtype = dtype or self.param_dtype
        path = f"{self.path}/{name}"
        if init == "normal":
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
            x = jax.random.normal(self._key_for(path), tuple(shape), jnp.float32) * std
        elif init == "zeros":
            x = jnp.zeros(tuple(shape), jnp.float32)
        elif init == "ones":
            x = jnp.ones(tuple(shape), jnp.float32)
        elif init == "constant":
            x = jnp.full(tuple(shape), scale, jnp.float32)
        elif init == "uniform":  # U[scale0, scale1] packed in scale tuple
            lo, hi = scale  # type: ignore[misc]
            u = jax.random.uniform(self._key_for(path), tuple(shape), jnp.float32)
            x = lo + (hi - lo) * u
        else:
            raise ValueError(f"unknown init {init!r}")
        return x.astype(dtype)


class AbstractBuilder(Builder):
    """ShapeDtypeStruct leaves — zero allocation, for .lower() dry-runs."""

    def __init__(self, param_dtype) -> None:
        super().__init__()
        self.param_dtype = param_dtype

    def param(self, name, shape, axes, *, init="normal", scale=None, dtype=None):
        return jax.ShapeDtypeStruct(tuple(shape), dtype or self.param_dtype)


class SpecBuilder(Builder):
    """Logical-axis tuples; resolved to PartitionSpec by parallel/mesh_rules."""

    def __init__(self) -> None:
        super().__init__()

    def param(self, name, shape, axes, *, init="normal", scale=None, dtype=None):
        if len(axes) != len(shape):
            raise ValueError(
                f"param {self.path}/{name}: {len(shape)}-d shape with "
                f"{len(axes)} logical axes {axes}"
            )
        return tuple(axes)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) or (S,).  Split-half convention."""
    b, s, h, d = x.shape
    freqs = _rope_freqs(d, theta)                      # (d/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings (frames, d)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def cross_entropy_loss(
    logits: jax.Array,        # (..., V) any float dtype
    labels: jax.Array,        # (...) int32
    mask: Optional[jax.Array] = None,
    *,
    z_loss: float = 0.0,
) -> Tuple[jax.Array, jax.Array]:
    """Mean token NLL in fp32 (+ optional z-loss); returns (loss, denom)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if z_loss:
        nll = nll + z_loss * lse**2
    if mask is not None:
        m = mask.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(m), 1.0)
        return jnp.sum(nll * m) / denom, denom
    denom = jnp.asarray(nll.size, jnp.float32)
    return jnp.mean(nll), denom

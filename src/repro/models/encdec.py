"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment, the conv frontend is a STUB: the model consumes
precomputed frame embeddings (B, S_enc, d_model) from ``input_specs()``.
LayerNorm + biased projections + GELU MLPs (whisper convention),
sinusoidal encoder positions, learned decoder positions, no RoPE.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.mesh_rules import shard_hint
from .attention import KVCache, abstract_kv_cache, attention, attention_params, init_kv_cache
from .ffn import gelu_ffn, gelu_ffn_params
from .layers import Builder, layer_norm, sinusoidal_positions
from .transformer import _StackedBuilder, _zero_aux

__all__ = [
    "build_encdec_params",
    "encoder_forward",
    "decoder_forward_encdec",
    "init_encdec_caches",
    "abstract_encdec_caches",
]

MAX_DECODER_POS = 32768


def _ln_params(b: Builder, name: str, d: int):
    return {
        "w": b.param(f"{name}_w", (d,), ("embed",), init="ones"),
        "b": b.param(f"{name}_b", (d,), ("embed",), init="zeros"),
    }


def _enc_block_params(b: Builder, cfg: ModelConfig):
    d = cfg.d_model
    return {
        "ln_attn": _ln_params(b, "ln_attn", d),
        "attn": attention_params(b, cfg, bias=True),
        "ln_mlp": _ln_params(b, "ln_mlp", d),
        "mlp": gelu_ffn_params(b, d, cfg.d_ff),
    }


def _dec_block_params(b: Builder, cfg: ModelConfig):
    d = cfg.d_model
    return {
        "ln_attn": _ln_params(b, "ln_attn", d),
        "attn": attention_params(b, cfg, bias=True),
        "ln_xattn": _ln_params(b, "ln_xattn", d),
        "xattn": attention_params(b, cfg, bias=True),
        "ln_mlp": _ln_params(b, "ln_mlp", d),
        "mlp": gelu_ffn_params(b, d, cfg.d_ff),
    }


def build_encdec_params(b: Builder, cfg: ModelConfig):
    d, v = cfg.d_model, cfg.padded_vocab
    params: Dict[str, Any] = {}
    with b.scope("embed"):
        params["embed"] = b.param("table", (v, d), ("vocab", None), scale=0.02)
        params["dec_pos"] = b.param(
            "dec_pos", (MAX_DECODER_POS, d), (None, "embed"), scale=0.01
        )
    eb = _StackedBuilder(b, cfg.encoder_layers)
    with b.scope("encoder"):
        params["enc_blocks"] = _enc_block_params(eb, cfg)
        params["enc_ln_out"] = _ln_params(b, "ln_out", d)
    db = _StackedBuilder(b, cfg.num_layers)
    with b.scope("decoder"):
        params["dec_blocks"] = _dec_block_params(db, cfg)
        params["dec_ln_out"] = _ln_params(b, "ln_out", d)
    return params


def _ln(x, p, cfg):
    return layer_norm(x, p["w"], p["b"], cfg.norm_eps)


def encoder_forward(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings → encoder states."""
    b_, s, d = frames.shape
    x = frames + sinusoidal_positions(s, d).astype(frames.dtype)[None]
    x = shard_hint(x, "act_batch", "act_seq", "act_embed")

    def body(x, p):
        h, _ = attention(p["attn"], _ln(x, p["ln_attn"], cfg), cfg,
                         causal=False, rope=False)
        x = x + h
        x = x + gelu_ffn(p["mlp"], _ln(x, p["ln_mlp"], cfg))
        return x, 0.0

    if cfg.parallel.scan_layers:
        body_fn = jax.checkpoint(body) if cfg.parallel.remat != "none" else body
        x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    else:
        for i in range(cfg.encoder_layers):
            p = jax.tree.map(lambda q: q[i], params["enc_blocks"])
            x, _ = body(x, p)
    return _ln(x, params["enc_ln_out"], cfg)


def init_encdec_caches(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    L = cfg.num_layers

    def stack(c):
        return jax.tree.map(lambda x: jnp.stack([x] * L), c)

    return {
        "self": stack(init_kv_cache(cfg, batch, max_len)),
        "cross": stack(init_kv_cache(cfg, batch, enc_len)),
    }


def abstract_encdec_caches(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    L = cfg.num_layers

    def stack(c):
        return jax.tree.map(lambda s: jax.ShapeDtypeStruct((L, *s.shape), s.dtype), c)

    return {
        "self": stack(abstract_kv_cache(cfg, batch, max_len)),
        "cross": stack(abstract_kv_cache(cfg, batch, enc_len)),
    }


def encdec_cache_specs(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    from .attention import kv_cache_specs

    def is_axes(x):
        return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)

    def stack(tree):
        return jax.tree.map(lambda axes: (None, *axes), tree, is_leaf=is_axes)

    return {"self": stack(kv_cache_specs(cfg)), "cross": stack(kv_cache_specs(cfg))}


def decoder_forward_encdec(
    params,
    tokens: jax.Array,                # (B, S)
    enc_out: jax.Array,               # (B, S_enc, d)
    cfg: ModelConfig,
    *,
    mode: str = "train",
    positions: Optional[jax.Array] = None,
    caches=None,
):
    """Returns (hidden, new_caches, aux)."""
    b_, s = tokens.shape
    x = params["embed"][tokens]
    if positions is None:
        positions = jnp.arange(s)[None, :]
    pos_emb = params["dec_pos"][positions.reshape(-1)].reshape(b_ if positions.shape[0] == b_ else 1, s, -1)
    x = x + pos_emb.astype(x.dtype)
    x = shard_hint(x, "act_batch", "act_seq", "act_embed")
    decode = mode == "decode"

    def block(x, p, cache):
        self_c = cache["self"] if cache is not None else None
        cross_c = cache["cross"] if cache is not None else None
        h, new_self = attention(p["attn"], _ln(x, p["ln_attn"], cfg), cfg,
                                positions=positions, cache=self_c, rope=False)
        x = x + h
        h, new_cross = attention(p["xattn"], _ln(x, p["ln_xattn"], cfg), cfg,
                                 kv_x=enc_out, causal=False, cache=cross_c,
                                 cache_update=not decode, rope=False)
        x = x + h
        x = x + gelu_ffn(p["mlp"], _ln(x, p["ln_mlp"], cfg))
        new_cache = {"self": new_self, "cross": new_cross} if cache is not None else None
        return x, new_cache

    if cfg.parallel.scan_layers:
        has_cache = caches is not None
        block_fn = jax.checkpoint(block) if cfg.parallel.remat != "none" else block
        if has_cache:
            # caches in the carry: in-place (aliased) layer updates
            def body(carry, p):
                x, bufs, i = carry
                c = jax.tree.map(
                    lambda b: jax.lax.dynamic_index_in_dim(b, i, 0, keepdims=False),
                    bufs,
                )
                x, nc = block_fn(x, p, c)
                bufs = jax.tree.map(
                    lambda b, n: jax.lax.dynamic_update_index_in_dim(
                        b, n.astype(b.dtype), i, 0
                    ),
                    bufs,
                    nc,
                )
                return (x, bufs, i + 1), 0.0

            (x, new_caches, _), _ = jax.lax.scan(
                body, (x, caches, jnp.zeros((), jnp.int32)), params["dec_blocks"]
            )
        else:

            def body(carry, p):
                x, _ = block_fn(carry, p, None)
                return x, 0.0

            x, _ = jax.lax.scan(body, x, params["dec_blocks"])
            new_caches = None
    else:
        new_list = [] if caches is not None else None
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda q: q[i], params["dec_blocks"])
            c = jax.tree.map(lambda q: q[i], caches) if caches is not None else None
            x, nc = block(x, p, c)
            if caches is not None:
                new_list.append(nc)
        new_caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_list) if caches is not None else None
        )
    x = _ln(x, params["dec_ln_out"], cfg)
    return x, new_caches, _zero_aux()

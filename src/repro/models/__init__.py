"""Model zoo: all assigned architectures over shared functional blocks."""

from .model_factory import Model, make_model

__all__ = ["Model", "make_model"]

"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence:  a_t = exp(c · r_t · log σ(Λ))   (input-dependent decay)
             h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill evaluates the linear recurrence with
``jax.lax.associative_scan`` (log-depth, TPU-friendly); decode is the O(1)
single-step update — which is why recurrentgemma runs the long_500k cell.
Gates use the paper's block-diagonal (8-block) projections.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.mesh_rules import shard_hint
from .layers import Builder

__all__ = ["rglru_params", "RGLRUState", "rglru_block", "init_rglru_state", "abstract_rglru_state"]

_C = 8.0          # the paper's fixed exponent scale
_N_BLOCKS = 8     # block-diagonal gate blocks


class RGLRUState(NamedTuple):
    conv: jax.Array   # (B, conv_width-1, lru_width)
    h: jax.Array      # (B, lru_width) recurrent state (fp32)


def _lw(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def init_rglru_state(cfg: ModelConfig, batch: int):
    lw = _lw(cfg)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return RGLRUState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, lw), dt),
        h=jnp.zeros((batch, lw), jnp.float32),
    )


def abstract_rglru_state(cfg: ModelConfig, batch: int):
    lw = _lw(cfg)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return RGLRUState(
        conv=jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, lw), dt),
        h=jax.ShapeDtypeStruct((batch, lw), jnp.float32),
    )


def rglru_state_specs(cfg: ModelConfig, batch: int = 0):
    return RGLRUState(conv=("act_batch", None, "act_mlp"), h=("act_batch", "act_mlp"))


def rglru_params(b: Builder, cfg: ModelConfig):
    d, lw, w = cfg.d_model, _lw(cfg), cfg.conv_width
    blk = lw // _N_BLOCKS
    return {
        "w_x": b.param("w_x", (d, lw), ("embed", "lru")),
        "w_gate": b.param("w_gate", (d, lw), ("embed", "lru")),
        "w_out": b.param("w_out", (lw, d), ("lru", "embed")),
        "conv_w": b.param("conv_w", (w, lw), (None, "conv_ch"), scale=0.1),
        "conv_b": b.param("conv_b", (lw,), ("conv_ch",), init="zeros"),
        # block-diagonal input/recurrence gates over the post-conv features
        "gate_r_w": b.param("gate_r_w", (_N_BLOCKS, blk, blk), (None, None, None)),
        "gate_r_b": b.param("gate_r_b", (lw,), ("lru",), init="zeros"),
        "gate_i_w": b.param("gate_i_w", (_N_BLOCKS, blk, blk), (None, None, None)),
        "gate_i_b": b.param("gate_i_b", (lw,), ("lru",), init="zeros"),
        # Λ init so that a = σ(Λ)^c lands in [0.9, 0.999]
        "lam": b.param("lam", (lw,), ("lru",), init="uniform", scale=(0.9, 4.0)),
    }


def _blockdiag(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (..., lw), w: (nb, blk, blk) → (..., lw)."""
    nb, blk, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, blk)
    y = jnp.einsum("...nb,nbc->...nc", xs, w)
    return y.reshape(*x.shape[:-1], nb * blk) + b


def _causal_conv(x, w, b):
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _gates(p, xc: jax.Array):
    """log_a (fp32, ≤0) and gated input multiplier from post-conv features."""
    r = jax.nn.sigmoid(_blockdiag(xc, p["gate_r_w"], p["gate_r_b"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_blockdiag(xc, p["gate_i_w"], p["gate_i_b"]).astype(jnp.float32))
    log_a = _C * r * jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
    return log_a, i


def rglru_block(
    p,
    x: jax.Array,                     # (B, S, d)
    cfg: ModelConfig,
    *,
    state: Optional[RGLRUState] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[RGLRUState]]:
    b, s, d = x.shape
    lw = _lw(cfg)

    xb = x @ p["w_x"]
    xb = shard_hint(xb, "act_batch", None, "act_mlp")
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32))

    if decode:
        assert state is not None and s == 1
        window = jnp.concatenate([state.conv, xb], axis=1)
        xc = jnp.einsum(
            "bwc,wc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
        ) + p["conv_b"].astype(jnp.float32)
        new_conv = window[:, 1:, :]
        log_a, i_g = _gates(p, xc)
        a = jnp.exp(log_a)
        beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
        h_new = a * state.h + beta * (i_g * xc)
        y = h_new[:, None, :]
        new_state = RGLRUState(conv=new_conv.astype(state.conv.dtype), h=h_new)
    else:
        xc = _causal_conv(xb, p["conv_w"], p["conv_b"]).astype(jnp.float32)
        log_a, i_g = _gates(p, xc)
        a = jnp.exp(log_a)
        beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
        bterm = beta * (i_g * xc)                          # (B,S,lw)
        if state is not None:
            # fold carried state into the first step's additive term
            bterm = bterm.at[:, 0, :].add(a[:, 0, :] * state.h)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        _, hs = jax.lax.associative_scan(combine, (a, bterm), axis=1)
        y = hs
        new_state = None
        if state is not None:
            new_state = RGLRUState(
                conv=xb[:, -(cfg.conv_width - 1):, :].astype(state.conv.dtype),
                h=hs[:, -1, :],
            )

    out = (gate * y).astype(x.dtype) @ p["w_out"]
    return shard_hint(out, "act_batch", "act_seq", "act_embed"), new_state

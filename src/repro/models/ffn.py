"""Feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.mesh_rules import shard_hint
from .layers import Builder

__all__ = ["ffn_params", "ffn", "gelu_ffn_params", "gelu_ffn"]


def ffn_params(b: Builder, d: int, ff: int):
    """SwiGLU: gate (w1), up (w3), down (w2)."""
    return {
        "w1": b.param("w1", (d, ff), ("embed", "mlp")),
        "w3": b.param("w3", (d, ff), ("embed", "mlp")),
        "w2": b.param("w2", (ff, d), ("mlp", "embed")),
    }


def ffn(p, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    h = shard_hint(h, "act_batch", None, "act_mlp")
    y = h @ p["w2"]
    return shard_hint(y, "act_batch", "act_seq", "act_embed")


def gelu_ffn_params(b: Builder, d: int, ff: int):
    return {
        "w1": b.param("w1", (d, ff), ("embed", "mlp")),
        "b1": b.param("b1", (ff,), ("mlp",), init="zeros"),
        "w2": b.param("w2", (ff, d), ("mlp", "embed")),
        "b2": b.param("b2", (d,), ("embed",), init="zeros"),
    }


def gelu_ffn(p, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ p["w1"] + p["b1"])
    h = shard_hint(h, "act_batch", None, "act_mlp")
    y = h @ p["w2"] + p["b2"]
    return shard_hint(y, "act_batch", "act_seq", "act_embed")

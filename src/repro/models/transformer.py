"""Decoder-only transformer supporting every assigned family.

A model is a *block pattern* repeated ``repeats`` times (+ an unrolled
remainder), scanned with ``jax.lax.scan`` over stacked parameters — the
production structure for 100-layer nets: HLO stays one-pattern-sized,
compiles in seconds at 512 devices, and remat applies per pattern group.

Patterns per family:
  dense   ("attn",) × L
  moe     ("moe",)  × L
  ssm     ("ssd",)  × L
  hybrid  ("rglru","rglru","attn") × 12  + remainder ("rglru","rglru")
  vlm     ("attn",)×4 + ("cross",)  × (L/5)
(whisper's encoder/decoder stacks live in encdec.py and reuse these blocks)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.mesh_rules import shard_hint
from .attention import KVCache, abstract_kv_cache, attention, attention_params, init_kv_cache
from .ffn import ffn, ffn_params, gelu_ffn, gelu_ffn_params
from .layers import Builder, layer_norm, rms_norm
from .moe import moe_ffn, moe_params
from .rglru import abstract_rglru_state, init_rglru_state, rglru_block, rglru_params
from .ssm import abstract_ssm_state, init_ssm_state, ssd_block, ssd_params

__all__ = ["pattern_of", "build_decoder_params", "decoder_forward", "Context", "init_caches",
           "abstract_caches", "AUX_KEYS"]

AUX_KEYS = ("moe_aux_loss", "moe_z_loss", "moe_overflow_frac", "moe_load_max")


@dataclasses.dataclass
class Context:
    mode: str                           # "train" | "prefill" | "decode"
    positions: Optional[jax.Array] = None
    img_embeds: Optional[jax.Array] = None   # (B, n_img, d) VLM stub input
    enc_out: Optional[jax.Array] = None      # (B, S_enc, d) whisper decoder
    max_len: int = 0                         # cache capacity for prefill


def pattern_of(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    if cfg.family == "dense":
        pat: Tuple[str, ...] = ("attn",)
    elif cfg.family == "moe":
        pat = ("moe",)
    elif cfg.family == "ssm":
        pat = ("ssd",)
    elif cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rglru", "rglru", "attn")
    elif cfg.family == "vlm":
        ce = cfg.cross_attn_every or 5
        pat = ("attn",) * (ce - 1) + ("cross",)
    else:
        raise ValueError(f"pattern_of: unsupported family {cfg.family}")
    repeats, rem = divmod(cfg.num_layers, len(pat))
    return pat, repeats, pat[:rem]


# ---------------------------------------------------------------------------
# per-kind parameter builders
# ---------------------------------------------------------------------------
def _block_params(b: Builder, cfg: ModelConfig, kind: str):
    d = cfg.d_model
    if kind == "attn":
        return {
            "ln_attn": b.param("ln_attn", (d,), ("embed",), init="zeros"),
            "attn": attention_params(b, cfg),
            "ln_mlp": b.param("ln_mlp", (d,), ("embed",), init="zeros"),
            "mlp": ffn_params(b, d, cfg.d_ff),
        }
    if kind == "moe":
        return {
            "ln_attn": b.param("ln_attn", (d,), ("embed",), init="zeros"),
            "attn": attention_params(b, cfg),
            "ln_mlp": b.param("ln_mlp", (d,), ("embed",), init="zeros"),
            "moe": moe_params(b, cfg),
        }
    if kind == "ssd":
        return {
            "ln": b.param("ln", (d,), ("embed",), init="zeros"),
            "ssd": ssd_params(b, cfg),
        }
    if kind == "rglru":
        return {
            "ln_rec": b.param("ln_rec", (d,), ("embed",), init="zeros"),
            "rec": rglru_params(b, cfg),
            "ln_mlp": b.param("ln_mlp", (d,), ("embed",), init="zeros"),
            "mlp": ffn_params(b, d, cfg.d_ff),
        }
    if kind == "cross":
        return {
            "ln_attn": b.param("ln_attn", (d,), ("embed",), init="zeros"),
            "attn": attention_params(b, cfg),
            "ln_xattn": b.param("ln_xattn", (d,), ("embed",), init="zeros"),
            "xattn": attention_params(b, cfg),
            "gate_attn": b.param("gate_attn", (), (), init="zeros"),
            "ln_mlp": b.param("ln_mlp", (d,), ("embed",), init="zeros"),
            "mlp": ffn_params(b, d, cfg.d_ff),
            "gate_mlp": b.param("gate_mlp", (), (), init="zeros"),
        }
    raise ValueError(f"unknown block kind {kind!r}")


class _StackedBuilder(Builder):
    """Proxy adding a leading ``stack`` dim to every param."""

    def __init__(self, inner: Builder, n: int) -> None:
        super().__init__()
        self.inner = inner
        self.n = n

    def scope(self, name):
        return self.inner.scope(name)

    def param(self, name, shape, axes, **kw):
        return self.inner.param(name, (self.n, *shape), ("stack", *axes), **kw)


def build_decoder_params(b: Builder, cfg: ModelConfig):
    pat, repeats, rem = pattern_of(cfg)
    d, v = cfg.d_model, cfg.padded_vocab
    params: Dict[str, Any] = {}
    with b.scope("embed"):
        # vocab-only sharding: a doubly-sharded table forces an involuntary
        # full rematerialization in the SPMD partitioner on the token gather
        params["embed"] = b.param("table", (v, d), ("vocab", None), scale=0.02)
    sb = _StackedBuilder(b, repeats)
    blocks = []
    for j, kind in enumerate(pat):
        with b.scope(f"pat{j}_{kind}"):
            blocks.append(_block_params(sb, cfg, kind))
    params["blocks"] = blocks
    remainder = []
    for j, kind in enumerate(rem):
        with b.scope(f"rem{j}_{kind}"):
            remainder.append(_block_params(b, cfg, kind))
    params["remainder"] = remainder
    params["final_norm"] = b.param("final_norm", (d,), ("embed",), init="zeros")
    if not cfg.tie_embeddings:
        with b.scope("lm_head"):
            params["lm_head"] = b.param("w", (d, v), ("embed", "vocab"), scale=0.02)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def _cache_for(cfg: ModelConfig, kind: str, batch: int, max_len: int, abstract: bool):
    kv = abstract_kv_cache if abstract else init_kv_cache
    if kind in ("attn", "moe"):
        window = cfg.window if cfg.family == "hybrid" else 0
        return kv(cfg, batch, max_len, window)
    if kind == "ssd":
        return (abstract_ssm_state if abstract else init_ssm_state)(cfg, batch)
    if kind == "rglru":
        return (abstract_rglru_state if abstract else init_rglru_state)(cfg, batch)
    if kind == "cross":
        window = 0
        return {
            "self": kv(cfg, batch, max_len, window),
            "cross": kv(cfg, batch, cfg.num_image_tokens, 0),
        }
    raise ValueError(kind)


def _stack_tree(trees: List[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    pat, repeats, rem = pattern_of(cfg)
    stacked = [
        _stack_tree([_cache_for(cfg, kind, batch, max_len, False) for _ in range(repeats)])
        for kind in pat
    ]
    remainder = [_cache_for(cfg, kind, batch, max_len, False) for kind in rem]
    return {"blocks": stacked, "remainder": remainder}


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int):
    pat, repeats, rem = pattern_of(cfg)

    def stack_sds(sds):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((repeats, *s.shape), s.dtype), sds
        )

    stacked = [stack_sds(_cache_for(cfg, kind, batch, max_len, True)) for kind in pat]
    remainder = [_cache_for(cfg, kind, batch, max_len, True) for kind in rem]
    return {"blocks": stacked, "remainder": remainder}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """Logical-axes tree mirroring init/abstract_caches (stack dim added)."""
    from .attention import kv_cache_specs
    from .rglru import rglru_state_specs
    from .ssm import ssm_state_specs

    def spec_for(kind):
        if kind in ("attn", "moe"):
            return kv_cache_specs(cfg)
        if kind == "ssd":
            return ssm_state_specs(cfg)
        if kind == "rglru":
            return rglru_state_specs(cfg)
        if kind == "cross":
            return {"self": kv_cache_specs(cfg), "cross": kv_cache_specs(cfg)}
        raise ValueError(kind)

    def is_axes(x):
        return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)

    def stack(tree):
        return jax.tree.map(lambda axes: (None, *axes), tree, is_leaf=is_axes)

    pat, repeats, rem = pattern_of(cfg)
    return {
        "blocks": [stack(spec_for(kind)) for kind in pat],
        "remainder": [spec_for(kind) for kind in rem],
    }


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------
def _norm(x, w, cfg):
    return rms_norm(x, w, cfg.norm_eps)


def _apply_block(kind, p, x, cfg, ctx: Context, cache):
    """Returns (x, new_cache, aux_dict)."""
    decode = ctx.mode == "decode"
    aux: Dict[str, jax.Array] = {}
    window = cfg.window if cfg.family == "hybrid" else 0

    if kind in ("attn", "moe"):
        h, new_kv = attention(
            p["attn"], _norm(x, p["ln_attn"], cfg), cfg,
            positions=ctx.positions, window=window, cache=cache,
        )
        x = x + h
        if kind == "attn":
            x = x + ffn(p["mlp"], _norm(x, p["ln_mlp"], cfg))
        else:
            h, aux = moe_ffn(p["moe"], _norm(x, p["ln_mlp"], cfg), cfg)
            x = x + h
        return x, new_kv, aux

    if kind == "ssd":
        h, new_state = ssd_block(p["ssd"], _norm(x, p["ln"], cfg), cfg,
                                 state=cache, decode=decode)
        return x + h, new_state, aux

    if kind == "rglru":
        h, new_state = rglru_block(p["rec"], _norm(x, p["ln_rec"], cfg), cfg,
                                   state=cache, decode=decode)
        x = x + h
        x = x + ffn(p["mlp"], _norm(x, p["ln_mlp"], cfg))
        return x, new_state, aux

    if kind == "cross":
        self_cache = cache["self"] if cache is not None else None
        cross_cache = cache["cross"] if cache is not None else None
        h, new_self = attention(
            p["attn"], _norm(x, p["ln_attn"], cfg), cfg,
            positions=ctx.positions, cache=self_cache,
        )
        x = x + h
        xh, new_cross = attention(
            p["xattn"], _norm(x, p["ln_xattn"], cfg), cfg,
            kv_x=ctx.img_embeds, causal=False, cache=cross_cache,
            cache_update=not decode, rope=False,
        )
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * xh
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * ffn(
            p["mlp"], _norm(x, p["ln_mlp"], cfg)
        )
        new_cache = None
        if cache is not None:
            new_cache = {"self": new_self, "cross": new_cross}
        return x, new_cache, aux

    raise ValueError(kind)


def _zero_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


def _acc_aux(acc, aux):
    out = dict(acc)
    for k, v in aux.items():
        out[k] = out.get(k, jnp.zeros((), jnp.float32)) + v.astype(jnp.float32)
    return out


# ---------------------------------------------------------------------------
# full decoder
# ---------------------------------------------------------------------------
def decoder_forward(
    params,
    tokens: jax.Array,                 # (B, S) int32
    cfg: ModelConfig,
    ctx: Context,
    caches=None,
):
    """Returns (final_hidden (B,S,d), new_caches, aux)."""
    pat, repeats, rem = pattern_of(cfg)
    x = params["embed"][tokens]
    if cfg.family == "hybrid":  # gemma-style embedding scale
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    x = shard_hint(x, "act_batch", "act_seq", "act_embed")

    use_scan = cfg.parallel.scan_layers and repeats > 1
    remat = cfg.parallel.remat != "none"

    def pattern_step(x, ps, cs):
        new_caches = []
        aux = _zero_aux()
        for j, kind in enumerate(pat):
            c = cs[j] if cs is not None else None
            x, nc, a = _apply_block(kind, ps[j], x, cfg, ctx, c)
            new_caches.append(nc)
            aux = _acc_aux(aux, a)
        return x, tuple(new_caches), aux

    if remat:
        pattern_step = jax.checkpoint(pattern_step, static_argnums=())

    if use_scan:
        has_cache = caches is not None
        if has_cache:
            # caches ride in the CARRY with indexed in-place updates: XLA
            # aliases while-loop carries, so the serve step holds ONE cache
            # buffer (donated in and out) instead of an xs + ys pair — at
            # grok/vision decode scale that pair alone blows past HBM.
            cache_carry = tuple(caches["blocks"])

            def body(carry, ps):
                x, aux_acc, bufs, i = carry
                cs = jax.tree.map(
                    lambda b: jax.lax.dynamic_index_in_dim(b, i, 0, keepdims=False),
                    bufs,
                )
                x, ncs, aux = pattern_step(x, ps, cs)
                bufs = jax.tree.map(
                    lambda b, n: jax.lax.dynamic_update_index_in_dim(
                        b, n.astype(b.dtype), i, 0
                    ),
                    bufs,
                    ncs,
                )
                aux_acc = _acc_aux(aux_acc, aux)
                return (x, aux_acc, bufs, i + 1), 0.0

            (x, aux_acc, cache_carry, _), _ = jax.lax.scan(
                body,
                (x, _zero_aux(), cache_carry, jnp.zeros((), jnp.int32)),
                tuple(params["blocks"]),
            )
            new_block_caches = list(cache_carry)
        else:

            def body(carry, ps):
                x, aux_acc = carry
                x, ncs, aux = pattern_step(x, ps, None)
                aux_acc = _acc_aux(aux_acc, aux)
                return (x, aux_acc), 0.0

            (x, aux_acc), _ = jax.lax.scan(body, (x, _zero_aux()), tuple(params["blocks"]))
            new_block_caches = None
    else:
        aux_acc = _zero_aux()
        new_block_caches = [] if caches is not None else None
        for r in range(repeats):
            ps = jax.tree.map(lambda p: p[r], tuple(params["blocks"]))
            cs = (
                jax.tree.map(lambda c: c[r], tuple(caches["blocks"]))
                if caches is not None
                else None
            )
            x, ncs, aux = pattern_step(x, ps, cs)
            aux_acc = _acc_aux(aux_acc, aux)
            if caches is not None:
                new_block_caches.append(ncs)
        if caches is not None and new_block_caches:
            # restack to match the scan layout
            new_block_caches = [
                jax.tree.map(lambda *xs: jnp.stack(xs), *[ncs for ncs in new_block_caches])
            ]
            new_block_caches = list(new_block_caches[0])

    # remainder blocks (unrolled)
    new_rem = [] if caches is not None else None
    for j, kind in enumerate(rem):
        c = caches["remainder"][j] if caches is not None else None
        x, nc, aux = _apply_block(kind, params["remainder"][j], x, cfg, ctx, c)
        aux_acc = _acc_aux(aux_acc, aux)
        if caches is not None:
            new_rem.append(nc)

    x = _norm(x, params["final_norm"], cfg)
    new_caches = (
        {"blocks": new_block_caches, "remainder": new_rem} if caches is not None else None
    )
    return x, new_caches, aux_acc


def lm_logits(params, hidden: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return hidden @ params["embed"].T
    return hidden @ params["lm_head"]

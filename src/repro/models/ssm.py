"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Training/prefill uses the *chunked* SSD algorithm: the sequence is split
into chunks of ``cfg.ssm_chunk``; each chunk computes a quadratic
(attention-like, MXU-friendly) intra-chunk term plus a rank-decomposed
inter-chunk term carried by a sequential scan over chunk summaries.  This
is the TPU-native formulation: the intra-chunk einsums are dense
(chunk × chunk)·(chunk × head_dim) matmuls that tile onto the MXU, and the
inter-chunk scan carries only the (heads, head_dim, state) tensor.

Decode carries the recurrent state directly: O(1) per token — which is why
mamba2 runs the long_500k cell that full-attention archs must skip.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.mesh_rules import shard_hint
from .layers import Builder, rms_norm

__all__ = ["ssd_params", "SSMState", "ssd_block", "init_ssm_state", "abstract_ssm_state"]


class SSMState(NamedTuple):
    conv: jax.Array   # (B, conv_width-1, d_inner + 2*state) rolling conv inputs
    h: jax.Array      # (B, heads, head_dim, state) recurrent state


def init_ssm_state(cfg: ModelConfig, batch: int):
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return SSMState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dt),
        h=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n), jnp.float32),
    )


def abstract_ssm_state(cfg: ModelConfig, batch: int):
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return SSMState(
        conv=jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, di + 2 * n), dt),
        h=jax.ShapeDtypeStruct((batch, cfg.ssm_heads, cfg.ssm_head_dim, n), jnp.float32),
    )


def ssm_state_specs(cfg: ModelConfig, batch: int = 0):
    return SSMState(
        conv=("act_batch", None, "act_mlp"),
        h=("act_batch", None, None, None),
    )


def ssd_params(b: Builder, cfg: ModelConfig):
    d, di, n, nh, w = (
        cfg.d_model,
        cfg.ssm_d_inner,
        cfg.ssm_state,
        cfg.ssm_heads,
        cfg.conv_width,
    )
    return {
        # z (gate), x, B, C, dt — one fused projection, mamba2-style
        "in_proj": b.param("in_proj", (d, 2 * di + 2 * n + nh), ("embed", "ssm_inner")),
        "conv_w": b.param("conv_w", (w, di + 2 * n), (None, "conv_ch"), scale=0.1),
        "conv_b": b.param("conv_b", (di + 2 * n,), ("conv_ch",), init="zeros"),
        "A_log": b.param("A_log", (nh,), ("ssm_heads",), init="uniform", scale=(0.0, 1.5)),
        "D": b.param("D", (nh,), ("ssm_heads",), init="ones"),
        "dt_bias": b.param("dt_bias", (nh,), ("ssm_heads",), init="uniform", scale=(-4.6, -2.3)),
        "norm": b.param("norm", (di,), ("ssm_inner",), init="zeros"),
        "out_proj": b.param("out_proj", (di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (W,C) → (B,S,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):  # W is tiny (4): unrolled taps fuse into one kernel
        out = out + xp[:, i : i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, n, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n :]
    return z, xBC, dt


def _ssd_chunked(x, log_a, Bm, Cm, chunk: int, h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x: (B,S,H,P) already scaled by dt;  log_a: (B,S,H) = dt·A (negative);
    Bm, Cm: (B,S,N).  Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    s_orig = s
    if s % chunk:
        # pad: log_a=0 (decay 1) and B=0 ⇒ padding never touches the state
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    xr = x.reshape(b, nc, chunk, h, p)
    ar = log_a.reshape(b, nc, chunk, h)
    Br = Bm.reshape(b, nc, chunk, n)
    Cr = Cm.reshape(b, nc, chunk, n)

    a_cs = jnp.cumsum(ar, axis=2)                                    # (b,nc,Q,h)
    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j
    seg = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]            # (b,nc,Q,Q,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    S = jnp.einsum("bcin,bcjn->bcij", Cr, Br)                        # (b,nc,Q,Q)
    M = S[..., None] * L                                             # (b,nc,Q,Q,h)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xr.astype(jnp.float32))

    # chunk summary states: sum_j exp(cs_Q - cs_j) B_j ⊗ x_j
    decay_out = jnp.exp(a_cs[:, :, -1:, :] - a_cs)                   # (b,nc,Q,h)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Br, decay_out, xr.astype(jnp.float32))
    chunk_decay = jnp.exp(a_cs[:, :, -1, :])                         # (b,nc,h)

    def scan_fn(h_prev, inp):
        st, dec = inp
        return h_prev * dec[:, :, None, None] + st, h_prev

    h_init = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0
    h_final, h_prevs = jax.lax.scan(
        scan_fn,
        h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                       # (b,nc,h,p,n)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", Cr, jnp.exp(a_cs), h_prevs)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y[:, :s_orig], h_final


def ssd_block(
    p,
    x: jax.Array,                       # (B, S, d)
    cfg: ModelConfig,
    *,
    state: Optional[SSMState] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[SSMState]]:
    b, s, d = x.shape
    di, n, nh, hd = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"]
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                     # (nh,) negative
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if decode:
        assert state is not None and s == 1
        window = jnp.concatenate([state.conv, xBC], axis=1)          # (B, W, C)
        conv_out = jnp.einsum(
            "bwc,wc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
        ) + p["conv_b"].astype(jnp.float32)
        xBC_t = jax.nn.silu(conv_out)                                # (B, C)
        new_conv = window[:, 1:, :]
        xs = xBC_t[:, :di].reshape(b, nh, hd)
        Bm = xBC_t[:, di : di + n]
        Cm = xBC_t[:, di + n :]
        dt_t = dt[:, 0]                                              # (B, nh)
        decay = jnp.exp(dt_t * A[None, :])                           # (B, nh)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt_t, Bm, xs.astype(jnp.float32))
        h_new = state.h * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm, h_new)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(b, 1, di)
        new_state = SSMState(conv=new_conv.astype(state.conv.dtype), h=h_new)
    else:
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]).astype(jnp.float32)).astype(x.dtype)
        xs = xBC[..., :di].reshape(b, s, nh, hd)
        Bm = xBC[..., di : di + n].astype(jnp.float32)
        Cm = xBC[..., di + n :].astype(jnp.float32)
        x_dt = xs.astype(jnp.float32) * dt[..., None]                # fold dt into x
        log_a = dt * A[None, None, :]                                # (B,S,nh)
        h0 = state.h if state is not None else None
        y, h_final = _ssd_chunked(x_dt, log_a, Bm, Cm, cfg.ssm_chunk, h0)
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(b, s, di)
        new_state = None
        if state is not None:  # prefill: hand decode the final state
            # conv tail: last (W-1) pre-activation conv inputs
            tail = xBC  # post-conv; decode needs pre-conv inputs — recompute:
            new_state = SSMState(
                conv=jax.lax.dynamic_slice_in_dim(
                    (x @ p["in_proj"])[..., di : 2 * di + 2 * n],
                    s - (cfg.conv_width - 1),
                    cfg.conv_width - 1,
                    axis=1,
                ).astype(state.conv.dtype),
                h=h_final,
            )

    y = rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype), p["norm"], cfg.norm_eps
    )
    out = y @ p["out_proj"]
    return shard_hint(out, "act_batch", "act_seq", "act_embed"), new_state

"""Model factory: one uniform API over all assigned architectures.

``Model`` bundles init / abstract params / sharding specs / forward /
loss / serve steps for a :class:`~repro.configs.base.ModelConfig`.  The
same object drives training (`examples/train_small.py`), serving
(`serving/engine.py`), and the multi-pod dry-run (`launch/dryrun.py`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import InputShape, ModelConfig
from ..parallel.mesh_rules import shard_hint
from . import encdec, transformer
from .layers import AbstractBuilder, ArrayBuilder, DTYPES, SpecBuilder, cross_entropy_loss

__all__ = ["Model", "make_model"]

MOE_AUX_COEF = 0.01
MOE_Z_COEF = 1e-3


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def _build(self, builder):
        if self.cfg.family == "encdec":
            return encdec.build_encdec_params(builder, self.cfg)
        return transformer.build_decoder_params(builder, self.cfg)

    def init(self, key: jax.Array):
        return self._build(ArrayBuilder(key, DTYPES[self.cfg.param_dtype]))

    def abstract_params(self):
        return self._build(AbstractBuilder(DTYPES[self.cfg.param_dtype]))

    def param_specs(self):
        return self._build(SpecBuilder())

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------
    def forward(self, params, batch: Dict[str, jax.Array], *, mode: str = "train",
                caches=None):
        """Returns (hidden (B,S,d), new_caches, aux)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        positions = batch.get("positions")
        if cfg.family == "encdec":
            if mode == "decode":
                b_ = tokens.shape[0]
                enc_out = jnp.zeros((b_, 1, cfg.d_model), DTYPES[cfg.dtype])
            else:
                enc_out = encdec.encoder_forward(params, batch["frames"], cfg)
            return encdec.decoder_forward_encdec(
                params, tokens, enc_out, cfg, mode=mode, positions=positions,
                caches=caches,
            )
        img = batch.get("image_embeds")
        if cfg.family == "vlm" and img is None and mode == "decode":
            img = jnp.zeros((tokens.shape[0], 1, cfg.d_model), DTYPES[cfg.dtype])
        ctx = transformer.Context(mode=mode, positions=positions, img_embeds=img)
        return transformer.decoder_forward(params, tokens, cfg, ctx, caches)

    def logits(self, params, hidden):
        if self.cfg.family == "encdec":
            return hidden @ params["embed"].T
        return transformer.lm_logits(params, hidden, self.cfg)

    # ------------------------------------------------------------------
    # training loss (chunked over sequence: never materializes full logits)
    # ------------------------------------------------------------------
    def loss_fn(self, params, batch, *, loss_chunk: int = 1024):
        cfg = self.cfg
        hidden, _, aux = self.forward(params, batch, mode="train")
        labels = batch["labels"]
        mask = batch.get("mask")
        b_, s, d = hidden.shape

        head = params["embed"].T if (cfg.tie_embeddings or cfg.family == "encdec") \
            else params["lm_head"]

        if loss_chunk and s > loss_chunk and s % loss_chunk == 0:
            nc = s // loss_chunk
            hs = hidden.reshape(b_, nc, loss_chunk, d).transpose(1, 0, 2, 3)
            ls = labels.reshape(b_, nc, loss_chunk).transpose(1, 0, 2)
            ms = (
                mask.reshape(b_, nc, loss_chunk).transpose(1, 0, 2)
                if mask is not None
                else jnp.ones((nc, b_, loss_chunk), jnp.float32)
            )

            def body(acc, xs):
                h, l, m = xs
                logits = h @ head
                lf = logits.astype(jnp.float32)
                lse = jax.nn.logsumexp(lf, axis=-1)
                picked = jnp.take_along_axis(lf, l[..., None], axis=-1)[..., 0]
                nll = (lse - picked) * m
                return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(m)), 0.0

            (tot, denom), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms))
            loss = tot / jnp.maximum(denom, 1.0)
        else:
            logits = self.logits(params, hidden)
            loss, denom = cross_entropy_loss(logits, labels, mask)

        metrics = {"ce_loss": loss, **aux}
        if cfg.family == "moe":
            loss = loss + MOE_AUX_COEF * aux["moe_aux_loss"] + MOE_Z_COEF * aux["moe_z_loss"]
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.init_encdec_caches(cfg, batch, max_len, cfg.encoder_seq)
        return transformer.init_caches(cfg, batch, max_len)

    def abstract_caches(self, batch: int, max_len: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.abstract_encdec_caches(cfg, batch, max_len, cfg.encoder_seq)
        return transformer.abstract_caches(cfg, batch, max_len)

    def cache_specs(self, batch: int, max_len: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec.encdec_cache_specs(cfg, batch, max_len, cfg.encoder_seq)
        return transformer.cache_specs(cfg, batch, max_len)

    def prefill(self, params, batch, max_len: int):
        """Full-sequence prefill → (last-position logits, populated caches)."""
        b_ = batch["tokens"].shape[0]
        caches = self.init_caches(b_, max_len)
        hidden, caches, _ = self.forward(params, batch, mode="prefill", caches=caches)
        return self.logits(params, hidden[:, -1:, :])[:, 0, :], caches

    def prefill_from(self, params, batch, caches):
        hidden, caches, _ = self.forward(params, batch, mode="prefill", caches=caches)
        return self.logits(params, hidden[:, -1:, :])[:, 0, :], caches

    def decode_step(self, params, tokens, positions, caches):
        """tokens: (B,1) → (logits (B,V), new_caches)."""
        batch = {"tokens": tokens, "positions": positions}
        hidden, caches, _ = self.forward(batch=batch, params=params, mode="decode",
                                         caches=caches)
        return self.logits(params, hidden)[:, 0, :], caches

    # ------------------------------------------------------------------
    # dry-run inputs (ShapeDtypeStruct stand-ins, no allocation)
    # ------------------------------------------------------------------
    def input_specs(self, shape: InputShape) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        act = DTYPES[cfg.dtype]
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch = {
                "tokens": sds((B, S), i32),
                "labels": sds((B, S), i32),
                "mask": sds((B, S), jnp.float32),
            }
            if cfg.family == "encdec":
                batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), act)
            if cfg.family == "vlm":
                batch["image_embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model), act)
            return {"batch": batch}
        if shape.kind == "prefill":
            batch = {"tokens": sds((B, S), i32)}
            if cfg.family == "encdec":
                batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), act)
            if cfg.family == "vlm":
                batch["image_embeds"] = sds((B, cfg.num_image_tokens, cfg.d_model), act)
            return {"batch": batch}
        # decode: one new token against a seq_len-sized cache
        return {
            "tokens": sds((B, 1), i32),
            "positions": sds((B, 1), i32),
            "caches": self.abstract_caches(B, S),
        }

    # ------------------------------------------------------------------
    # analytic costs (for the roofline's MODEL_FLOPS row)
    # ------------------------------------------------------------------
    def model_flops(self, shape: InputShape) -> float:
        n = self.cfg.active_param_count()
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        if shape.kind == "train":
            return 6.0 * n * tokens
        return 2.0 * n * tokens


def make_model(cfg: ModelConfig) -> Model:
    return Model(cfg)

"""Continuous-batching serving engine driven by the ENEAC scheduler.

The serving translation of the paper's design: the decode batch has B
*slots* (compute units); the request queue is the iteration space.  Two
refill policies, benchmarked against each other (Table-1-style isolation
of the completion-driven mechanism):

* ``"static"`` — the no-interrupt baseline: a batch of requests runs to
  the LAST finisher before any new request is admitted (host "polls" at
  batch granularity; finished slots idle — the busy-wait analogue).
* ``"continuous"`` — completion-driven: the moment a sequence finishes,
  its slot is refilled at the next step boundary (offload on
  availability, per the MultiDynamic rule).  Throughput gain over
  ``static`` grows with generation-length variance — the serving
  equivalent of the paper's irregular-workload result.

Request admission is a thin client of
:class:`~repro.core.runtime.HeteroRuntime`: each decode slot registers as
a compute unit and ``run()`` opens a :class:`~repro.core.runtime.WorkQueue`
over an :class:`~repro.core.space.IterationSpace` of the submitted
requests — a :class:`~repro.core.space.FlatSpace` whose indices are queue
positions, scheduled in unit-size chunks — so which request a freed slot
picks up, and all per-slot utilization/coverage accounting, comes from
the same completion-driven scheduler that powers ``parallel_for``.  The
closing :class:`~repro.core.interrupts.RunReport` of the most recent
batch is exposed as :attr:`ServingEngine.last_run_report` (per-slot
coverage, utilization, load balance — what the serving bench prints).

Slot state lives in the batched KV caches; a new request is prefilled
with batch=1 and spliced into its slot (pytree scatter on the batch dim).
``backend="threads"`` dispatches those prefills to per-slot
:class:`~repro.core.backends.ThreadUnit`\\ s so the decode loop keeps
stepping active slots while newcomers prefill — the backend-unit layer
applied at the serving tier; ``backend="inline"`` (default) keeps the
fully synchronous, deterministic admission path.
``backend="remote:<host:port>[,<host:port>...]"`` goes one step further:
each slot's prefill unit is a :class:`~repro.core.transport.RemoteUnit`
and admissions prefill in *worker subprocesses* (round-robin over the
addresses); because the work crosses a pickling transport, remote mode
needs ``model_spec={"config", "smoke", "seed"}`` so workers can rebuild
the model+params deterministically, and prefill results (the batch=1
cache + first token) travel back in the completion frame.  See
``docs/architecture.md`` for how serving maps onto the runtime.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.backends import BackendUnit, CompletionBus, ThreadUnit
from ..core.runtime import HeteroRuntime, WorkQueue
from ..core.scheduler import WorkerKind
from ..core.space import FlatSpace
from ..core.transport import RemoteUnit
from ..models import Model
from .sampling import sample

__all__ = ["Request", "RequestResult", "ServingEngine"]


# ---------------------------------------------------------------------------
# remote prefill: picklable work + a per-process model cache on the worker
# ---------------------------------------------------------------------------
_WORKER_MODELS: Dict[tuple, tuple] = {}
_WORKER_MODELS_LOCK = threading.Lock()


def _worker_model(spec: dict):
    """Build (model, params) once per worker process for a model spec."""
    key = (spec["config"], bool(spec.get("smoke", False)),
           int(spec.get("seed", 0)))
    with _WORKER_MODELS_LOCK:
        if key not in _WORKER_MODELS:
            from ..configs import get_config
            from ..models import make_model

            cfg = get_config(key[0])
            if key[1]:
                cfg = cfg.smoke()
            model = make_model(cfg)
            params = model.init(jax.random.PRNGKey(key[2]))
            _WORKER_MODELS[key] = (model, params)
        return _WORKER_MODELS[key]


class _RemotePrefill:
    """One request's prefill as picklable work for a remote worker.

    The worker rebuilds the model deterministically (same config + init
    seed => identical params), prefills batch=1, and returns the single-
    slot cache as numpy (device-free, transportable) plus the first
    greedy token; the driver splices both into the decode batch.
    """

    def __init__(self, spec: dict, prompt, max_len: int) -> None:
        self.spec = dict(spec)
        self.prompt = np.asarray(prompt, np.int32)
        self.max_len = int(max_len)

    def __call__(self, chunk):
        model, params = _worker_model(self.spec)
        prompt = jnp.asarray(self.prompt, jnp.int32)[None, :]
        single = model.init_caches(1, self.max_len)
        logits, single = model.prefill_from(params, {"tokens": prompt}, single)
        tok = int(np.asarray(sample(logits, temperature=0.0))[0])
        return jax.tree.map(np.asarray, single), tok


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    eos_id: int = -1              # -1: run to max_new_tokens


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: List[int]
    prompt_len: int
    submit_time: float
    finish_time: float

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time


def _splice_slot(batched, single, slot: int):
    """Insert a batch=1 cache pytree into slot ``slot`` of a batched one."""

    def one(b, s):
        if b.ndim == 0:
            return b
        # leading dims may include a stacked layer dim; batch dim is where
        # shapes diverge — caches built by the same model always put layers
        # first (stacked) then batch.  Handle both (B, ...) and (L, B, ...).
        if b.shape[0] == s.shape[0]:      # (L, B, ...) stacked
            return jax.vmap(lambda bb, ss: bb.at[slot].set(ss[0]))(b, s)
        return b.at[slot].set(s[0])

    return jax.tree.map(one, batched, single)


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        slots: int = 4,
        max_len: int = 512,
        mode: str = "continuous",
        temperature: float = 0.0,
        seed: int = 0,
        backend: str = "inline",
        model_spec: Optional[dict] = None,
    ) -> None:
        if mode not in ("continuous", "static"):
            raise ValueError(mode)
        is_remote = isinstance(backend, str) and backend.startswith("remote:")
        if backend not in ("inline", "threads", "thread") and not is_remote:
            raise ValueError(
                f"backend must be inline|threads|remote:<addr>[,...], "
                f"got {backend!r}"
            )
        if is_remote and not model_spec:
            raise ValueError(
                "backend='remote:...' needs model_spec={'config': name, "
                "'smoke': bool, 'seed': int} so workers can rebuild the "
                "model deterministically"
            )
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.mode = mode
        self.backend = "threads" if backend == "thread" else backend
        self.model_spec = dict(model_spec) if model_spec else None
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self.queue: Deque[Request] = deque()
        self.results: Dict[int, RequestResult] = {}
        self._submit_times: Dict[int, float] = {}

        # decode slots are the compute units; run() opens a WorkQueue over
        # the submitted requests so refill is completion-driven.  (Remote
        # prefill units are registered by instance below, so the runtime
        # registry itself stays backend-less for remote mode.)
        self.runtime = HeteroRuntime()
        for b in range(slots):
            self.runtime.register_unit(
                f"slot{b}", WorkerKind.ACC,
                backend=None if is_remote else self.backend,
            )
        self._feed: Optional[WorkQueue] = None
        self._pending: List[Request] = []
        self.last_run_report = None

        # backend="threads": prefill of admitted requests is dispatched to
        # a per-slot ThreadUnit so the decode loop keeps stepping while new
        # requests prefill — real asynchrony at the serving layer (the
        # decode step itself stays lockstep-batched).
        # backend="remote:...": the same per-slot units, but RemoteUnits —
        # prefills execute in worker subprocesses round-robin over the
        # given addresses and results come back in completion frames.
        self._prefill_units: Optional[Dict[int, BackendUnit]] = None
        self._prefill_bus: Optional[CompletionBus] = None
        self._prefilling: Dict[int, Request] = {}
        if self.backend == "threads":
            self._prefill_bus = CompletionBus()
            self._prefill_units = {
                b: ThreadUnit(f"slot{b}") for b in range(slots)
            }
        elif is_remote:
            addrs = self.backend[len("remote:"):].split(",")
            self._prefill_bus = CompletionBus()
            self._prefill_units = {
                b: RemoteUnit(f"slot{b}", address=addrs[b % len(addrs)])
                for b in range(slots)
            }

        self.caches = model.init_caches(slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.generated: List[List[int]] = [[] for _ in range(slots)]
        self.lengths = np.zeros(slots, np.int32)
        self.last_token = np.zeros(slots, np.int32)
        self.steps = 0

        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, t, pos, c)
        )

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._submit_times[req.rid] = time.perf_counter()
        self.queue.append(req)

    def _prefill(self, req: Request):
        """Batch=1 prefill + first greedy token (runs on a prefill unit)."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        single = self.model.init_caches(1, self.max_len)
        logits, single = self.model.prefill_from(self.params, {"tokens": prompt}, single)
        tok = int(np.asarray(sample(logits, temperature=0.0))[0])
        return single, tok

    def _install(self, slot: int, req: Request, single, tok: int) -> None:
        """Splice a finished prefill into its decode slot (driver thread)."""
        self.caches = _splice_slot(self.caches, single, slot)
        self.active[slot] = req
        self.generated[slot] = [tok]
        self.lengths[slot] = len(req.prompt)
        self.last_token[slot] = tok

    def _admit(self, slot: int) -> bool:
        if self._feed is None:
            return False
        chunk = self._feed.acquire(f"slot{slot}")
        if chunk is None:
            return False
        req = self._pending[chunk.start]
        if self._prefill_units is not None:
            # async admission: the slot's prefill unit works while the
            # decode loop keeps stepping the already-active slots; remote
            # units need picklable work, so they get a _RemotePrefill
            # instead of a closure over the live model
            if self.model_spec is not None:
                work = _RemotePrefill(self.model_spec, req.prompt,
                                      self.max_len)
            else:
                work = lambda c, req=req: self._prefill(req)  # noqa: E731
            self._prefilling[slot] = req
            self._prefill_units[slot].submit(chunk, work)
            return True
        self._install(slot, req, *self._prefill(req))
        return True

    def _collect_prefills(self, block: bool = False) -> None:
        """Splice any finished async prefills; optionally wait for one."""
        if self._prefill_bus is None or not self._prefilling:
            return
        if block:
            self._prefill_bus.wait(timeout=60.0)
        for rec in self._prefill_bus.drain():
            slot = int(rec.unit[len("slot"):])
            req = self._prefilling.pop(slot)
            if rec.error is not None:
                raise rec.error
            single, tok = rec.result
            self._install(slot, req, single, tok)

    def _finish(self, slot: int) -> None:
        req = self.active[slot]
        assert req is not None
        self.results[req.rid] = RequestResult(
            rid=req.rid,
            tokens=list(self.generated[slot]),
            prompt_len=len(req.prompt),
            submit_time=self._submit_times[req.rid],
            finish_time=time.perf_counter(),
        )
        self.active[slot] = None
        self.generated[slot] = []
        if self._feed is not None:
            self._feed.complete(f"slot{slot}")

    def _slot_done(self, slot: int) -> bool:
        req = self.active[slot]
        if req is None:
            return False
        toks = self.generated[slot]
        if len(toks) >= req.max_new_tokens:
            return True
        return req.eos_id >= 0 and toks and toks[-1] == req.eos_id

    # ------------------------------------------------------------------
    def run(self) -> Dict[int, RequestResult]:
        """Serve until the queue drains and all slots finish."""
        if self._prefill_units is not None:
            for unit in self._prefill_units.values():
                unit.start(self._prefill_bus)
        try:
            return self._run_loop()
        finally:
            if self._prefill_units is not None:
                for unit in self._prefill_units.values():
                    unit.close()

    def _run_loop(self) -> Dict[int, RequestResult]:
        while True:
            # snapshot newly-submitted requests into a fresh feed whenever
            # the previous one has fully drained (feeds are per-batch: the
            # scheduler's iteration space is fixed at open time)
            if self._feed is None and self.queue:
                self._pending = list(self.queue)
                self.queue.clear()
                self._feed = self.runtime.work_queue(
                    space=FlatSpace(len(self._pending)),
                    policy="multidynamic", acc_chunk=1,
                )
            # admit work into free slots (completion-driven in continuous
            # mode; batch-granularity in static mode — the polling analogue)
            if self.mode == "continuous" or all(a is None for a in self.active):
                for b in range(self.slots):
                    if self.active[b] is None and b not in self._prefilling:
                        self._admit(b)
            self._collect_prefills()
            if all(a is None for a in self.active):
                if self._prefilling:
                    # nothing decodable yet: sleep on the completion bus
                    self._collect_prefills(block=True)
                    continue
                if self._feed is not None:
                    self.last_run_report = self._feed.report()
                    self._attach_dispatch_stats(self.last_run_report)
                    self._feed = None
                if self.queue:  # submissions landed after the snapshot
                    continue
                return dict(self.results)

            tokens = jnp.asarray(self.last_token, jnp.int32)[:, None]
            positions = jnp.asarray(
                self.lengths + np.array([len(g) for g in self.generated], np.int32) - 1,
                jnp.int32,
            )[:, None]
            self.key, sk = jax.random.split(self.key)
            logits, self.caches = self._decode(self.params, tokens, positions, self.caches)
            nxt = np.asarray(
                sample(logits, sk, temperature=self.temperature)
            )
            self.steps += 1
            for b in range(self.slots):
                if self.active[b] is None:
                    continue
                tok = int(nxt[b])
                self.generated[b].append(tok)
                self.last_token[b] = tok
                if self._slot_done(b):
                    self._finish(b)

    def _attach_dispatch_stats(self, report) -> None:
        """Expose prefill dispatch latency per slot on the batch report."""
        if report is None or self._prefill_units is None:
            return
        stats = {}
        for b, unit in self._prefill_units.items():
            lats = unit.dispatch_latencies
            if lats:
                stats[f"slot{b}"] = sum(lats) / len(lats)
        report.dispatch_latency = stats or None

    # ------------------------------------------------------------------
    def throughput_report(self) -> Dict[str, float]:
        done = list(self.results.values())
        total_tokens = sum(len(r.tokens) for r in done)
        if not done:
            return {"tokens": 0, "steps": self.steps, "tokens_per_step": 0.0}
        return {
            "tokens": total_tokens,
            "steps": self.steps,
            "tokens_per_step": total_tokens / max(self.steps, 1),
            "mean_latency": float(np.mean([r.latency for r in done])),
        }

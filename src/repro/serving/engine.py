"""Continuous-batching serving engine driven by the ENEAC scheduler.

The serving translation of the paper's design: the decode batch has B
*slots* (compute units); the request queue is the iteration space.  Two
refill policies, benchmarked against each other (Table-1-style isolation
of the completion-driven mechanism):

* ``"static"`` — the no-interrupt baseline: a batch of requests runs to
  the LAST finisher before any new request is admitted (host "polls" at
  batch granularity; finished slots idle — the busy-wait analogue).
* ``"continuous"`` — completion-driven: the moment a sequence finishes,
  its slot is refilled at the next step boundary (offload on
  availability, per the MultiDynamic rule).  Throughput gain over
  ``static`` grows with generation-length variance — the serving
  equivalent of the paper's irregular-workload result.

Request admission is a thin client of
:class:`~repro.core.runtime.HeteroRuntime`: each decode slot registers as
a compute unit and ``run()`` opens a :class:`~repro.core.runtime.WorkQueue`
over an :class:`~repro.core.space.IterationSpace` of the submitted
requests — a :class:`~repro.core.space.FlatSpace` whose indices are queue
positions, scheduled in unit-size chunks — so which request a freed slot
picks up, and all per-slot utilization/coverage accounting, comes from
the same completion-driven scheduler that powers ``parallel_for``.  The
closing :class:`~repro.core.interrupts.RunReport` of the most recent
batch is exposed as :attr:`ServingEngine.last_run_report` (per-slot
coverage, utilization, load balance — what the serving bench prints).

*Which* request a freed slot picks up is decided by an
:class:`~repro.serving.admission.AdmissionPolicy`: when the engine
snapshots its queue into a scheduler feed, the snapshot is
policy-ordered (FIFO / priority / earliest-deadline-first / cost-aware
shortest-predicted-prefill-first), and ``submit()`` consults the same
policy for **backpressure** — it returns an
:class:`~repro.serving.admission.AdmissionVerdict`, and a bounded queue
sheds arrivals instead of growing without limit.  Per-request deadlines
(``Request.deadline``, relative seconds) flow into
:attr:`RequestResult.deadline` / ``met_deadline`` so goodput — tokens
that met their SLO — is measurable (see :mod:`repro.serving.loadgen`).

Slot state lives in the batched KV caches; a new request is prefilled
with batch=1 and spliced into its slot (pytree scatter on the batch dim).
``backend="threads"`` dispatches those prefills to per-slot
:class:`~repro.core.backends.ThreadUnit`\\ s so the decode loop keeps
stepping active slots while newcomers prefill — the backend-unit layer
applied at the serving tier; ``backend="inline"`` (default) keeps the
fully synchronous, deterministic admission path.
``backend="remote:<host:port>[,<host:port>...]"`` goes one step further:
each slot's prefill unit is a :class:`~repro.core.transport.RemoteUnit`
and admissions prefill in *worker subprocesses* (round-robin over the
addresses); because the work crosses a pickling transport, remote mode
needs ``model_spec={"config", "smoke", "seed"}`` so workers can rebuild
the model+params deterministically, and prefill results (the batch=1
cache + first token) travel back in the completion frame.  See
``docs/architecture.md`` for how serving maps onto the runtime.

Sampling is reproducible by construction: every sampled token uses a key
derived as ``fold_in(fold_in(PRNGKey(seed), rid), token_index)`` — a
pure function of the engine seed, the request id, and the position in
the stream — so a request's tokens do not depend on which other slots
happen to be occupied, which slot it lands in, or the admission order.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.backends import BackendUnit, CompletionBus, ThreadUnit
from ..core.runtime import HeteroRuntime, WorkQueue
from ..core.scheduler import WorkerKind
from ..core.space import FlatSpace
from ..core.transport import RemoteUnit
from ..models import Model
from .admission import AdmissionPolicy, AdmissionVerdict, make_policy
from .sampling import sample

__all__ = ["Request", "RequestResult", "ServingEngine"]


def _sample_key(seed_key: jax.Array, rid: int, index: int) -> jax.Array:
    """The per-token sampling key: pure in (seed, rid, stream index)."""
    return jax.random.fold_in(jax.random.fold_in(seed_key, rid), index)


# ---------------------------------------------------------------------------
# remote prefill: picklable work + a per-process model cache on the worker
# ---------------------------------------------------------------------------
_WORKER_MODELS: Dict[tuple, tuple] = {}
_WORKER_MODELS_LOCK = threading.Lock()


def _worker_model(spec: dict):
    """Build (model, params) once per worker process for a model spec."""
    key = (spec["config"], bool(spec.get("smoke", False)),
           int(spec.get("seed", 0)))
    with _WORKER_MODELS_LOCK:
        if key not in _WORKER_MODELS:
            from ..configs import get_config
            from ..models import make_model

            cfg = get_config(key[0])
            if key[1]:
                cfg = cfg.smoke()
            model = make_model(cfg)
            params = model.init(jax.random.PRNGKey(key[2]))
            _WORKER_MODELS[key] = (model, params)
        return _WORKER_MODELS[key]


_WORKER_PREFILL_STEPS: Dict[Tuple[int, int], Any] = {}


def _worker_prefill_step(model, max_len: int):
    """One jitted batch=1 prefill per (model, max_len) in this process."""
    key = (id(model), int(max_len))
    with _WORKER_MODELS_LOCK:
        if key not in _WORKER_PREFILL_STEPS:
            _WORKER_PREFILL_STEPS[key] = jax.jit(
                lambda p, toks: model.prefill(p, {"tokens": toks}, max_len)
            )
        return _WORKER_PREFILL_STEPS[key]


class _RemotePrefill:
    """One request's prefill as picklable work for a remote worker.

    The worker rebuilds the model deterministically (same config + init
    seed => identical params), prefills batch=1, and returns the single-
    slot cache as numpy (device-free, transportable) plus the first
    token — sampled with the *engine's* temperature under the same
    ``fold_in(fold_in(seed, rid), 0)`` key the driver would use, so
    remote admission is token-identical to inline admission.
    """

    def __init__(self, spec: dict, prompt, max_len: int, *,
                 rid: int, temperature: float, sample_seed: int) -> None:
        self.spec = dict(spec)
        self.prompt = np.asarray(prompt, np.int32)
        self.max_len = int(max_len)
        self.rid = int(rid)
        self.temperature = float(temperature)
        self.sample_seed = int(sample_seed)

    def __call__(self, chunk):
        model, params = _worker_model(self.spec)
        prompt = jnp.asarray(self.prompt, jnp.int32)[None, :]
        step = _worker_prefill_step(model, self.max_len)
        logits, single = step(params, prompt)
        key = _sample_key(jax.random.PRNGKey(self.sample_seed), self.rid, 0)
        tok = int(np.asarray(
            sample(logits, key, temperature=self.temperature)
        )[0])
        return jax.tree.map(np.asarray, single), tok


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int
    eos_id: int = -1              # -1: run to max_new_tokens
    priority: int = 0             # PriorityPolicy: higher served first
    deadline: Optional[float] = None   # SLO budget, seconds from submit
    submitted_at: Optional[float] = None  # stamped by ServingEngine.submit


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: List[int]
    prompt_len: int
    submit_time: float
    finish_time: float
    first_token_time: Optional[float] = None   # prefill completion (TTFT)
    deadline: Optional[float] = None           # absolute; None = no SLO
    error: Optional[str] = None                # failed prefill etc.

    @property
    def latency(self) -> float:
        return self.finish_time - self.submit_time

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (prefill completion), seconds."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def met_deadline(self) -> bool:
        """True iff the request finished successfully within its SLO
        (requests without a deadline always count)."""
        if self.error is not None:
            return False
        return self.deadline is None or self.finish_time <= self.deadline


def _splice_slot(batched, single, slot: int):
    """Insert a batch=1 cache pytree into slot ``slot`` of a batched one."""

    def one(b, s):
        if b.ndim == 0:
            return b
        # leading dims may include a stacked layer dim; batch dim is where
        # shapes diverge — caches built by the same model always put layers
        # first (stacked) then batch.  Handle both (B, ...) and (L, B, ...).
        if b.shape[0] == s.shape[0]:      # (L, B, ...) stacked
            return jax.vmap(lambda bb, ss: bb.at[slot].set(ss[0]))(b, s)
        return b.at[slot].set(s[0])

    return jax.tree.map(one, batched, single)


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        slots: int = 4,
        max_len: int = 512,
        mode: str = "continuous",
        temperature: float = 0.0,
        seed: int = 0,
        backend: str = "inline",
        model_spec: Optional[dict] = None,
        policy: Union[str, AdmissionPolicy, None] = "fifo",
        max_queue: Optional[int] = None,
        prefill_timeout: float = 60.0,
    ) -> None:
        if mode not in ("continuous", "static"):
            raise ValueError(mode)
        is_remote = isinstance(backend, str) and backend.startswith("remote:")
        if backend not in ("inline", "threads", "thread") and not is_remote:
            raise ValueError(
                f"backend must be inline|threads|remote:<addr>[,...], "
                f"got {backend!r}"
            )
        if is_remote and not model_spec:
            raise ValueError(
                "backend='remote:...' needs model_spec={'config': name, "
                "'smoke': bool, 'seed': int} so workers can rebuild the "
                "model deterministically"
            )
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.mode = mode
        self.backend = "threads" if backend == "thread" else backend
        self.model_spec = dict(model_spec) if model_spec else None
        self.temperature = temperature
        self.seed = int(seed)
        self._seed_key = jax.random.PRNGKey(self.seed)
        self.policy = make_policy(policy, max_queue=max_queue)
        self.prefill_timeout = float(prefill_timeout)

        self.queue: Deque[Request] = deque()
        self._queue_lock = threading.Lock()  # submit() may race _run_loop
        self.results: Dict[int, RequestResult] = {}
        self.shed: Dict[int, AdmissionVerdict] = {}
        self._submit_times: Dict[int, float] = {}
        self._deadlines: Dict[int, float] = {}      # rid -> absolute deadline
        self._first_token: Dict[int, float] = {}    # rid -> TTFT timestamp

        # decode slots are the compute units; run() opens a WorkQueue over
        # the submitted requests so refill is completion-driven.  (Remote
        # prefill units are registered by instance below, so the runtime
        # registry itself stays backend-less for remote mode.)
        self.runtime = HeteroRuntime()
        for b in range(slots):
            self.runtime.register_unit(
                f"slot{b}", WorkerKind.ACC,
                backend=None if is_remote else self.backend,
            )
        self._feed: Optional[WorkQueue] = None
        self._pending: List[Request] = []
        self._feed_exhausted = False
        # per-slot issuing feed: continuous mode retires an exhausted
        # feed while its chunks still decode, so completions must route
        # to the feed that issued them, not the current one
        self._slot_feed: List[Optional[WorkQueue]] = [None] * slots
        self._retired_feeds: List[WorkQueue] = []
        self.last_run_report = None

        # backend="threads": prefill of admitted requests is dispatched to
        # a per-slot ThreadUnit so the decode loop keeps stepping while new
        # requests prefill — real asynchrony at the serving layer (the
        # decode step itself stays lockstep-batched).
        # backend="remote:...": the same per-slot units, but RemoteUnits —
        # prefills execute in worker subprocesses round-robin over the
        # given addresses and results come back in completion frames.
        self._prefill_units: Optional[Dict[int, BackendUnit]] = None
        self._prefill_bus: Optional[CompletionBus] = None
        self._prefilling: Dict[int, Request] = {}
        if self.backend == "threads":
            self._prefill_bus = CompletionBus()
            self._prefill_units = {
                b: ThreadUnit(f"slot{b}") for b in range(slots)
            }
        elif is_remote:
            addrs = self.backend[len("remote:"):].split(",")
            self._prefill_bus = CompletionBus()
            self._prefill_units = {
                b: RemoteUnit(f"slot{b}", address=addrs[b % len(addrs)])
                for b in range(slots)
            }

        self.caches = model.init_caches(slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.generated: List[List[int]] = [[] for _ in range(slots)]
        self.lengths = np.zeros(slots, np.int32)
        self.last_token = np.zeros(slots, np.int32)
        self.steps = 0

        self._decode = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, t, pos, c)
        )
        # batch=1 prefill, cache init fused in (max_len is closed over,
        # so it is static to the trace); retraces once per prompt length
        self._prefill_step = jax.jit(
            lambda p, toks: model.prefill(p, {"tokens": toks}, max_len)
        )
        # cache splice compiles per slot index (one variant per slot) —
        # eager per-leaf updates cost about a decode step per admission
        self._splice = jax.jit(_splice_slot, static_argnums=(2,))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> AdmissionVerdict:
        """Offer a request; returns the policy's admit/shed verdict.

        Shed requests are *not* queued (no result will appear for them);
        they are recorded in :attr:`shed` keyed by rid.  Safe to call
        from a different thread than :meth:`run` (open-loop load
        generators submit while the engine serves).
        """
        now = time.perf_counter()
        with self._queue_lock:
            depth = len(self.queue)
        verdict = self.policy.admit(req, queue_depth=depth, now=now)
        if not verdict.admitted:
            self.shed[req.rid] = verdict
            return verdict
        req.submitted_at = now
        self._submit_times[req.rid] = now
        if req.deadline is not None:
            self._deadlines[req.rid] = now + req.deadline
        with self._queue_lock:
            self.queue.append(req)
        return verdict

    @property
    def has_work(self) -> bool:
        """True while anything is queued, prefilling, or decoding."""
        return (bool(self.queue) or bool(self._prefilling)
                or any(a is not None for a in self.active)
                or self._feed is not None)

    def _request_key(self, rid: int, index: int) -> jax.Array:
        return _sample_key(self._seed_key, rid, index)

    def _prefill(self, req: Request):
        """Batch=1 prefill + first token (runs on a prefill unit).

        The forward pass runs under ``jit`` (one compiled variant per
        prompt length — an eager prefill costs 10x+ a decode step in
        dispatch overhead alone, which would make admission, not
        scheduling, the serving bottleneck).  The first token honours
        the engine temperature under the request's position-0 key —
        decode steps continue the same per-(rid, index) key stream.
        """
        prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, single = self._prefill_step(self.params, prompt)
        tok = int(np.asarray(
            sample(logits, self._request_key(req.rid, 0),
                   temperature=self.temperature)
        )[0])
        return single, tok

    def _install(self, slot: int, req: Request, single, tok: int,
                 prefill_elapsed: Optional[float] = None) -> None:
        """Splice a finished prefill into its decode slot (driver thread)."""
        self.caches = self._splice(self.caches, single, slot)
        self.active[slot] = req
        self.generated[slot] = [tok]
        self.lengths[slot] = len(req.prompt)
        self.last_token[slot] = tok
        self._first_token[req.rid] = time.perf_counter()
        if prefill_elapsed is not None:
            self.policy.observe_prefill(
                f"slot{slot}", len(req.prompt), prefill_elapsed
            )

    def _fail(self, slot: int, req: Request, error: BaseException) -> None:
        """Record a failed admission and close its scheduler chunk.

        The request surfaces as a :class:`RequestResult` with ``error``
        set (empty token stream); the WorkQueue chunk completes so batch
        coverage accounting stays exact and draining continues.
        """
        self.results[req.rid] = RequestResult(
            rid=req.rid,
            tokens=[],
            prompt_len=len(req.prompt),
            submit_time=self._submit_times[req.rid],
            finish_time=time.perf_counter(),
            deadline=self._deadlines.get(req.rid),
            error=f"{type(error).__name__}: {error}",
        )
        self._complete_chunk(slot)

    def _admit(self, slot: int) -> bool:
        if self._feed is None:
            return False
        chunk = self._feed.acquire(f"slot{slot}")
        if chunk is None:
            # every request of this snapshot has been issued; in
            # continuous mode the run loop may now retire the feed and
            # re-snapshot, so queued arrivals join mid-batch
            self._feed_exhausted = True
            return False
        self._slot_feed[slot] = self._feed
        req = self._pending[chunk.start]
        if self._prefill_units is not None:
            # async admission: the slot's prefill unit works while the
            # decode loop keeps stepping the already-active slots; remote
            # units need picklable work, so they get a _RemotePrefill
            # instead of a closure over the live model
            if self.model_spec is not None:
                work = _RemotePrefill(self.model_spec, req.prompt,
                                      self.max_len, rid=req.rid,
                                      temperature=self.temperature,
                                      sample_seed=self.seed)
            else:
                work = lambda c, req=req: self._prefill(req)  # noqa: E731
            self._prefilling[slot] = req
            self._prefill_units[slot].submit(chunk, work)
            return True
        t0 = time.perf_counter()
        try:
            single, tok = self._prefill(req)
        except Exception as exc:
            self._fail(slot, req, exc)
            return True
        self._install(slot, req, single, tok,
                      prefill_elapsed=time.perf_counter() - t0)
        return True

    def _collect_prefills(self, block: bool = False) -> None:
        """Splice any finished async prefills; optionally wait for one.

        A prefill that errored surfaces as a failed :class:`RequestResult`
        (its chunk completes, draining continues — one poisoned request
        must not drop its batch-mates).  A blocking wait that expires
        with prefills still in flight raises, naming the stuck slots —
        a dead prefill unit must not turn ``run()`` into a silent spin.
        """
        if self._prefill_bus is None or not self._prefilling:
            return
        if block:
            arrived = self._prefill_bus.wait(timeout=self.prefill_timeout)
            if not arrived and self._prefilling:
                stuck = ", ".join(f"slot{s}" for s in sorted(self._prefilling))
                raise TimeoutError(
                    f"no prefill completion within {self.prefill_timeout:.1f}s "
                    f"with prefills still in flight on {stuck}; the unit(s) "
                    "are stuck or dead"
                )
        for rec in self._prefill_bus.drain():
            slot = int(rec.unit[len("slot"):])
            req = self._prefilling.pop(slot)
            if rec.error is not None:
                self._fail(slot, req, rec.error)
                continue
            single, tok = rec.result
            self._install(slot, req, single, tok,
                          prefill_elapsed=rec.elapsed)

    def _finish(self, slot: int) -> None:
        req = self.active[slot]
        assert req is not None
        self.results[req.rid] = RequestResult(
            rid=req.rid,
            tokens=list(self.generated[slot]),
            prompt_len=len(req.prompt),
            submit_time=self._submit_times[req.rid],
            finish_time=time.perf_counter(),
            first_token_time=self._first_token.get(req.rid),
            deadline=self._deadlines.get(req.rid),
        )
        self.active[slot] = None
        self.generated[slot] = []
        self._complete_chunk(slot)

    def _slot_done(self, slot: int) -> bool:
        req = self.active[slot]
        if req is None:
            return False
        toks = self.generated[slot]
        if len(toks) >= req.max_new_tokens:
            return True
        return req.eos_id >= 0 and toks and toks[-1] == req.eos_id

    # ------------------------------------------------------------------
    def run(self) -> Dict[int, RequestResult]:
        """Serve until the queue drains and all slots finish."""
        if self._prefill_units is not None:
            for unit in self._prefill_units.values():
                unit.start(self._prefill_bus)
        try:
            return self._run_loop()
        finally:
            if self._prefill_units is not None:
                for unit in self._prefill_units.values():
                    unit.close()

    def _snapshot_queue(self) -> None:
        """Open a policy-ordered feed over the currently queued requests."""
        with self._queue_lock:
            fresh = list(self.queue)
            self.queue.clear()
        if not fresh:
            return
        self._pending = self.policy.order(fresh, now=time.perf_counter())
        self._feed = self.runtime.work_queue(
            space=FlatSpace(len(self._pending)),
            policy="multidynamic", acc_chunk=1,
        )
        self._feed_exhausted = False

    def _retire_feed(self) -> None:
        """Stop acquiring from the current feed; report it when its last
        in-flight chunk completes (immediately if none are in flight)."""
        feed = self._feed
        self._feed = None
        if feed is None:
            return
        if any(f is feed for f in self._slot_feed):
            self._retired_feeds.append(feed)
        else:
            self.last_run_report = feed.report()
            self._attach_dispatch_stats(self.last_run_report)

    def _complete_chunk(self, slot: int) -> None:
        """Report the slot's chunk back to the feed that issued it.

        Continuous mode can retire a feed while its chunks still decode;
        the chunk must complete against the *issuing* feed (coverage
        accounting is per-feed), and a retired feed produces its
        RunReport when the last such chunk lands."""
        feed = self._slot_feed[slot]
        self._slot_feed[slot] = None
        if feed is None:
            return
        feed.complete(f"slot{slot}")
        if (feed is not self._feed
                and any(f is feed for f in self._retired_feeds)
                and not any(f is feed for f in self._slot_feed)):
            self._retired_feeds = [f for f in self._retired_feeds
                                   if f is not feed]
            self.last_run_report = feed.report()
            self._attach_dispatch_stats(self.last_run_report)

    def _admit_pass(self) -> bool:
        """Offer every free slot work from the feed; True if any chunk
        was acquired.  A failed synchronous admission leaves its slot
        free with the chunk already completed, so keep pulling until the
        slot is occupied or the feed has nothing left for it."""
        acquired = False
        for b in range(self.slots):
            while (self.active[b] is None
                   and b not in self._prefilling
                   and self._admit(b)):
                acquired = True
        return acquired

    def _run_loop(self) -> Dict[int, RequestResult]:
        while True:
            # a feed's iteration space is fixed at open time, so live
            # arrivals cannot join it.  Continuous mode therefore retires
            # an exhausted feed (all requests issued) as soon as new
            # arrivals are queued — without this, "continuous" degrades
            # to batch granularity under open-loop traffic: arrivals
            # would wait for the whole snapshot to drain even with slots
            # sitting free.
            if (self.mode == "continuous" and self._feed is not None
                    and self._feed_exhausted and self.queue):
                self._retire_feed()
            if self._feed is None and self.queue:
                self._snapshot_queue()
            # admit work into free slots (completion-driven in continuous
            # mode; batch-granularity in static mode — the polling analogue)
            if self.mode == "continuous" or all(a is None for a in self.active):
                self._admit_pass()
            self._collect_prefills()
            if all(a is None for a in self.active):
                if self._prefilling:
                    # nothing decodable yet: sleep on the completion bus
                    self._collect_prefills(block=True)
                    continue
                # failed async prefills may have freed slots *after* the
                # admit pass above — retry before declaring the feed done
                if self._feed is not None and self._admit_pass():
                    continue
                self._retire_feed()
                if self.queue:  # submissions landed after the snapshot
                    continue
                return dict(self.results)

            tokens = jnp.asarray(self.last_token, jnp.int32)[:, None]
            positions = jnp.asarray(
                self.lengths + np.array([len(g) for g in self.generated], np.int32) - 1,
                jnp.int32,
            )[:, None]
            logits, self.caches = self._decode(self.params, tokens, positions, self.caches)
            nxt = self._sample_step(logits)
            self.steps += 1
            for b in range(self.slots):
                if self.active[b] is None:
                    continue
                tok = int(nxt[b])
                self.generated[b].append(tok)
                self.last_token[b] = tok
                if self._slot_done(b):
                    self._finish(b)

    def _sample_step(self, logits) -> np.ndarray:
        """Sample one token per slot under per-(rid, index) keys.

        Greedy decode needs no keys.  Stochastic decode folds a key per
        slot from the request id and its stream position, so a request's
        tokens are identical for a fixed seed regardless of which other
        slots are occupied (and of admission order) — batch composition
        cannot perturb RNG.
        """
        if self.temperature <= 0.0:
            return np.asarray(sample(logits, temperature=0.0))
        keys = jnp.stack([
            self._request_key(self.active[b].rid, len(self.generated[b]))
            if self.active[b] is not None else self._seed_key
            for b in range(self.slots)
        ])
        return np.asarray(sample(logits, keys, temperature=self.temperature))

    def _attach_dispatch_stats(self, report) -> None:
        """Expose prefill dispatch latency per slot on the batch report."""
        if report is None or self._prefill_units is None:
            return
        stats = {}
        for b, unit in self._prefill_units.items():
            lats = unit.dispatch_latencies
            if lats:
                stats[f"slot{b}"] = sum(lats) / len(lats)
        report.dispatch_latency = stats or None

    # ------------------------------------------------------------------
    def throughput_report(self) -> Dict[str, float]:
        """Serving metrics with a stable schema.

        Every key below is always present (zeros when nothing finished),
        so consumers can index without guarding:

        ``tokens, steps, tokens_per_step, completed, failed, shed,
        mean_latency, p50_latency, p95_latency, p99_latency, mean_ttft,
        goodput_tokens``
        """
        done = [r for r in self.results.values() if r.error is None]
        failed = len(self.results) - len(done)
        total_tokens = sum(len(r.tokens) for r in done)
        lats = [r.latency for r in done]
        ttfts = [r.ttft for r in done if r.ttft is not None]

        def pct(p: float) -> float:
            return float(np.percentile(lats, p)) if lats else 0.0

        return {
            "tokens": total_tokens,
            "steps": self.steps,
            "tokens_per_step": total_tokens / max(self.steps, 1),
            "completed": len(done),
            "failed": failed,
            "shed": len(self.shed),
            "mean_latency": float(np.mean(lats)) if lats else 0.0,
            "p50_latency": pct(50.0),
            "p95_latency": pct(95.0),
            "p99_latency": pct(99.0),
            "mean_ttft": float(np.mean(ttfts)) if ttfts else 0.0,
            "goodput_tokens": sum(
                len(r.tokens) for r in done if r.met_deadline
            ),
        }

"""Admission policies and backpressure for the serving tier.

The paper's scheduler argument — *which* unit gets the next chunk should
follow measured completion behaviour, not a fixed plan — translates at
the serving tier into *which request* gets the next free decode slot.
This module makes that decision pluggable:

* :class:`FIFOPolicy` — arrival order (the pre-PR-6 behaviour, and the
  baseline every other policy is benchmarked against).
* :class:`PriorityPolicy` — strict priority classes, FIFO within a
  class (``Request.priority``, higher first).
* :class:`DeadlinePolicy` — earliest-deadline-first over per-request
  SLOs (``Request.deadline``, relative seconds from submit); requests
  whose budget is already spent at admission time are shed instead of
  wasting prefill work.
* :class:`CostAwarePolicy` — shortest-predicted-prefill-first: the
  predicted cost of a request is ``prompt_len / throughput`` where
  throughput is an online :class:`~repro.core.hetero.ThroughputTracker`
  EWMA learned from observed prefill completions (the MultiDynamic
  feedback rule applied to request routing).  A
  :class:`~repro.core.straggler.StragglerDetector` watches per-slot
  prefill time per token, so persistently slow prefill units are
  visible to callers (``straggler_report``).

Every policy also owns the **backpressure** verdict: ``admit`` is
consulted by :meth:`ServingEngine.submit` *before* a request enters the
queue and returns an :class:`AdmissionVerdict` — a bounded queue
(``max_queue``) sheds instead of growing without limit, which is what
keeps an open-loop arrival process from driving latency to infinity.

Ordering is applied when the engine snapshots its queue into a
scheduler feed: ``order(requests, now)`` returns the snapshot sequence,
and the runtime's completion-driven ``WorkQueue`` then serves it
front-to-back as slots free up.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

from ..core.hetero import ThroughputTracker
from ..core.straggler import StragglerDetector, StragglerReport

__all__ = [
    "AdmissionVerdict",
    "AdmissionPolicy",
    "FIFOPolicy",
    "PriorityPolicy",
    "DeadlinePolicy",
    "CostAwarePolicy",
    "POLICIES",
    "make_policy",
]


@dataclasses.dataclass(frozen=True)
class AdmissionVerdict:
    """The result of offering a request to the serving tier.

    Truthy iff admitted, so callers can write ``if not engine.submit(r)``.
    ``reason`` names the shed cause (``"queue_full"``, ``"expired"``) or
    ``"admitted"``; ``queue_depth`` is the depth observed at decision
    time (post-admission depth for admitted requests).
    """

    admitted: bool
    reason: str = "admitted"
    queue_depth: int = 0

    def __bool__(self) -> bool:
        return self.admitted


class AdmissionPolicy:
    """Base policy: unbounded FIFO.  Subclasses override the hooks.

    ``max_queue`` bounds the engine queue: an arrival that would push
    the depth past it is shed with ``reason="queue_full"`` — the
    backpressure contract every subclass inherits.
    """

    name = "fifo"

    def __init__(self, *, max_queue: Optional[int] = None) -> None:
        if max_queue is not None and max_queue <= 0:
            raise ValueError(f"max_queue must be positive, got {max_queue}")
        self.max_queue = max_queue

    # -- backpressure --------------------------------------------------------
    def admit(self, req, *, queue_depth: int, now: float) -> AdmissionVerdict:
        if self.max_queue is not None and queue_depth >= self.max_queue:
            return AdmissionVerdict(False, "queue_full", queue_depth)
        return AdmissionVerdict(True, "admitted", queue_depth + 1)

    # -- snapshot ordering -----------------------------------------------------
    def order(self, requests: Sequence, *, now: float = 0.0) -> List:
        """Return the feed order for a queue snapshot (front served first)."""
        return list(requests)

    # -- cost feedback (no-op unless a policy learns online) -----------------
    def observe_prefill(self, unit: str, tokens: int, elapsed: float) -> None:
        """Engine callback: one finished prefill of ``tokens`` prompt
        tokens took ``elapsed`` seconds on ``unit``."""

    def describe(self) -> str:
        bound = f", max_queue={self.max_queue}" if self.max_queue else ""
        return f"{type(self).__name__}({self.name!r}{bound})"


class FIFOPolicy(AdmissionPolicy):
    """Arrival order — the baseline."""

    name = "fifo"


class PriorityPolicy(AdmissionPolicy):
    """Strict priority classes; FIFO within a class.

    ``Request.priority`` is an int, higher served first.  The sort is
    stable, so equal-priority requests keep their arrival order.
    """

    name = "priority"

    def order(self, requests: Sequence, *, now: float = 0.0) -> List:
        return sorted(requests, key=lambda r: -int(getattr(r, "priority", 0)))


class DeadlinePolicy(AdmissionPolicy):
    """Earliest-deadline-first over per-request SLOs.

    ``Request.deadline`` is a *relative* budget in seconds from submit;
    the engine stamps ``Request.submitted_at``, so the absolute deadline
    is ``submitted_at + deadline``.  Requests without a deadline sort
    after every deadlined one (best-effort class).  An arrival whose
    budget is already spent (``now >= submitted-at-deadline``, which at
    admit time means ``deadline <= 0``) is shed as ``"expired"`` rather
    than admitted to miss.
    """

    name = "deadline"

    @staticmethod
    def _absolute(req, now: float) -> float:
        rel = getattr(req, "deadline", None)
        if rel is None:
            return float("inf")
        base = getattr(req, "submitted_at", None)
        return (base if base is not None else now) + rel

    def admit(self, req, *, queue_depth: int, now: float) -> AdmissionVerdict:
        verdict = super().admit(req, queue_depth=queue_depth, now=now)
        if not verdict:
            return verdict
        rel = getattr(req, "deadline", None)
        if rel is not None and rel <= 0:
            return AdmissionVerdict(False, "expired", queue_depth)
        return verdict

    def order(self, requests: Sequence, *, now: float = 0.0) -> List:
        return sorted(requests, key=lambda r: self._absolute(r, now))


class CostAwarePolicy(AdmissionPolicy):
    """Shortest-predicted-prefill-first from measured throughput.

    Prediction: ``len(prompt) / tp`` where ``tp`` is the EWMA prefill
    throughput (prompt tokens per second) learned from
    :meth:`observe_prefill` — before any observation the tracker default
    makes this plain shortest-prompt-first.  Per-slot observations also
    feed a :class:`~repro.core.straggler.StragglerDetector` on prefill
    seconds-per-token, so a persistently slow prefill unit (a thermally
    throttled core, a congested remote worker) is reported rather than
    silently averaged away.

    Pass ``cost_model=`` (a :class:`~repro.core.costmodel.CostModel`) to
    share capability descriptors with the batch runtime: prefill
    observations land in the model under ``kernel`` (default
    ``"prefill"``) and predictions use its persisted fleet throughput
    when available — a restarted server starts cost-aware instead of
    shortest-prompt-first.
    """

    name = "cost"

    def __init__(
        self,
        *,
        max_queue: Optional[int] = None,
        tracker: Optional[ThroughputTracker] = None,
        detector: Optional[StragglerDetector] = None,
        cost_model=None,
        kernel: str = "prefill",
    ) -> None:
        super().__init__(max_queue=max_queue)
        self.tracker = tracker or ThroughputTracker()
        self.detector = detector or StragglerDetector()
        self.straggler_report: Optional[StragglerReport] = None
        # Optional shared repro.core.costmodel.CostModel: the same store a
        # HeteroRuntime learns batch splits from.  Observations flow both
        # ways — prefills teach it under ``kernel``, predictions prefer
        # its fleet throughput over the policy-local tracker, and the
        # model's persistence means a restarted server predicts from day
        # one instead of re-warming.
        self.cost_model = cost_model
        self.kernel = kernel

    def observe_prefill(self, unit: str, tokens: int, elapsed: float) -> None:
        tokens = max(int(tokens), 1)
        self.tracker.update("prefill", tokens, elapsed)
        self.tracker.update(unit, tokens, elapsed)
        if self.cost_model is not None:
            self.cost_model.observe(unit, self.kernel,
                                    items=tokens, elapsed=elapsed)
        self.straggler_report = self.detector.observe(
            {unit: elapsed / tokens}
        )

    def predicted_cost(self, req) -> float:
        tp = None
        if self.cost_model is not None:
            tp = self.cost_model.fleet_throughput(self.kernel)
        if tp is None:
            tp = self.tracker.get("prefill", 1.0)
        return len(req.prompt) / tp

    def order(self, requests: Sequence, *, now: float = 0.0) -> List:
        return sorted(requests, key=self.predicted_cost)


POLICIES: Dict[str, type] = {
    "fifo": FIFOPolicy,
    "priority": PriorityPolicy,
    "deadline": DeadlinePolicy,
    "cost": CostAwarePolicy,
}


def make_policy(
    spec: Union[str, AdmissionPolicy, None],
    *,
    max_queue: Optional[int] = None,
) -> AdmissionPolicy:
    """Normalize a policy spec (name / instance / None) to a policy.

    ``None`` means FIFO.  Passing ``max_queue`` alongside an *instance*
    whose bound is unset installs the bound on it; conflicting explicit
    bounds are an error (two sources of truth).
    """
    if isinstance(spec, AdmissionPolicy):
        if max_queue is not None:
            if spec.max_queue is not None and spec.max_queue != max_queue:
                raise ValueError(
                    f"policy already bounds its queue at {spec.max_queue}, "
                    f"conflicting max_queue={max_queue}"
                )
            spec.max_queue = max_queue
        return spec
    if spec is None:
        return FIFOPolicy(max_queue=max_queue)
    cls = POLICIES.get(str(spec))
    if cls is None:
        raise ValueError(
            f"unknown admission policy {spec!r}: valid names are "
            + ", ".join(sorted(POLICIES))
            + ", or an AdmissionPolicy instance"
        )
    return cls(max_queue=max_queue)

"""Token sampling (greedy / temperature / top-k)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["sample"]


def sample(
    logits: jax.Array,          # (B, V)
    key: Optional[jax.Array] = None,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        thresh = jax.lax.top_k(lf, top_k)[0][..., -1:]
        lf = jnp.where(lf < thresh, -jnp.inf, lf)
    assert key is not None, "stochastic sampling needs a key"
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)

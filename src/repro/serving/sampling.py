"""Token sampling (greedy / temperature / top-k)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["sample"]


def sample(
    logits: jax.Array,          # (B, V)
    key: Optional[jax.Array] = None,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
) -> jax.Array:
    """Greedy (``temperature<=0``) or stochastic sampling.

    ``key`` is either one PRNG key shared by the whole batch, or a
    *stacked* ``(B, ...)`` array of per-row keys — one independent key
    per batch row, so a row's draw cannot depend on its batch-mates
    (the serving engine's per-request key streams rely on this).
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        thresh = jax.lax.top_k(lf, top_k)[0][..., -1:]
        lf = jnp.where(lf < thresh, -jnp.inf, lf)
    assert key is not None, "stochastic sampling needs a key"
    single_ndim = 0 if jnp.issubdtype(key.dtype, jax.dtypes.prng_key) else 1
    if key.ndim == single_ndim + 1:   # stacked per-row keys
        if key.shape[0] != lf.shape[0]:
            raise ValueError(
                f"{key.shape[0]} per-row keys for batch {lf.shape[0]}"
            )
        return jax.vmap(
            lambda k, row: jax.random.categorical(k, row, axis=-1)
        )(key, lf).astype(jnp.int32)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)

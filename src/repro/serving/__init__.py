"""Serving: continuous batching engine (ENEAC completion-driven refill)."""

from .engine import Request, RequestResult, ServingEngine
from .sampling import sample

__all__ = ["Request", "RequestResult", "ServingEngine", "sample"]

"""Serving: continuous batching engine (ENEAC completion-driven refill),
admission policies with backpressure, and the open-loop load harness."""

from .admission import (
    AdmissionPolicy,
    AdmissionVerdict,
    CostAwarePolicy,
    DeadlinePolicy,
    FIFOPolicy,
    PriorityPolicy,
    make_policy,
)
from .engine import Request, RequestResult, ServingEngine
from .loadgen import LoadgenScenario, TimedRequest, make_trace, run_trace
from .sampling import sample

__all__ = [
    "AdmissionPolicy",
    "AdmissionVerdict",
    "CostAwarePolicy",
    "DeadlinePolicy",
    "FIFOPolicy",
    "PriorityPolicy",
    "LoadgenScenario",
    "Request",
    "RequestResult",
    "ServingEngine",
    "TimedRequest",
    "make_trace",
    "run_trace",
    "make_policy",
    "sample",
]

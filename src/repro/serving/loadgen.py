"""Open-loop load generation for the serving tier.

The paper's claim is about *irregular* workloads; a serving benchmark
only exposes it if the traffic is irregular too.  This module builds
seeded request traces with controllable irregularity and drives a
:class:`~repro.serving.engine.ServingEngine` with them **open-loop**:
arrivals happen at trace-determined times whether or not the engine has
kept up (the only honest way to measure tail latency — a closed loop
slows its own arrivals exactly when the engine struggles, hiding the
tail).  E2C's workload-scenario simulator (arXiv:2212.11333) is the
model: mixed arrival processes × mixed length distributions are what
separate schedulers that look identical under uniform load.

* **Arrivals** — ``"poisson"`` (exponential inter-arrival gaps at
  ``rate`` req/s), ``"bursty"`` (on/off modulated Poisson: short dense
  bursts separated by quiet gaps, same mean rate), or ``"uniform"``
  (constant gap control).
* **Lengths** — prompt and generation lengths drawn from a clipped Zipf
  (``zipf_a``): mostly short, occasionally very long — the mixed-length
  scenario where continuous batching beats static refill.
* **Deadlines** — optional per-request SLO ``deadline_base +
  deadline_per_token * max_new_tokens`` seconds, so *goodput* (tokens of
  requests that met their deadline) is measurable, not assumed.

``run_trace`` returns a stable metrics dict (p50/p95/p99 latency, TTFT,
goodput, shed/failed counts) — the same schema
``benchmarks/bench_serving.py`` commits to ``BENCH_serving.json`` so
every PR leaves a visible perf trajectory.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .engine import Request, ServingEngine

__all__ = [
    "LoadgenScenario",
    "TimedRequest",
    "make_trace",
    "run_trace",
    "summarize",
    "METRIC_KEYS",
]

ARRIVALS = ("poisson", "bursty", "uniform")

# The stable schema of run_trace()/summarize() — tools/check_bench.py
# validates committed artifacts against exactly this set.
METRIC_KEYS = (
    "requests", "completed", "failed", "shed",
    "wall_time_s", "tokens",
    "mean_latency_s", "p50_latency_s", "p95_latency_s", "p99_latency_s",
    "mean_ttft_s", "p95_ttft_s",
    "tokens_per_s", "goodput_tokens", "goodput_tokens_per_s",
    "deadline_hit_rate",
)


@dataclasses.dataclass(frozen=True)
class LoadgenScenario:
    """A fully-seeded description of one traffic pattern."""

    name: str = "mixed"
    seed: int = 0
    n: int = 32
    rate: float = 50.0                 # mean arrivals per second
    arrival: str = "poisson"           # poisson | bursty | uniform
    prompt_lens: Tuple[int, int] = (2, 48)   # clipped-Zipf bounds
    gen_lens: Tuple[int, int] = (2, 48)
    zipf_a: float = 1.4
    vocab_size: int = 256
    deadline_base: Optional[float] = None     # seconds; None = no SLO
    deadline_per_token: float = 0.0
    priorities: Tuple[int, ...] = (0,)        # cycled over arrivals
    burst_factor: float = 8.0          # bursty: in-burst rate multiplier

    def describe(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TimedRequest:
    """One trace entry: the request and its arrival offset (seconds)."""

    at: float
    request: Request


def _zipf_clipped(rng: np.random.Generator, n: int, a: float,
                  lo: int, hi: int) -> np.ndarray:
    """Zipf ranks mapped into [lo, hi]: mass at lo, heavy tail to hi."""
    raw = rng.zipf(a, size=n)
    return np.clip(lo + raw - 1, lo, hi).astype(np.int64)


def _arrival_times(rng: np.random.Generator, sc: LoadgenScenario) -> np.ndarray:
    if sc.arrival not in ARRIVALS:
        raise ValueError(
            f"unknown arrival process {sc.arrival!r} (want one of {ARRIVALS})"
        )
    if sc.arrival == "uniform":
        gaps = np.full(sc.n, 1.0 / sc.rate)
    elif sc.arrival == "poisson":
        gaps = rng.exponential(1.0 / sc.rate, size=sc.n)
    else:  # bursty: on/off modulated Poisson, same mean rate
        gaps = np.empty(sc.n)
        i = 0
        while i < sc.n:
            burst = int(rng.integers(2, 9))          # arrivals per burst
            # the first arrival of a burst waits out the quiet period
            gaps[i] = rng.exponential(sc.burst_factor / (2.0 * sc.rate))
            i += 1
            for _ in range(min(burst - 1, sc.n - i)):
                gaps[i] = rng.exponential(1.0 / (sc.rate * sc.burst_factor))
                i += 1
    return np.cumsum(gaps)


def make_trace(
    scenario: Optional[LoadgenScenario] = None, **overrides
) -> List[TimedRequest]:
    """Build a seeded open-loop trace.

    Pass a :class:`LoadgenScenario` or keyword overrides of its fields
    (``make_trace(seed=1, n=64, arrival="bursty")``).  The same scenario
    always yields the same trace — arrival times, prompts, lengths,
    priorities, and deadlines are all drawn from one seeded generator.
    """
    if scenario is None:
        scenario = LoadgenScenario(**overrides)
    elif overrides:
        scenario = dataclasses.replace(scenario, **overrides)
    sc = scenario
    rng = np.random.default_rng(sc.seed)
    at = _arrival_times(rng, sc)
    plens = _zipf_clipped(rng, sc.n, sc.zipf_a, *sc.prompt_lens)
    glens = _zipf_clipped(rng, sc.n, sc.zipf_a, *sc.gen_lens)
    trace: List[TimedRequest] = []
    for i in range(sc.n):
        prompt = rng.integers(0, sc.vocab_size, int(plens[i])).astype(np.int32)
        deadline = None
        if sc.deadline_base is not None:
            deadline = sc.deadline_base + sc.deadline_per_token * int(glens[i])
        trace.append(TimedRequest(
            at=float(at[i]),
            request=Request(
                rid=i, prompt=prompt, max_new_tokens=int(glens[i]),
                priority=int(sc.priorities[i % len(sc.priorities)]),
                deadline=deadline,
            ),
        ))
    return trace


def _pct(xs: Sequence[float], p: float) -> float:
    # nan, not 0.0: a run that completed nothing has *no* latency
    # distribution, and a 0.0s p99 reads as an impossibly good pass.
    # Consumers (tools/check_bench.py) treat nan as "no data".
    return float(np.percentile(list(xs), p)) if len(xs) else float("nan")


def summarize(engine: ServingEngine, *, wall: float,
              offered: int) -> Dict[str, float]:
    """Fold an engine's results into the stable ``METRIC_KEYS`` schema."""
    results = list(engine.results.values())
    done = [r for r in results if r.error is None]
    lats = [r.latency for r in done]
    ttfts = [r.ttft for r in done if r.ttft is not None]
    tokens = sum(len(r.tokens) for r in done)
    good = sum(len(r.tokens) for r in done if r.met_deadline)
    with_slo = [r for r in done if r.deadline is not None]
    hits = sum(1 for r in with_slo if r.met_deadline)
    wall = max(wall, 1e-9)
    return {
        "requests": offered,
        "completed": len(done),
        "failed": len(results) - len(done),
        "shed": len(engine.shed),
        "wall_time_s": wall,
        "tokens": tokens,
        "mean_latency_s": float(np.mean(lats)) if lats else float("nan"),
        "p50_latency_s": _pct(lats, 50.0),
        "p95_latency_s": _pct(lats, 95.0),
        "p99_latency_s": _pct(lats, 99.0),
        "mean_ttft_s": float(np.mean(ttfts)) if ttfts else float("nan"),
        "p95_ttft_s": _pct(ttfts, 95.0),
        "tokens_per_s": tokens / wall,
        "goodput_tokens": good,
        "goodput_tokens_per_s": good / wall,
        "deadline_hit_rate": (hits / len(with_slo)) if with_slo else 1.0,
    }


def run_trace(
    engine: ServingEngine,
    trace: Sequence[TimedRequest],
    *,
    time_scale: float = 1.0,
    poll_interval: float = 0.005,
) -> Dict[str, float]:
    """Drive the engine with the trace, open-loop; return metrics.

    A feeder thread submits each request at ``t0 + at * time_scale``
    regardless of engine progress, while the caller thread serves
    (``engine.run()`` whenever there is work).  ``time_scale`` stretches
    or compresses the trace clock — 0 submits everything immediately
    (the closed-batch limit).  Shed verdicts are counted, not retried:
    open-loop traffic does not wait for permission.
    """
    t0 = time.perf_counter()
    feeder_errors: List[BaseException] = []

    def feeder() -> None:
        try:
            for tr in trace:
                delay = (t0 + tr.at * time_scale) - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                engine.submit(tr.request)
        except BaseException as exc:  # surfaced to the driver below
            feeder_errors.append(exc)

    th = threading.Thread(target=feeder, name="loadgen-feeder", daemon=True)
    th.start()
    try:
        while th.is_alive() or engine.has_work:
            if engine.has_work:
                engine.run()
            else:
                time.sleep(poll_interval)
    finally:
        th.join(timeout=30.0)
    if feeder_errors:
        raise feeder_errors[0]
    wall = time.perf_counter() - t0
    return summarize(engine, wall=wall, offered=len(trace))

"""Completion-driven background prefetcher (ENEAC interrupt discipline).

The host thread that feeds the device never *builds* batches: a producer
thread prepares them ahead of time and parks on a bounded queue; the
training loop's ``get()`` sleeps on the queue's condition variable (no
polling) and almost always returns immediately — the data-pipeline
analogue of the paper's "host thread does not waste CPU cycles waiting".
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

__all__ = ["Prefetcher"]


class Prefetcher:
    def __init__(
        self,
        make_batch: Callable[[int], object],   # step -> batch
        *,
        depth: int = 2,
        start_step: int = 0,
    ) -> None:
        self.make_batch = make_batch
        self._q: "queue.Queue[tuple[int, object, Optional[BaseException]]]" = (
            queue.Queue(maxsize=depth)
        )
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._produce, daemon=True,
                                        name="eneac-prefetch")
        self._thread.start()

    def _produce(self) -> None:
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self.make_batch(step)
            except BaseException as exc:  # delivered in order with the stream
                self._q.put((step, None, exc))
                return
            self._q.put((step, batch, None))  # blocks at depth (backpressure)
            step += 1

    def get(self, timeout: Optional[float] = 30.0):
        """Sleeps (no busy-wait) until the next batch is ready."""
        step, batch, err = self._q.get(timeout=timeout)
        if err is not None:
            raise err
        return step, batch

    def close(self) -> None:
        self._stop.set()
        # unblock the producer if it is parked on a full queue
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)

"""Data pipeline: deterministic sharded sources + async prefetch."""

from .prefetch import Prefetcher
from .tokens import Batch, MemmapTokens, SyntheticTokens

__all__ = ["Batch", "SyntheticTokens", "MemmapTokens", "Prefetcher"]

"""Token data sources: deterministic synthetic + memmap'd binary corpora.

Both sources are *sharded* and *stateless-resumable*: a (step, shard)
pair fully determines the batch, so checkpoint-restart and elastic
rescaling (different shard count after a failure) never replay or skip
data nondeterministically.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = ["SyntheticTokens", "MemmapTokens", "Batch"]


@dataclasses.dataclass
class Batch:
    tokens: np.ndarray     # (B, S) int32
    labels: np.ndarray     # (B, S) int32 (next-token)
    mask: np.ndarray       # (B, S) float32


class SyntheticTokens:
    """Deterministic pseudo-corpus: token t of document d is a hash mix —
    structured enough that loss decreases (bigram-ish patterns), cheap to
    generate at any (step, shard) without state."""

    def __init__(self, vocab_size: int, seq_len: int, *, seed: int = 0) -> None:
        self.vocab = vocab_size
        self.seq = seq_len
        self.seed = seed

    def batch(self, step: int, shard: int, num_shards: int, per_shard: int) -> Batch:
        idx = step * num_shards + shard
        rng = np.random.default_rng((self.seed << 32) ^ idx)
        base = rng.integers(0, self.vocab, (per_shard, 1), dtype=np.int64)
        drift = rng.integers(1, 7, (per_shard, self.seq + 1), dtype=np.int64).cumsum(1)
        toks = ((base + drift * 2654435761) % self.vocab).astype(np.int32)
        return Batch(
            tokens=toks[:, :-1],
            labels=toks[:, 1:],
            mask=np.ones((per_shard, self.seq), np.float32),
        )


class MemmapTokens:
    """Flat binary corpus (np.int32 tokens) sampled in fixed windows.

    Sampling is strided-deterministic: window w of (step, shard) starts at
    ``hash(step, shard, w) % (n_tokens − seq − 1)`` — stateless, resumable,
    shard-disjoint in expectation.
    """

    def __init__(self, path: str | Path, seq_len: int, *, dtype=np.int32) -> None:
        self.path = Path(path)
        self.seq = seq_len
        self.data = np.memmap(self.path, dtype=dtype, mode="r")
        if len(self.data) < seq_len + 2:
            raise ValueError(f"corpus too small: {len(self.data)} tokens")

    @staticmethod
    def write_corpus(path: str | Path, tokens: np.ndarray) -> None:
        np.asarray(tokens, np.int32).tofile(path)

    def batch(self, step: int, shard: int, num_shards: int, per_shard: int) -> Batch:
        n = len(self.data)
        span = n - self.seq - 1
        toks = np.empty((per_shard, self.seq + 1), np.int32)
        for w in range(per_shard):
            h = np.uint64((step * 2654435761 + shard * 40503 + w * 69069 + 12345) % (2**63))
            h ^= h >> np.uint64(13)
            h *= np.uint64(0x9E3779B97F4A7C15)
            h ^= h >> np.uint64(7)
            start = int(h % np.uint64(span))
            toks[w] = self.data[start : start + self.seq + 1]
        return Batch(
            tokens=toks[:, :-1],
            labels=toks[:, 1:],
            mask=np.ones((per_shard, self.seq), np.float32),
        )

"""Hybrid dense/sparse ``parallel_for`` over an irregular iteration space.

Within a single TPU chip, the ENEAC "ACC vs CC" pairing maps onto the two
compute units that actually exist: the MXU (systolic 128×128 matmuls —
high throughput, rigid tile shapes) and the VPU/gather path (flexible,
much lower throughput).  For an irregular workload like SPMM, rows with
enough density to fill dense tiles belong on the MXU; the long sparse tail
is cheaper via gathers.  The split point is the scheduling decision, and
MultiDynamic's measure-and-adapt loop chooses it.

:class:`HybridExecutor` owns that decision as a thin client of
:class:`~repro.core.runtime.HeteroRuntime`: the MXU path registers as an
ACC unit, the gather path as a CC unit, the runtime's oracle policy turns
measured throughputs into the balanced split, and each round executes
through ``runtime.parallel_for`` so the throughput feedback loop shares
the engine bookkeeping (busy times, coverage, utilization) with every
other workload.

* ``"parallel"`` — units overlap (multi-device via shard_map, or
  MXU/VPU co-issue inside one fused kernel): cost = max(t_dense, t_sparse)
  ⇒ balance the split (the paper's load-balance objective).
* ``"serial"`` — units serialize (single stream): cost = sum ⇒ each item
  goes to whichever path is cheaper *for it* (threshold on density).

Both reduce to the paper's scheme: a tunable accelerator chunk and a
dynamically-adapted remainder.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

from .hetero import ThroughputTracker
from .runtime import HeteroRuntime
from .scheduler import WorkerKind

__all__ = ["SplitDecision", "HybridExecutor"]


@dataclasses.dataclass(frozen=True)
class SplitDecision:
    n_dense: int            # items sent to the MXU/accelerator path
    n_sparse: int           # items on the VPU/core path
    predicted_time: float

    @property
    def dense_fraction(self) -> float:
        tot = self.n_dense + self.n_sparse
        return self.n_dense / tot if tot else 0.0


class HybridExecutor:
    """MultiDynamic split-point controller + runner for two-path workloads.

    ``dense_fn(items) -> result`` and ``sparse_fn(items) -> result`` each
    process a *prefix count* of the (pre-sorted, densest-first) iteration
    space; ``merge_fn`` combines the two partial results.
    """

    def __init__(
        self,
        dense_fn: Callable[[int], object],
        sparse_fn: Callable[[int], object],
        merge_fn: Callable[[object, object], object],
        num_items: int,
        *,
        mode: str = "parallel",
        dense_quantum: int = 8,
        init_dense_throughput: float = 8.0,
        init_sparse_throughput: float = 1.0,
    ) -> None:
        if mode not in ("parallel", "serial"):
            raise ValueError(f"mode must be parallel|serial, got {mode!r}")
        self.dense_fn = dense_fn
        self.sparse_fn = sparse_fn
        self.merge_fn = merge_fn
        self.num_items = num_items
        self.mode = mode
        self.dense_quantum = dense_quantum
        self.tracker = ThroughputTracker(alpha=0.4)
        self.tracker.update("dense", init_dense_throughput, 1.0)
        self.tracker.update("sparse", init_sparse_throughput, 1.0)
        self._results: dict = {}
        self.runtime = HeteroRuntime()
        # dense first: the runtime's prefix split then maps "dense" to the
        # leading (densest) rows, which is what the path callables expect.
        self.runtime.register_unit(
            "dense", WorkerKind.ACC,
            work_fn=lambda c: self._results.__setitem__("dense", self.dense_fn(c.size)),
        )
        self.runtime.register_unit(
            "sparse", WorkerKind.CC,
            work_fn=lambda c: self._results.__setitem__("sparse", self.sparse_fn(c.size)),
        )

    def _sync_speeds(self) -> Tuple[float, float]:
        td = self.tracker.get("dense")
        ts = self.tracker.get("sparse")
        self.runtime.set_speed("dense", td)
        self.runtime.set_speed("sparse", ts)
        return td, ts

    # -- the scheduling decision -------------------------------------------
    def decide(self) -> SplitDecision:
        td, ts = self._sync_speeds()
        n = self.num_items
        if self.mode == "parallel":
            # balance: n_d/td == n_s/ts — the runtime's throughput-
            # proportional (oracle) split over the two units.
            plan = self.runtime.plan(n, policy="oracle")
            lo, hi = plan.get("dense", (0, 0))
            nd = hi - lo
        else:
            # serial: everything goes to the faster path; the split only
            # helps when per-item costs differ — callers sort densest-first
            # so a prefix split is optimal for either ordering.
            nd = n if td >= ts else 0
        nd = int(round(nd / self.dense_quantum)) * self.dense_quantum
        nd = max(0, min(n, nd))
        ns = n - nd
        if self.mode == "parallel":
            pred = max(nd / max(td, 1e-12), ns / max(ts, 1e-12))
        else:
            pred = nd / max(td, 1e-12) + ns / max(ts, 1e-12)
        return SplitDecision(n_dense=nd, n_sparse=ns, predicted_time=pred)

    # -- execution + feedback -------------------------------------------------
    def run(self, decision: Optional[SplitDecision] = None) -> Tuple[object, SplitDecision]:
        d = decision or self.decide()
        self._results.clear()
        rep = self.runtime.parallel_for(
            num_items=self.num_items,
            policy={"dense": (0, d.n_dense),
                    "sparse": (d.n_dense, self.num_items)},
            engine="inline",
        )
        for path in ("dense", "sparse"):
            items = rep.per_worker_items.get(path, 0)
            if items:
                self.tracker.update(path, items, rep.per_worker_busy[path])
        merged = self.merge_fn(self._results.get("dense"), self._results.get("sparse"))
        return merged, d

    def converge(self, rounds: int = 5) -> SplitDecision:
        """Run the measure→rebalance loop until the split stabilizes."""
        last = None
        for _ in range(rounds):
            _, last = self.run()
        return last if last is not None else self.decide()

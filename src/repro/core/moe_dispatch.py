"""ENEAC-style Mixture-of-Experts dispatch: capacity chunks + dense fallback.

This is the first-class integration of the paper's MultiDynamic idea into a
modern LM workload.  Token→expert routing is an *irregular iteration space*
(expert loads are data-dependent and unpredictable — exactly the paper's
SPMM setting).  The mapping:

* **Experts = accelerators (ACC).**  Each expert processes a *fixed-size
  chunk* of at most ``capacity`` tokens per step — the ACC chunk size knob.
  Fixed chunks keep shapes static (one compiled executable) and keep the
  expert matmuls MXU-shaped, which is why every production MoE has a
  capacity; the paper's Table-1 cliff (">1/4 of the workload per ACC chunk
  collapses throughput") is the same phenomenon as an oversized capacity
  factor wasting FLOPs on padding.
* **Dense fallback path = the CPU cores (CC).**  Tokens that overflow an
  expert's capacity are NOT dropped (the usual Switch-Transformer behaviour)
  — they are routed to a shared dense FFN that acts as the lower-throughput
  generalist unit picking up the remainder.  All token gradients flow.
* **MultiDynamic = the capacity controller.**  The host-side controller
  (:class:`CapacityController`) observes realized expert load factors and
  adapts the capacity factor between steps, the same measure-and-rebalance
  loop the paper runs between chunks.

Implementation notes: dispatch is *sort-based* (argsort by expert id +
rank-within-expert), never the dense ``(T, E, C)`` one-hot einsum — at
assigned-architecture scale (qwen3-moe: 128 experts, 32k tokens/device)
the one-hot mask would be terabytes.  Sort-based dispatch is O(T·k·log) and
gathers are MXU-adjacent memory ops.  All functions are pure and
shard_map/pjit friendly; expert-parallel sharding is annotated by the model
layer (see ``models/moe.py``), letting GSPMD insert the all-to-alls.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.mesh_rules import shard_hint

__all__ = [
    "RouterOutput",
    "DispatchPlan",
    "route_topk",
    "make_dispatch_plan",
    "dispatch",
    "combine",
    "CapacityController",
    "expert_load_stats",
]


class RouterOutput(NamedTuple):
    expert_ids: jax.Array      # (T, k) int32 — chosen experts per token
    expert_probs: jax.Array    # (T, k) float — router weights (softmax'd)
    router_z_loss: jax.Array   # scalar — router logit regularizer
    aux_loss: jax.Array        # scalar — load-balance auxiliary loss


class DispatchPlan(NamedTuple):
    """Static-shape routing plan for one MoE layer application.

    Both directions are expressed as GATHERS (scatters shard terribly in
    SPMD: a flat (E·C, d) scatter target has no expert dimension for the
    partitioner to split, so it replicates — measured 10.7 GiB/device
    buffers at qwen3-moe scale).  The gather form keeps the (E, C, d)
    expert batch sharded over the expert axis and the combine is a pure
    reshape-reduce (assignments of token t live at rows t·k..t·k+k−1).
    """

    slot_token: jax.Array      # (E, C) int32 — token id feeding each slot
    slot_valid: jax.Array      # (E, C) bool  — slot actually filled
    slot_index: jax.Array      # (T*k,) int32 in [0, E*C) or -1 (overflow)
    expert_ids: jax.Array      # (T, k)
    gate: jax.Array            # (T, k) float — combine weights
    overflow: jax.Array        # (T, k) bool — True ⇒ served by fallback path
    num_experts: int
    capacity: int


def route_topk(
    logits: jax.Array,
    k: int,
    *,
    router_noise: Optional[jax.Array] = None,
    norm_topk: bool = True,
) -> RouterOutput:
    """Top-k routing with the standard auxiliary losses.

    ``logits``: (T, E) raw router outputs.  ``norm_topk`` renormalizes the
    chosen probabilities to sum to 1 per token (Qwen3/Mixtral convention).
    """
    T, E = logits.shape
    if router_noise is not None:
        logits = logits + router_noise
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    expert_probs, expert_ids = jax.lax.top_k(probs, k)
    if norm_topk:
        expert_probs = expert_probs / jnp.maximum(
            jnp.sum(expert_probs, axis=-1, keepdims=True), 1e-9
        )
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    assign_onehot = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    f = jnp.mean(assign_onehot, axis=0)              # fraction routed (top-1)
    p = jnp.mean(probs, axis=0)                      # mean router prob
    aux = E * jnp.sum(f * p)
    z = jnp.mean(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1) ** 2)
    return RouterOutput(expert_ids.astype(jnp.int32), expert_probs, z, aux)


def make_dispatch_plan(
    expert_ids: jax.Array,
    expert_probs: jax.Array,
    num_experts: int,
    capacity: int,
) -> DispatchPlan:
    """Sort-based capacity assignment (the MultiDynamic chunk issue).

    Every (token, k) assignment gets a rank within its expert (arrival order
    = token order, matching the paper's in-order chunk issue); ranks beyond
    ``capacity`` overflow to the fallback path.
    """
    T, k = expert_ids.shape
    E, C = num_experts, capacity
    flat_expert = expert_ids.reshape(-1)                       # (T*k,)

    # rank-within-expert: stable sort by expert id, then position − segment start.
    order = jnp.argsort(flat_expert, stable=True).astype(jnp.int32)
    sorted_expert = flat_expert[order]
    counts = jnp.bincount(flat_expert, length=E)               # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_expert].astype(jnp.int32)
    # undo the sort (structured scatter of a permutation — small int array)
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)

    overflow_flat = pos >= C
    slot = jnp.where(overflow_flat, -1, flat_expert * C + pos)

    # slot → assignment table (E, C): slot (e, c) is filled by the c-th
    # sorted assignment of expert e.
    grid = starts[:, None].astype(jnp.int32) + jnp.arange(C, dtype=jnp.int32)[None, :]
    slot_valid = jnp.arange(C, dtype=counts.dtype)[None, :] < jnp.minimum(counts, C)[:, None]
    assign = jnp.take(order, jnp.clip(grid, 0, T * k - 1))     # (E, C) in [0, T*k)
    slot_token = jnp.where(slot_valid, assign // k, T)         # sentinel T = empty
    return DispatchPlan(
        slot_token=slot_token.astype(jnp.int32),
        slot_valid=slot_valid,
        slot_index=slot.astype(jnp.int32),
        expert_ids=expert_ids,
        gate=expert_probs,
        overflow=overflow_flat.reshape(T, k),
        num_experts=E,
        capacity=C,
    )


def dispatch(x: jax.Array, plan: DispatchPlan) -> jax.Array:
    """Gather tokens into their expert chunks → (E, C, d).

    Pure gather: the (E, C, d) output shards over the expert axis and the
    partitioner turns the token fetch into the EP all-to-all.
    """
    T, d = x.shape
    safe = jnp.clip(plan.slot_token, 0, T - 1)
    xe = jnp.take(x, safe, axis=0)                             # (E, C, d)
    return jnp.where(plan.slot_valid[..., None], xe, jnp.zeros((), x.dtype))


def combine(
    expert_out: jax.Array,       # (E, C, d) — ACC path results
    fallback_out: jax.Array,     # (T, d)   — CC path results (dense FFN)
    plan: DispatchPlan,
) -> jax.Array:
    """Weighted merge back to token order (ENEAC result merge).

    Each assignment contributes ``gate · expert_out`` if it ran on its
    expert, else ``gate · fallback_out`` — the CC path picks up exactly the
    overflowed fraction with its router weight preserved, so no token loses
    gradient signal.  Assignments of token t are rows t·k..t·k+k−1, so the
    reduction is a reshape-sum, not a scatter.
    """
    E, C, d = expert_out.shape
    T = fallback_out.shape[0]
    k = plan.gate.shape[1]
    flat_gate = plan.gate.reshape(-1).astype(expert_out.dtype)   # (T*k,)
    safe_slot = jnp.where(plan.slot_index < 0, 0, plan.slot_index)
    # 2-D indexed gather — NOT a reshape to (E·C, d): collapsing the sharded
    # capacity dim forces GSPMD to all-gather the whole expert batch
    # (measured 68 GiB f32 per layer at grok prefill scale).
    e_idx = safe_slot // C
    c_idx = safe_slot % C
    picked = expert_out[e_idx, c_idx]                            # (T*k, d)
    picked = shard_hint(picked, "act_batch", None)   # assignments stay DP-sharded
    overflow = plan.overflow.reshape(-1)
    fb = jnp.repeat(fallback_out, k, axis=0) if k > 1 else fallback_out
    contrib = jnp.where(overflow[:, None], fb, picked) * flat_gate[:, None]
    contrib = shard_hint(contrib, "act_batch", None)
    return jnp.sum(contrib.reshape(T, k, d), axis=1)


def expert_load_stats(plan: DispatchPlan) -> Tuple[jax.Array, jax.Array]:
    """(per-expert load fraction of capacity, overflow fraction) — the
    runtime feedback that drives :class:`CapacityController`."""
    E, C = plan.num_experts, plan.capacity
    flat = plan.expert_ids.reshape(-1)
    counts = jnp.bincount(flat, length=E)
    load = counts.astype(jnp.float32) / float(C)
    overflow_frac = jnp.mean(plan.overflow.astype(jnp.float32))
    return load, overflow_frac


@dataclasses.dataclass
class CapacityController:
    """Host-side MultiDynamic controller for the capacity factor.

    The paper sweeps the ACC chunk size offline; production cannot.  This
    controller adapts between steps: if the overflow fraction (work sent to
    the slow CC path) exceeds ``target_overflow`` the capacity factor grows;
    if experts run underfull (padding waste — the Table-1 cliff) it shrinks.
    Changes are quantized to ``quantum`` so recompilation only triggers on
    material shifts, mirroring :class:`~repro.core.hetero.HeterogeneousPartitioner`
    hysteresis.
    """

    capacity_factor: float = 1.25
    target_overflow: float = 0.02
    min_factor: float = 1.0
    max_factor: float = 4.0
    gain: float = 0.5
    quantum: float = 0.25

    def capacity(self, tokens: int, k: int, num_experts: int) -> int:
        c = int(self.capacity_factor * tokens * k / num_experts)
        return max(1, c)

    def update(self, overflow_frac: float, mean_load: float) -> bool:
        """Feed realized stats; returns True if the factor changed (⇒ the
        caller should re-lower with the new static capacity)."""
        old = self.capacity_factor
        if overflow_frac > self.target_overflow:
            self.capacity_factor *= 1.0 + self.gain * min(overflow_frac, 0.5)
        elif mean_load < 0.5:  # under-full: padding waste
            self.capacity_factor *= 1.0 - self.gain * 0.25
        self.capacity_factor = min(self.max_factor, max(self.min_factor, self.capacity_factor))
        # quantize for recompile hysteresis
        self.capacity_factor = round(self.capacity_factor / self.quantum) * self.quantum
        return self.capacity_factor != old

"""MultiDynamic heterogeneous chunk scheduler (ENEAC §3.3).

The paper's scheduler exposes a ``parallel_for()`` over an iteration space
``[0, N)`` executed simultaneously by heterogeneous compute units:
*accelerators* (ACC — FPGA blocks in the paper, MXU-dense paths / fast DP
groups here) and *cores* (CC — ARM cores in the paper, VPU-sparse paths /
slow DP groups here).  Its defining properties, reproduced faithfully:

1. The ACC chunk size is **user-specified** (the paper sweeps it; Table 1's
   throughput cliff appears when one ACC chunk exceeds 1/4 of the space).
2. The CC chunk size is **adapted dynamically** to maximize load balance:
   a core should finish its chunk in roughly the time an accelerator
   finishes one of its own, so ``cc_chunk ≈ acc_chunk * (T_cc / T_acc)``
   where ``T_*`` are measured throughputs (items/s), with a guided-style
   decay near the tail so no unit is left holding a large remainder.
3. Chunks are handed to a unit **as soon as it becomes available**
   (completion-driven, see :mod:`repro.core.interrupts`), which is what
   makes the scheme robust to irregular workloads (SPMM in the paper).

The scheduler is pure host-side bookkeeping (plain Python + floats): it
never touches jax device state, so it can be driven from interrupt
callbacks, serving threads, or the training loop alike.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = [
    "Chunk",
    "WorkerKind",
    "WorkerState",
    "MultiDynamicScheduler",
    "StaticScheduler",
    "OracleStaticScheduler",
    "proportional_split",
    "latency_aware_split",
]

# A measured throughput of exactly 0.0 is still a measurement (the unit is
# stalled), not an invitation to re-apply the optimistic bootstrap prior;
# the floor only protects the arithmetic downstream from division blowups.
THROUGHPUT_FLOOR = 1e-9


def proportional_split(num_items: int, throughputs: Dict[str, float]) -> Dict[str, int]:
    """Split ``[0, num_items)`` proportionally to per-unit throughputs.

    Worker order follows ``throughputs`` insertion order; every non-last
    share is rounded (banker's ``round``) then clamped so rounding can
    never overshoot the space, and the last worker absorbs the exact
    remainder — the split always tiles the space.  Whenever the space has
    at least one item per worker, every positive-throughput worker is
    guaranteed a non-empty share (a slow-but-live unit must not round to
    zero and then idle for the whole run).  Shared by
    :class:`OracleStaticScheduler` (user-supplied speeds) and the learned
    policy in :mod:`repro.core.runtime` (measured speeds from the cost
    model).  Equivalent to :func:`latency_aware_split` at zero overhead.
    """
    return latency_aware_split(num_items, throughputs)


def latency_aware_split(
    num_items: int,
    throughputs: Dict[str, float],
    overheads: Optional[Dict[str, float]] = None,
) -> Dict[str, int]:
    """Split ``[0, num_items)`` to equalize *predicted completion time*.

    ``overheads`` maps worker -> fixed seconds the worker pays before its
    share completes (learned dispatch + wire latency from the cost model);
    missing/None entries mean zero.  The ideal share solves the
    water-filling problem: find the completion level ``tau`` with

        sum_i  T_i * max(tau - L_i, 0)  =  num_items

    so every participating worker finishes at ``n_i / T_i + L_i == tau``,
    and a worker whose overhead alone exceeds ``tau`` drops out of the
    level computation (it would need a negative share).  With all-zero
    overheads this degenerates to a pure throughput-proportional split.

    Rounding and guarantees are shared with :func:`proportional_split`:
    insertion-order banker's rounding with the last *positive-throughput*
    worker absorbing the remainder (a stalled unit never absorbs), and —
    whenever ``num_items >= len(throughputs)`` — at least 1 item for
    every positive-throughput worker (donated from the largest share,
    first-in-order on ties).
    """
    if num_items < 0:
        raise ValueError(f"num_items must be non-negative, got {num_items}")
    if not throughputs:
        raise ValueError("throughputs must not be empty")
    total = sum(throughputs.values())
    if total <= 0:
        raise ValueError(f"throughputs must sum positive, got {total}")
    names = list(throughputs)
    if num_items == 0:
        return {w: 0 for w in names}
    lat = {w: max(float((overheads or {}).get(w) or 0.0), 0.0) for w in names}

    # Water-fill the completion level over positive-throughput workers,
    # dropping the highest-overhead worker while it sits above the level.
    shares = {w: 0.0 for w in names}
    active = [w for w in names if throughputs[w] > 0]
    level = 0.0
    while active:
        t_sum = sum(throughputs[w] for w in active)
        level = (num_items + sum(throughputs[w] * lat[w] for w in active)) / t_sum
        over = [w for w in active if lat[w] >= level]
        if not over:
            break
        worst = max(over, key=lambda w: lat[w])
        active.remove(worst)
    for w in active:
        shares[w] = throughputs[w] * (level - lat[w])

    # Banker's rounding in insertion order; the *last live* worker absorbs
    # the remainder (never a zero-throughput one — handing a stalled unit
    # the rounding slack would strand those items).
    absorber = [w for w in names if throughputs[w] > 0][-1]
    sizes: Dict[str, int] = {}
    start = 0
    for w in names:
        size = min(int(round(shares[w])), num_items - start)
        sizes[w] = size
        start += size
    sizes[absorber] += num_items - start

    # Starvation guarantee: with at least one item per worker available,
    # every positive-throughput worker gets a non-empty share.  Donors are
    # the largest shares (first in insertion order on ties); by pigeonhole
    # a >=2-item donor always exists while some live worker sits at zero.
    if num_items >= len(names):
        for w in names:
            while throughputs[w] > 0 and sizes[w] < 1:
                donor = max(names, key=lambda d: sizes[d])
                sizes[donor] -= 1
                sizes[w] += 1
    return sizes


@dataclass(frozen=True)
class Chunk:
    """A contiguous slice ``[start, stop)`` of the iteration space."""

    start: int
    stop: int
    worker: str

    @property
    def size(self) -> int:
        return self.stop - self.start

    def indices(self) -> range:
        return range(self.start, self.stop)


class WorkerKind:
    ACC = "acc"  # accelerator: fixed, user-set chunk size
    CC = "cc"    # core: dynamically adapted chunk size


@dataclass
class WorkerState:
    name: str
    kind: str
    # items/second, EWMA-updated from completions.  ``None`` until first
    # completion; the scheduler bootstraps with ``initial_throughput``.
    throughput: Optional[float] = None
    items_done: int = 0
    chunks_done: int = 0
    busy: bool = False
    total_busy_time: float = 0.0


class MultiDynamicScheduler:
    """The paper's *MultiDynamic* scheduler.

    Parameters
    ----------
    num_items:
        Size of the iteration space (rows for SPMM/HOTSPOT, microbatches
        for hetero data-parallel training, request slots for serving).
    acc_chunk:
        User-specified accelerator chunk size (the paper's central knob).
    min_cc_chunk / max_cc_chunk:
        Clamp for the adaptive CC chunk.
    ewma_alpha:
        Smoothing for the throughput estimate (paper adapts at runtime;
        EWMA is the standard instantiation).
    initial_acc_speedup:
        Prior for ACC/CC throughput ratio before any completion has been
        observed (the paper seeds from a calibration run).
    """

    def __init__(
        self,
        num_items: int,
        acc_chunk: int,
        *,
        min_cc_chunk: int = 1,
        max_cc_chunk: Optional[int] = None,
        ewma_alpha: float = 0.4,
        initial_acc_speedup: float = 8.0,
        tail_fraction: float = 0.5,
    ) -> None:
        if num_items <= 0:
            raise ValueError(f"num_items must be positive, got {num_items}")
        if acc_chunk <= 0:
            raise ValueError(f"acc_chunk must be positive, got {acc_chunk}")
        self.num_items = num_items
        self.acc_chunk = acc_chunk
        self.min_cc_chunk = min_cc_chunk
        self.max_cc_chunk = max_cc_chunk or max(1, num_items)
        self.ewma_alpha = ewma_alpha
        self.initial_acc_speedup = initial_acc_speedup
        self.tail_fraction = tail_fraction

        self._next = 0
        self._lock = threading.Lock()
        self._workers: Dict[str, WorkerState] = {}
        # worker -> FIFO of in-flight chunks.  Plain (capacity-1) drivers
        # only ever have one entry; a pipelined driver (BackendEngine over
        # a batched RemoteUnit) raises the worker's capacity first via
        # set_capacity() and may then keep several in flight.
        self._outstanding: Dict[str, List[Chunk]] = {}
        self._capacity: Dict[str, int] = {}
        self._issue_times: Dict[str, float] = {}
        self._history: List[Tuple[Chunk, float]] = []

    def set_capacity(self, worker: str, capacity: int) -> None:
        """Allow ``worker`` to hold up to ``capacity`` chunks in flight."""
        with self._lock:
            self._capacity[worker] = max(int(capacity), 1)

    # ------------------------------------------------------------------
    # worker registry
    # ------------------------------------------------------------------
    def add_worker(self, name: str, kind: str, throughput: Optional[float] = None) -> None:
        if kind not in (WorkerKind.ACC, WorkerKind.CC):
            raise ValueError(f"unknown worker kind {kind!r}")
        with self._lock:
            if name in self._workers:
                raise ValueError(f"duplicate worker {name!r}")
            self._workers[name] = WorkerState(name=name, kind=kind, throughput=throughput)

    def abort(self, worker: str) -> List[Chunk]:
        """Drop ``worker``'s in-flight chunks without counting them.

        The elastic layer calls this when a unit departs mid-chunk; the
        caller owns requeueing the dropped spans so coverage stays
        exact-once.  Returns *all* aborted chunks oldest-first — with
        ``set_capacity > 1`` a pipelined worker may hold several in
        flight, and returning only the oldest would silently lose
        coverage for any driver that isn't the tracked runtime facade.
        """
        with self._lock:
            state = self._workers.get(worker)
            chunks = self._outstanding.pop(worker, None)
            self._issue_times.pop(worker, None)
            if state is not None:
                state.busy = False
            return list(chunks) if chunks else []

    def remove_worker(self, name: str) -> List[Chunk]:
        """Unregister a unit mid-run (elastic leave); returns all its aborted chunks."""
        chunks = self.abort(name)
        with self._lock:
            self._workers.pop(name, None)
        return chunks

    @property
    def workers(self) -> Dict[str, WorkerState]:
        return dict(self._workers)

    # ------------------------------------------------------------------
    # throughput estimation
    # ------------------------------------------------------------------
    def _estimated_throughput(self, state: WorkerState) -> float:
        if state.throughput is not None:
            # A measurement — even 0.0 from a stalled unit counts; floor it
            # instead of falling through to the optimistic bootstrap prior.
            return max(state.throughput, THROUGHPUT_FLOOR)
        # Bootstrap: unobserved units get a prior relative to observed ones.
        observed = [w.throughput for w in self._workers.values()
                    if w.throughput is not None]
        base = max(min(observed), THROUGHPUT_FLOOR) if observed else 1.0
        if state.kind == WorkerKind.ACC:
            return base * self.initial_acc_speedup
        return base

    def _cc_chunk_size(self, state: WorkerState, remaining: int) -> int:
        """Adapt the CC chunk so a core finishes in about one ACC-chunk time.

        ``cc_chunk = acc_chunk * T_cc / T_acc`` (load-balance condition),
        decayed guided-style over the tail so the final chunks shrink and no
        unit strands the others waiting on a large remainder.
        """
        t_cc = self._estimated_throughput(state)
        accs = [w for w in self._workers.values() if w.kind == WorkerKind.ACC]
        if accs:
            t_acc = max(self._estimated_throughput(a) for a in accs)
        else:
            t_acc = t_cc * self.initial_acc_speedup
        balanced = self.acc_chunk * (t_cc / max(t_acc, 1e-12))
        # Guided tail decay: never take more than tail_fraction of what is
        # left divided by the number of idle units.
        idle = max(1, sum(1 for w in self._workers.values() if not w.busy))
        guided_cap = max(1.0, self.tail_fraction * remaining / idle)
        size = int(max(self.min_cc_chunk, min(balanced, guided_cap, self.max_cc_chunk)))
        return max(1, size)

    # ------------------------------------------------------------------
    # chunk issue / completion (the parallel_for engine of Fig. 2)
    # ------------------------------------------------------------------
    def next_chunk(self, worker: str, now: float = 0.0) -> Optional[Chunk]:
        """Hand the next chunk to ``worker``; ``None`` when space exhausted.

        A worker may hold several chunks at once when its driver pipelines
        and raised the worker's capacity via :meth:`set_capacity`; at the
        default capacity of 1 a busy worker cannot double-issue.  ``busy``
        means "has at least one chunk in flight", which is what the CC
        chunk-size adaptation's idle count keys on.
        """
        with self._lock:
            state = self._workers[worker]
            pending = self._outstanding.get(worker, ())
            if len(pending) >= self._capacity.get(worker, 1):
                raise RuntimeError(f"worker {worker!r} requested a chunk while busy")
            remaining = self.num_items - self._next
            if remaining <= 0:
                return None
            if state.kind == WorkerKind.ACC:
                size = min(self.acc_chunk, remaining)
            else:
                size = min(self._cc_chunk_size(state, remaining), remaining)
            chunk = Chunk(self._next, self._next + size, worker)
            self._next += size
            state.busy = True
            self._outstanding.setdefault(worker, []).append(chunk)
            self._issue_times[worker] = now
            return chunk

    def complete(self, worker: str, elapsed: float,
                 chunk: Optional[Chunk] = None) -> None:
        """Record a completion (called by the interrupt/event layer).

        ``chunk`` selects which in-flight chunk finished when the worker
        pipelines several (matched on ``(start, stop)``); ``None`` means
        FIFO — the only case for capacity-1 drivers, where it is exact.
        """
        with self._lock:
            state = self._workers[worker]
            pending = self._outstanding.get(worker)
            if not pending:
                raise RuntimeError(f"completion from {worker!r} with no outstanding chunk")
            if chunk is None:
                done = pending.pop(0)
            else:
                for i, c in enumerate(pending):
                    if (c.start, c.stop) == (chunk.start, chunk.stop):
                        done = pending.pop(i)
                        break
                else:
                    raise RuntimeError(
                        f"completion from {worker!r} for span "
                        f"[{chunk.start}, {chunk.stop}) that is not outstanding"
                    )
            if not pending:
                del self._outstanding[worker]
                state.busy = False
            state.items_done += done.size
            state.chunks_done += 1
            state.total_busy_time += max(elapsed, 1e-12)
            inst = done.size / max(elapsed, 1e-12)
            if state.throughput is None:
                state.throughput = inst
            else:
                a = self.ewma_alpha
                state.throughput = a * inst + (1 - a) * state.throughput
            self._history.append((done, elapsed))

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        with self._lock:
            return self._next >= self.num_items and not self._outstanding

    @property
    def issued(self) -> int:
        with self._lock:
            return self._next

    def coverage(self) -> List[Tuple[int, int]]:
        """Sorted (start, stop) of all completed chunks — for invariants."""
        with self._lock:
            spans = sorted((c.start, c.stop) for c, _ in self._history)
        return spans

    def load_balance(self) -> float:
        """max busy time / mean busy time across units (1.0 = perfect)."""
        with self._lock:
            times = [w.total_busy_time for w in self._workers.values() if w.chunks_done]
        if not times:
            return 1.0
        mean = sum(times) / len(times)
        return max(times) / max(mean, 1e-12)


class StaticScheduler:
    """Baseline: pre-split the space evenly across units (no adaptation).

    This is the strawman the paper's dynamic scheme beats on irregular
    workloads; kept for the Table-1-style ablation.
    """

    def __init__(self, num_items: int, workers: List[str]) -> None:
        self.num_items = num_items
        self._assignments: Dict[str, Iterator[Chunk]] = {}
        n = len(workers)
        per = num_items // n
        rem = num_items % n
        start = 0
        for i, w in enumerate(workers):
            size = per + (1 if i < rem else 0)
            chunk = Chunk(start, start + size, w)
            self._assignments[w] = iter([chunk] if size else [])
            start += size

    def next_chunk(self, worker: str, now: float = 0.0) -> Optional[Chunk]:
        return next(self._assignments[worker], None)

    def complete(self, worker: str, elapsed: float,
                 chunk: Optional[Chunk] = None) -> None:  # pragma: no cover
        pass


class OracleStaticScheduler:
    """Static split proportional to *known* throughputs (upper bound for
    regular workloads; still loses to MultiDynamic on irregular ones)."""

    def __init__(
        self,
        num_items: int,
        throughputs: Dict[str, float],
        overheads: Optional[Dict[str, float]] = None,
    ) -> None:
        self.num_items = num_items
        self._assignments: Dict[str, Optional[Chunk]] = {}
        start = 0
        split = latency_aware_split(num_items, throughputs, overheads)
        for w, size in split.items():
            self._assignments[w] = Chunk(start, start + size, w) if size > 0 else None
            start += size

    def next_chunk(self, worker: str, now: float = 0.0) -> Optional[Chunk]:
        chunk = self._assignments.get(worker)
        self._assignments[worker] = None
        return chunk

    def complete(self, worker: str, elapsed: float,
                 chunk: Optional[Chunk] = None) -> None:  # pragma: no cover
        pass

"""Straggler detection and mitigation.

On 1000+ node jobs some hosts are always slow (thermal throttling, ECC
retries, noisy neighbours, failing NICs).  SPMD lock-step turns one slow
group into a whole-job slowdown.  The ENEAC response: measure per-unit
throughput at runtime and rebalance the chunk assignment (here: per-group
microbatch counts via :class:`~repro.core.hetero.HeterogeneousPartitioner`).

Detection is deliberately boring and robust: per-group step-time EWMA
compared against the fleet median with a multiplicative threshold plus a
consecutive-breach count (single slow steps — GC pauses, checkpoint writes —
must not trigger a rebalance).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .hetero import HeteroPartition, HeterogeneousPartitioner, ThroughputTracker

__all__ = ["StragglerDetector", "StragglerReport", "MitigationPlan", "StragglerMitigator"]


def _median(xs: Sequence[float]) -> float:
    s = sorted(xs)
    n = len(s)
    if n == 0:
        return 0.0
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


@dataclass
class StragglerReport:
    stragglers: List[str]
    ratios: Dict[str, float]          # group step time / median step time
    median_step_time: float


@dataclass
class MitigationPlan:
    partition: HeteroPartition
    predicted_step_time: float
    baseline_step_time: float

    @property
    def predicted_speedup(self) -> float:
        return self.baseline_step_time / max(self.predicted_step_time, 1e-12)


class StragglerDetector:
    def __init__(
        self,
        *,
        alpha: float = 0.3,
        threshold: float = 1.3,
        patience: int = 3,
    ) -> None:
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self._ewma: Dict[str, float] = {}
        self._breaches: Dict[str, int] = {}

    def observe(self, step_times: Dict[str, float]) -> StragglerReport:
        for g, t in step_times.items():
            prev = self._ewma.get(g)
            self._ewma[g] = t if prev is None else self.alpha * t + (1 - self.alpha) * prev
        med = _median(list(self._ewma.values()))
        ratios = {g: v / max(med, 1e-12) for g, v in self._ewma.items()}
        stragglers = []
        # Breach counters move only for groups observed *this* call: the
        # engine feeds one completion at a time, and a unit must not
        # accumulate breaches while it is idle just because others finish.
        for g in step_times:
            if ratios.get(g, 0.0) > self.threshold:
                self._breaches[g] = self._breaches.get(g, 0) + 1
            else:
                self._breaches[g] = 0
        for g, n in self._breaches.items():
            if n >= self.patience:
                stragglers.append(g)
        return StragglerReport(stragglers=sorted(stragglers), ratios=ratios, median_step_time=med)

    def forget(self, group: str) -> None:
        """Stop tracking ``group`` (e.g. after it was quarantined) so its
        stale EWMA no longer skews the fleet median."""
        self._ewma.pop(group, None)
        self._breaches.pop(group, None)


class StragglerMitigator:
    """Glue: detector + throughput tracker + partitioner → MitigationPlan."""

    def __init__(
        self,
        groups: Sequence[str],
        total_microbatches: int,
        *,
        detector: Optional[StragglerDetector] = None,
        partitioner: Optional[HeterogeneousPartitioner] = None,
    ) -> None:
        self.groups = list(groups)
        self.total = total_microbatches
        self.detector = detector or StragglerDetector()
        self.partitioner = partitioner or HeterogeneousPartitioner()
        self.tracker = ThroughputTracker()
        self.partition = HeterogeneousPartitioner.uniform(total_microbatches, groups)

    def step(self, step_times: Dict[str, float]) -> Optional[MitigationPlan]:
        """Feed one step's per-group times; returns a plan when rebalancing."""
        for g, t in step_times.items():
            self.tracker.update(g, items=self.partition.counts[g], elapsed=t)
        report = self.detector.observe(step_times)
        if not report.stragglers:
            return None
        tps = {g: self.tracker.get(g) for g in self.groups}
        new = self.partitioner.update(self.total, tps)
        if new is self.partition:
            return None
        baseline = HeterogeneousPartitioner.step_time(
            HeterogeneousPartitioner.uniform(self.total, self.groups), tps
        )
        plan = MitigationPlan(
            partition=new,
            predicted_step_time=HeterogeneousPartitioner.step_time(new, tps),
            baseline_step_time=baseline,
        )
        self.partition = new
        return plan

"""ENEAC core: the paper's contribution as composable JAX/host modules.

* :mod:`repro.core.scheduler` — MultiDynamic heterogeneous chunk scheduler.
* :mod:`repro.core.interrupts` — completion-driven async engine (interrupt
  analogue) + busy-wait baseline.
* :mod:`repro.core.backends` — real backend units (threads, process
  pools, jax device streams) + the event-driven wall-clock engine.
* :mod:`repro.core.transport` — message-level transports (loopback,
  TCP, fault-injecting) and remote shard engines: ``RemoteWorker``
  hosts backend units behind a transport, ``RemoteUnit`` proxies them
  into the runtime as ordinary units.
* :mod:`repro.core.hetero` — throughput-proportional work partitioning.
* :mod:`repro.core.costmodel` — online per-(unit, kernel) cost model:
  EWMA capability descriptors learned from run reports, persisted as a
  versioned JSON store; feeds ``policy="learned"`` splits.
* :mod:`repro.core.straggler` — straggler detection and mitigation.
* :mod:`repro.core.elastic` — node-failure handling / mesh rescale plans.
* :mod:`repro.core.fleet` — fleet membership: heartbeat liveness ledger,
  queue-driven autoscaling, seeded churn simulation, and the wall-clock
  manager that owns ``spawn_worker`` subprocesses.
* :mod:`repro.core.moe_dispatch` — capacity-chunk MoE dispatch with dense
  fallback (the LM-native instantiation of MultiDynamic).
* :mod:`repro.core.parallel_for` — hybrid MXU/VPU executor for irregular
  workloads (SPMM).
* :mod:`repro.core.space` — iteration spaces: flat ranges, 2D kernel
  tile grids, and host-sharded spaces with merged global reports.
* :mod:`repro.core.runtime` — :class:`HeteroRuntime`, the unified front
  door: scheduler policy × completion engine × clock × iteration space
  behind one ``parallel_for`` (the paper's Fig. 2 pipeline end-to-end),
  with elastic unit join/leave under :class:`SimulatedClock`.
"""

from .scheduler import Chunk, MultiDynamicScheduler, OracleStaticScheduler, StaticScheduler, WorkerKind
from .interrupts import AsyncEngine, CompletionEvent, PollingEngine, RunReport
from .backends import (
    BackendEngine,
    BackendUnit,
    CompletionBus,
    CompletionRecord,
    InlineUnit,
    JaxDeviceUnit,
    ProcessPoolUnit,
    ThreadUnit,
    WorkerDead,
    WorkerLost,
)
from .transport import (
    FlakyTransport,
    LoopbackTransport,
    RemoteUnit,
    RemoteWorker,
    SocketTransport,
    Transport,
    TransportClosed,
    TransportError,
    WorkerServer,
    spawn_worker,
)
from .space import FlatSpace, IterationSpace, ShardedSpace, TiledSpace
from .costmodel import CostEntry, CostModel, CostModelWarning
from .runtime import HeteroRuntime, SimulatedClock, UnitSpec, WallClock, WorkQueue
from .hetero import HeteroPartition, HeterogeneousPartitioner, ThroughputTracker
from .straggler import MitigationPlan, StragglerDetector, StragglerMitigator, StragglerReport
from .elastic import DeviceHealth, ElasticEvent, ElasticMeshManager, ElasticSchedule, RescalePlan
from .parallel_for import HybridExecutor, SplitDecision
from .fleet import (
    Autoscaler,
    FailureTrace,
    FleetManager,
    FleetSimResult,
    HeartbeatBook,
    TraceEvent,
    simulate_fleet,
)

__all__ = [
    "HeteroRuntime",
    "SimulatedClock",
    "UnitSpec",
    "WallClock",
    "WorkQueue",
    "IterationSpace",
    "FlatSpace",
    "TiledSpace",
    "ShardedSpace",
    "ElasticEvent",
    "ElasticSchedule",
    "Chunk",
    "MultiDynamicScheduler",
    "StaticScheduler",
    "OracleStaticScheduler",
    "WorkerKind",
    "AsyncEngine",
    "PollingEngine",
    "CompletionEvent",
    "RunReport",
    "BackendEngine",
    "BackendUnit",
    "CompletionBus",
    "CompletionRecord",
    "InlineUnit",
    "ThreadUnit",
    "ProcessPoolUnit",
    "JaxDeviceUnit",
    "WorkerLost",
    "WorkerDead",
    "Transport",
    "TransportError",
    "TransportClosed",
    "LoopbackTransport",
    "SocketTransport",
    "FlakyTransport",
    "RemoteUnit",
    "RemoteWorker",
    "WorkerServer",
    "spawn_worker",
    "HeteroPartition",
    "HeterogeneousPartitioner",
    "ThroughputTracker",
    "CostModel",
    "CostEntry",
    "CostModelWarning",
    "StragglerDetector",
    "StragglerMitigator",
    "StragglerReport",
    "MitigationPlan",
    "DeviceHealth",
    "ElasticMeshManager",
    "RescalePlan",
    "HybridExecutor",
    "SplitDecision",
    "HeartbeatBook",
    "Autoscaler",
    "FailureTrace",
    "TraceEvent",
    "FleetSimResult",
    "FleetManager",
    "simulate_fleet",
]

"""Multi-host transport backends: one runtime driving remote shard engines.

The ENEAC loop so far kept every compute unit in the dispatcher's address
space — threads, process pools, device streams.  This module stretches
the :class:`~repro.core.backends.BackendUnit` boundary across a *message
transport*, the way HEROv2 (arXiv:2201.03861) stretches the host↔PULP
offload path across a real interconnect, while keeping dispatch latency
observable end-to-end (HTS, arXiv:1907.00271):

* **Frame codec** — length-prefixed pickled frames
  (:func:`encode_frame`, :class:`FrameDecoder`): a 4-byte big-endian
  payload length followed by the pickled frame dict.
* :class:`Transport` — the message boundary: ``send(frame)`` /
  ``recv(timeout)`` / ``close()``.  Two real implementations:
  :class:`LoopbackTransport` (an in-process queue pair that passes frames
  by reference — the deterministic test medium) and
  :class:`SocketTransport` (localhost/LAN TCP with the length-prefixed
  pickle codec).  :class:`FlakyTransport` wraps either with seeded
  drop / delay / duplicate / reorder fault injection — the first place in
  this repo where a completion can be lost by the *medium* instead of the
  code, which is why the reliability protocol below exists.
* :class:`RemoteWorker` — the far side: a serve loop that hosts real
  backend units (thread / inline / process / jax) behind one transport
  session, executes submitted chunks on them, and pumps their
  completions back as frames.  :class:`WorkerServer` accepts TCP
  connections and runs one :class:`RemoteWorker` per connection;
  ``python -m repro.core.transport`` serves one from a fresh process and
  :func:`spawn_worker` launches that as a managed subprocess.
* :class:`RemoteUnit` — the near side: a
  :class:`~repro.core.backends.BackendUnit` proxy that makes a remote
  worker look like any other unit.  ``submit(chunk, work_fn)`` forwards a
  frame without blocking; a receiver thread pumps ``done`` frames back
  onto the run's :class:`~repro.core.backends.CompletionBus`; dispatch
  latency is split into its local-queue and wire components
  (``RunReport.wire_latency``).

Reliability protocol (what makes the FlakyTransport battery pass):

* every submit carries a per-unit monotonically increasing ``seq``; the
  engine guarantees one chunk in flight per unit, so the proxy
  retransmits the pending frame on a timer until its completion arrives;
* the worker executes a seq **at most once**: duplicates of an already
  accepted seq re-send the cached ``done`` frame, or answer ``busy``
  while it is still executing — so dropped/duplicated/reordered frames
  never duplicate work-function side effects, and the retransmit budget
  measures worker *silence* rather than execution time (a chunk may
  legitimately run for minutes);
* the proxy ignores ``done`` frames whose seq is not the pending one, so
  duplicated completions are dropped on the floor;
* a definitive connection loss (EOF) or retransmit exhaustion posts a
  :class:`~repro.core.backends.WorkerLost` completion, which
  :class:`~repro.core.backends.BackendEngine` answers by removing the
  unit and requeueing its in-flight chunk to the survivors exactly once
  (an ``action="lost"`` event in ``RunReport.events``).

Failure semantics, stated honestly: when only *frames* are lost the
protocol preserves exact-once execution.  When the **worker itself** is
lost, a chunk it had already executed (whose completion never arrived)
is requeued and re-executed by a survivor — results stay correct because
the dead worker's results never surfaced, but external side effects need
an idempotent sink (e.g. write-per-index files, not appends).  This is
the standard at-least-once boundary of any distributed work queue; the
tests pin both halves of the contract.
"""

from __future__ import annotations

import argparse
import os
import pickle
import queue
import random
import select
import socket
import struct
import subprocess
import sys
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional, Tuple

from .backends import (
    BackendUnit,
    CompletionBus,
    CompletionRecord,
    WorkerLost,
    make_backend,
)
from .scheduler import Chunk

__all__ = [
    "Transport",
    "TransportError",
    "TransportClosed",
    "LoopbackTransport",
    "SocketTransport",
    "FlakyTransport",
    "RemoteWorker",
    "WorkerServer",
    "RemoteUnit",
    "SleepWork",
    "WorkerHandle",
    "spawn_worker",
    "encode_frame",
    "FrameDecoder",
    "MAX_FRAME_BYTES",
]


class TransportError(ConnectionError):
    """The transport failed to carry a frame (protocol or session error)."""


class TransportClosed(TransportError):
    """The transport is closed (locally or by the peer) — definitive EOF."""


# ---------------------------------------------------------------------------
# frame codec: length-prefixed pickled frames
# ---------------------------------------------------------------------------
_HEADER = struct.Struct(">I")
MAX_FRAME_BYTES = 256 * 1024 * 1024  # refuse absurd lengths (corrupt header)


def encode_frame(frame: dict) -> bytes:
    """``frame`` -> 4-byte big-endian payload length + pickled payload."""
    payload = pickle.dumps(frame, protocol=pickle.HIGHEST_PROTOCOL)
    if len(payload) > MAX_FRAME_BYTES:
        raise TransportError(
            f"frame of {len(payload)} bytes exceeds MAX_FRAME_BYTES"
        )
    return _HEADER.pack(len(payload)) + payload


class FrameDecoder:
    """Incremental decoder: feed byte chunks, get complete frames out.

    TCP delivers a byte stream, not messages; the decoder buffers partial
    frames across ``feed`` calls and yields each frame exactly once, in
    order, no matter how the stream was segmented.
    """

    def __init__(self) -> None:
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[dict]:
        self._buf += data
        out: List[dict] = []
        while True:
            if len(self._buf) < _HEADER.size:
                break
            (n,) = _HEADER.unpack_from(self._buf)
            if n > MAX_FRAME_BYTES:
                raise TransportError(
                    f"frame header claims {n} bytes (> {MAX_FRAME_BYTES}); "
                    "stream is corrupt"
                )
            if len(self._buf) < _HEADER.size + n:
                break
            payload = bytes(self._buf[_HEADER.size:_HEADER.size + n])
            del self._buf[:_HEADER.size + n]
            try:
                out.append(pickle.loads(payload))
            except Exception as exc:
                # The length prefix kept the stream aligned, so a payload
                # that cannot unpickle here (e.g. a work_fn whose module
                # the peer cannot import) is dropped as a poison frame —
                # the retransmit/WorkerLost protocol turns it into a
                # requeue instead of a dead session thread.
                out.append({"kind": "undecodable", "message": repr(exc)})
        return out


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------
class Transport:
    """Message boundary between a :class:`RemoteUnit` and its worker.

    ``send`` must be safe to call from multiple threads; ``recv`` is only
    ever called from one receiver thread.  ``recv`` returns ``None`` on
    timeout and raises :class:`TransportClosed` on definitive EOF.
    """

    def send(self, frame: dict) -> None:
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None) -> Optional[dict]:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    @property
    def closed(self) -> bool:
        raise NotImplementedError


_EOF = object()


class LoopbackTransport(Transport):
    """In-process transport: a queue pair passing frames *by reference*.

    The deterministic test medium: no sockets, no pickling — which is
    deliberate, because by-reference delivery is what lets in-process
    tests share a side-effect ledger with the "remote" worker and assert
    exact-once semantics directly.  (Message-level fidelity — everything
    must survive pickling — is :class:`SocketTransport`'s job.)
    """

    def __init__(self) -> None:
        self._inbox: "queue.Queue" = queue.Queue()
        self._peer: Optional["LoopbackTransport"] = None
        self._closed = False

    @classmethod
    def pair(cls) -> Tuple["LoopbackTransport", "LoopbackTransport"]:
        a, b = cls(), cls()
        a._peer, b._peer = b, a
        return a, b

    def send(self, frame: dict) -> None:
        peer = self._peer
        if self._closed or peer is None or peer._closed:
            raise TransportClosed("loopback endpoint closed")
        peer._inbox.put(frame)

    def recv(self, timeout: Optional[float] = None) -> Optional[dict]:
        if self._closed:
            raise TransportClosed("loopback endpoint closed")
        try:
            item = self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None
        if item is _EOF:
            self._inbox.put(_EOF)  # later recvs see EOF too
            raise TransportClosed("peer closed the loopback")
        return item

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._inbox.put(_EOF)
        if self._peer is not None:
            self._peer._inbox.put(_EOF)

    @property
    def closed(self) -> bool:
        return self._closed


def parse_address(address: str) -> Tuple[str, int]:
    """``"host:port"`` -> ``(host, port)`` (last colon splits the port)."""
    host, sep, port = str(address).rpartition(":")
    if not sep or not host or not port.isdigit():
        raise ValueError(f"address must be 'host:port', got {address!r}")
    return host, int(port)


class SocketTransport(Transport):
    """Length-prefixed pickled frames over a stream socket (TCP or UNIX)."""

    def __init__(self, sock: socket.socket) -> None:
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass  # AF_UNIX / socketpair: no Nagle to disable
        self._sock = sock
        self._decoder = FrameDecoder()
        self._ready: deque = deque()
        self._send_lock = threading.Lock()
        self._closed = False

    @classmethod
    def connect(cls, address: str, timeout: float = 10.0) -> "SocketTransport":
        host, port = parse_address(address)
        sock = socket.create_connection((host, port), timeout=timeout)
        sock.settimeout(None)
        return cls(sock)

    def send(self, frame: dict) -> None:
        data = encode_frame(frame)  # pickling errors surface to the caller
        with self._send_lock:
            if self._closed:
                raise TransportClosed("socket transport closed")
            try:
                self._sock.sendall(data)
            except OSError as exc:
                self._closed = True
                raise TransportClosed(f"send failed: {exc}") from exc

    def recv(self, timeout: Optional[float] = None) -> Optional[dict]:
        deadline = None if timeout is None else time.perf_counter() + timeout
        while True:
            if self._ready:
                return self._ready.popleft()
            if self._closed:
                raise TransportClosed("socket transport closed")
            remaining: Optional[float] = None
            if deadline is not None:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    return None
            try:
                self._sock.settimeout(remaining)
                data = self._sock.recv(1 << 16)
            except socket.timeout:
                return None
            except OSError as exc:
                self._closed = True
                raise TransportClosed(f"recv failed: {exc}") from exc
            if not data:
                self._closed = True
                raise TransportClosed("peer closed the connection")
            self._ready.extend(self._decoder.feed(data))

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass

    @property
    def closed(self) -> bool:
        return self._closed


class FlakyTransport(Transport):
    """Seeded fault injection on the send path of any transport.

    Each sent frame independently draws from the seeded RNG: it may be
    **dropped** (never delivered), **duplicated** (delivered twice),
    **held for reordering** (delivered after the *next* frame), or
    **delayed** (delivered up to ``max_delay`` seconds late from a timer
    thread).  Receives pass straight through — wrap both endpoints to
    fault both directions.  Faults never raise: a frame racing a closing
    transport is just another drop, which the reliability protocol must
    absorb anyway.
    """

    def __init__(
        self,
        inner: Transport,
        *,
        seed: int,
        drop: float = 0.0,
        duplicate: float = 0.0,
        reorder: float = 0.0,
        delay: float = 0.0,
        max_delay: float = 0.02,
    ) -> None:
        self.inner = inner
        self.drop = float(drop)
        self.duplicate = float(duplicate)
        self.reorder = float(reorder)
        self.delay = float(delay)
        self.max_delay = float(max_delay)
        self._rng = random.Random(seed)
        self._held: Optional[dict] = None
        self._lock = threading.Lock()
        self.stats = {"sent": 0, "dropped": 0, "duplicated": 0,
                      "reordered": 0, "delayed": 0}

    def _deliver(self, frame: dict) -> None:
        try:
            self.inner.send(frame)
        except TransportError:
            pass  # racing a close: equivalent to a drop

    def send(self, frame: dict) -> None:
        with self._lock:
            self.stats["sent"] += 1
            if self._rng.random() < self.drop:
                self.stats["dropped"] += 1
                return
            dup = self._rng.random() < self.duplicate
            hold = self._rng.random() < self.reorder
            delay_s = (
                self._rng.uniform(0.0, self.max_delay)
                if self._rng.random() < self.delay else 0.0
            )
            to_send: List[dict] = []
            if hold:
                self.stats["reordered"] += 1
                held, self._held = self._held, frame
                if held is not None:
                    to_send.append(held)  # an older frame jumps the queue
            else:
                to_send.append(frame)
                held, self._held = self._held, None
                if held is not None:
                    to_send.append(held)  # delivered after its successor
                if dup:
                    self.stats["duplicated"] += 1
                    to_send.append(frame)
        for f in to_send:
            if delay_s > 0.0:
                self.stats["delayed"] += 1
                timer = threading.Timer(delay_s, self._deliver, args=(f,))
                timer.daemon = True
                timer.start()
            else:
                self._deliver(f)

    def recv(self, timeout: Optional[float] = None) -> Optional[dict]:
        return self.inner.recv(timeout)

    def close(self) -> None:
        self.inner.close()  # a still-held frame dies with the session

    @property
    def closed(self) -> bool:
        return self.inner.closed


# ---------------------------------------------------------------------------
# the far side: a worker hosting real backend units
# ---------------------------------------------------------------------------
_DONE_CACHE_DEPTH = 8   # completion frames kept per unit for dup-resend
_HOSTABLE = ("thread", "threads", "inline", "process", "processes", "jax")


class RemoteWorker:
    """Serve one transport session: host backend units, execute, report.

    Frames handled:

    * ``hello {unit, backend}`` — start hosting a backend unit for
      ``unit`` (idempotent: duplicates re-ack with ``ready``); a bad
      backend spec answers with an ``error`` frame instead.
    * ``submit {unit, seq, chunk, fn, t_submit}`` — execute ``fn(chunk)``
      on the hosted unit, **at most once per seq**: duplicates of an
      accepted seq re-send the cached ``done`` frame, or answer ``busy``
      while that seq is still executing (the client's liveness signal for
      long-running chunks), so retransmits and transport duplicates never
      duplicate side effects.
    * ``bye {unit}`` — graceful drain: stop hosting the unit (its
      in-flight chunk completes first; thread/pool shutdown waits on it).
    * ``shutdown`` — end the serve loop.

    All timestamps are ``time.perf_counter()`` — CLOCK_MONOTONIC, which
    on Linux is shared by every process on one machine, so worker-side
    execution-start times compose with client-side submit times into one
    dispatch-latency measurement across *local* processes (same trick
    :class:`ProcessPoolUnit` uses).  Across machines the two clocks have
    unrelated epochs: execution/coverage semantics are unaffected, but
    the reported latency split is only meaningful when client and worker
    share a host (the supported benchmark/test topology).
    """

    def __init__(self, transport: Transport, *, poll_interval: float = 0.2) -> None:
        self.transport = transport
        self.poll_interval = poll_interval
        self.bus = CompletionBus()
        self._units: Dict[str, BackendUnit] = {}
        self._last_seq: Dict[str, int] = {}
        self._inflight: Dict[str, Tuple[int, float]] = {}  # unit -> (seq, t_accept)
        self._done_cache: Dict[str, "OrderedDict[int, dict]"] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()

    # -- outbound ------------------------------------------------------------
    def _send(self, frame: dict) -> None:
        try:
            self.transport.send(frame)
            return
        except TransportClosed:
            self._stop.set()
            return
        except Exception as exc:
            # an untransportable payload (unpicklable result/error, or a
            # frame over MAX_FRAME_BYTES): strip it and keep the protocol
            # alive so the client gets an explanatory error instead of a
            # retransmit-exhaustion "lost worker"
            reason = exc
        stripped = {**frame, "result": None,
                    "error": TransportError(
                        f"completion payload not transportable: {reason}")}
        try:
            self.transport.send(stripped)
        except TransportError:
            self._stop.set()

    # -- inbound -------------------------------------------------------------
    def _handle_hello(self, frame: dict) -> None:
        name = frame.get("unit")
        spec = frame.get("backend") or "thread"
        if name not in self._units:
            if not isinstance(spec, str) or spec not in _HOSTABLE:
                self._send({"kind": "error", "unit": name,
                            "message": f"worker cannot host backend {spec!r} "
                                       f"(want one of {_HOSTABLE})"})
                return
            unit = make_backend(spec, name)
            unit.start(self.bus)
            with self._lock:
                self._units[name] = unit
                self._last_seq[name] = -1
                self._done_cache[name] = OrderedDict()
        self._send({"kind": "ready", "unit": name})

    def _handle_submit(self, frame: dict) -> None:
        name, seq = frame.get("unit"), frame.get("seq")
        reply = None
        accepted = False
        with self._lock:
            unit = self._units.get(name)
            if unit is None:
                return  # submit raced ahead of hello; retransmit will return
            if seq <= self._last_seq[name]:
                cached = self._done_cache[name].get(seq)
                if cached is not None:
                    reply = cached  # completion was lost in flight: resend
                elif self._inflight.get(name, (None,))[0] == seq:
                    # still executing: answer the probe so the client's
                    # retransmit budget measures *silence*, not work time
                    reply = {"kind": "busy", "unit": name, "seq": seq}
                # else: stale duplicate from before the cache window — drop
            elif name in self._inflight:
                pass  # defensive: never two executions on one unit
            else:
                self._last_seq[name] = seq
                self._inflight[name] = (seq, time.perf_counter())
                accepted = True
        if reply is not None:
            self._send(reply)
        if accepted:
            unit.submit(frame["chunk"], frame["fn"])

    def _handle_bye(self, frame: dict) -> None:
        with self._lock:
            unit = self._units.pop(frame.get("unit"), None)
        if unit is not None:
            unit.close()  # waits for an in-flight chunk (graceful drain)

    def _pump(self) -> None:
        """Forward hosted-unit completions as ``done`` frames."""
        while not self._stop.is_set():
            self.bus.wait(timeout=self.poll_interval)
            for rec in self.bus.drain():
                with self._lock:
                    entry = self._inflight.pop(rec.unit, None)
                if entry is None:
                    continue  # completion of a bye'd unit's last chunk
                seq, t_accept = entry
                frame = {
                    "kind": "done", "unit": rec.unit, "seq": seq,
                    "chunk": rec.chunk, "elapsed": rec.elapsed,
                    "t_start": t_accept + rec.dispatch_latency,
                    "error": rec.error, "result": rec.result,
                }
                with self._lock:
                    cache = self._done_cache.get(rec.unit)
                    if cache is not None:
                        cache[seq] = frame
                        while len(cache) > _DONE_CACHE_DEPTH:
                            cache.popitem(last=False)
                self._send(frame)

    # -- the loop ------------------------------------------------------------
    def serve(self) -> None:
        """Blocking serve loop; returns when the session ends."""
        pump = threading.Thread(target=self._pump, daemon=True,
                                name="eneac-worker-pump")
        pump.start()
        try:
            while not self._stop.is_set():
                try:
                    frame = self.transport.recv(timeout=self.poll_interval)
                except TransportClosed:
                    break
                if frame is None:
                    continue
                kind = frame.get("kind")
                if kind == "hello":
                    self._handle_hello(frame)
                elif kind == "submit":
                    self._handle_submit(frame)
                elif kind == "bye":
                    self._handle_bye(frame)
                elif kind == "shutdown":
                    break
                # unknown kinds are ignored (forward compatibility)
        finally:
            self._stop.set()
            pump.join(timeout=10.0)
            with self._lock:
                units, self._units = dict(self._units), {}
            for unit in units.values():
                try:
                    unit.close()
                except Exception:
                    pass
            self.transport.close()

    def stop(self) -> None:
        self._stop.set()


class WorkerServer:
    """TCP front door: one :class:`RemoteWorker` session per connection."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0) -> None:
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.host = host
        self.port = self._sock.getsockname()[1]
        self.address = f"{self.host}:{self.port}"
        self._stop = threading.Event()
        self._workers: List[RemoteWorker] = []
        self._threads: List[threading.Thread] = []
        self._accept_thread: Optional[threading.Thread] = None

    def serve_forever(self) -> None:
        self._sock.settimeout(0.25)
        while not self._stop.is_set():
            try:
                conn, _addr = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            worker = RemoteWorker(SocketTransport(conn))
            t = threading.Thread(target=worker.serve, daemon=True,
                                 name=f"eneac-worker-conn{len(self._threads)}")
            t.start()
            self._workers.append(worker)
            self._threads.append(t)

    def start(self) -> "WorkerServer":
        """Serve from a daemon thread (in-process test servers)."""
        self._accept_thread = threading.Thread(
            target=self.serve_forever, daemon=True, name="eneac-worker-accept"
        )
        self._accept_thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        for worker in self._workers:
            worker.stop()
        for t in self._threads:
            t.join(timeout=5.0)
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)


# ---------------------------------------------------------------------------
# the near side: the proxy unit
# ---------------------------------------------------------------------------
class RemoteUnit(BackendUnit):
    """A :class:`BackendUnit` whose execution happens behind a transport.

    Construct with either ``address="host:port"`` (connects a
    :class:`SocketTransport` at ``start``; reconnects on restart) or an
    already-connected ``transport=`` endpoint (loopback tests; single
    session).  ``remote_backend`` names the backend the worker hosts for
    this unit ("thread" by default).

    ``submit`` is non-blocking: it frames the chunk and returns; the
    receiver thread retransmits the pending frame every
    ``retry_interval`` seconds until its ``done`` arrives (the worker
    dedups, so retransmits are safe), posts the completion to the run's
    bus, and records the dispatch-latency split —

    * ``dispatch_latencies``: submit → remote execution start (total),
    * ``local_queue_latencies``: submit → first socket write,
    * ``wire_latencies``: first write → remote execution start (wire +
      remote queue; surfaced as ``RunReport.wire_latency``).

    The split subtracts worker-side from client-side ``perf_counter``
    readings, so it is meaningful when both share a machine (subprocess
    workers — the supported topology); a cross-machine worker skews the
    latency numbers by the clock-epoch offset without affecting
    execution or coverage semantics.

    Definitive EOF, a failed send, or ``max_retries`` unanswered
    retransmits post a :class:`~repro.core.backends.WorkerLost`
    completion instead — the engine's signal to requeue the in-flight
    chunk and drop this unit from the run.
    """

    kind_name = "remote"

    def __init__(
        self,
        name: str,
        address: Optional[str] = None,
        *,
        transport: Optional[Transport] = None,
        remote_backend: str = "thread",
        retry_interval: float = 0.1,
        max_retries: int = 100,
        connect_timeout: float = 10.0,
    ) -> None:
        super().__init__(name)
        if (address is None) == (transport is None):
            raise ValueError("pass exactly one of address= or transport=")
        if remote_backend not in _HOSTABLE:
            raise ValueError(
                f"remote_backend must be one of {_HOSTABLE}, "
                f"got {remote_backend!r} (no proxy chains)"
            )
        self.address = address
        self.remote_backend = remote_backend
        self.retry_interval = float(retry_interval)
        self.max_retries = int(max_retries)
        self.connect_timeout = float(connect_timeout)
        self._transport = transport
        self.lost = False
        self.wire_latencies: List[float] = []
        self.local_queue_latencies: List[float] = []
        self._seq = 0
        self._pending: Optional[dict] = None
        self._plock = threading.Lock()
        self._stop = threading.Event()
        self._recv_thread: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------
    def start(self, bus: CompletionBus) -> None:
        super().start(bus)
        self.wire_latencies = []
        self.local_queue_latencies = []
        if self._transport is None or self._transport.closed:
            if self.address is None:
                raise TransportClosed(
                    f"unit {self.name!r}: injected transport is closed and "
                    "there is no address to reconnect to"
                )
            self._transport = SocketTransport.connect(
                self.address, timeout=self.connect_timeout
            )
        self.lost = False
        self._stop.clear()
        self._handshake()
        self._recv_thread = threading.Thread(
            target=self._recv_loop, daemon=True,
            name=f"eneac-remote-{self.name}",
        )
        self._recv_thread.start()

    def _handshake(self) -> None:
        """hello → ready, retransmitting until the worker answers."""
        hello = {"kind": "hello", "unit": self.name,
                 "backend": self.remote_backend}
        deadline = time.perf_counter() + self.connect_timeout
        next_hello = 0.0
        while time.perf_counter() < deadline:
            if time.perf_counter() >= next_hello:
                self._transport.send(hello)
                next_hello = time.perf_counter() + max(self.retry_interval, 0.02)
            frame = self._transport.recv(timeout=0.02)
            if frame is None:
                continue
            kind = frame.get("kind")
            if kind == "ready" and frame.get("unit") == self.name:
                return
            if kind == "error" and frame.get("unit") == self.name:
                raise TransportError(
                    f"worker refused unit {self.name!r}: {frame.get('message')}"
                )
            # stale frames from an earlier session are ignored
        raise TransportError(
            f"worker for unit {self.name!r} did not answer hello within "
            f"{self.connect_timeout}s"
        )

    def close(self) -> None:
        self._stop.set()
        if self._transport is not None and not self._transport.closed:
            try:
                self._transport.send({"kind": "bye", "unit": self.name})
            except TransportError:
                pass
        thread = self._recv_thread
        if (thread is not None and thread.is_alive()
                and thread is not threading.current_thread()):
            thread.join(timeout=5.0)
        self._recv_thread = None
        if self._transport is not None:
            self._transport.close()
        super().close()

    # -- submission ---------------------------------------------------------
    def submit(self, chunk: Chunk, work_fn: Callable[[Chunk], Any]) -> None:
        if self.lost or self._transport is None or self._transport.closed:
            self._post_lost(chunk, "transport already lost at submit")
            return
        t_submit = time.perf_counter()
        frame = {"kind": "submit", "unit": self.name, "seq": self._seq,
                 "chunk": chunk, "fn": work_fn, "t_submit": t_submit}
        with self._plock:
            self._pending = {
                "seq": self._seq, "frame": frame, "chunk": chunk,
                "t_submit": t_submit, "t_sent": None, "sends": 0,
                "next_resend": 0.0,
            }
            self._seq += 1
        self._transmit_pending()

    def _transmit_pending(self) -> None:
        with self._plock:
            p = self._pending
            if p is None:
                return
            now = time.perf_counter()
            if p["t_sent"] is None:
                p["t_sent"] = now
            p["sends"] += 1
            p["next_resend"] = now + self.retry_interval
            frame = p["frame"]
        try:
            self._transport.send(frame)
        except TransportError:
            self._fail_pending("connection lost while sending a submit")

    # -- the receiver thread -------------------------------------------------
    def _recv_loop(self) -> None:
        tick = max(min(self.retry_interval / 2.0, 0.05), 0.005)
        while not self._stop.is_set():
            try:
                frame = self._transport.recv(timeout=tick)
            except TransportClosed:
                self._fail_pending("connection closed by the worker")
                return
            if frame is not None:
                self._on_frame(frame)
            self._maybe_retransmit()

    def _maybe_retransmit(self) -> None:
        exhausted = False
        due = False
        with self._plock:
            p = self._pending
            if p is not None and time.perf_counter() >= p["next_resend"]:
                if p["sends"] > self.max_retries:
                    exhausted = True
                else:
                    due = True
        if exhausted:
            self._fail_pending(
                f"no completion after {self.max_retries} retransmits"
            )
        elif due:
            self._transmit_pending()

    def _on_frame(self, frame: dict) -> None:
        if frame.get("unit") != self.name:
            return
        if frame.get("kind") == "busy":
            # the worker is alive and executing our pending seq: the
            # retransmit budget bounds unresponsiveness, not work time
            with self._plock:
                p = self._pending
                if p is not None and frame.get("seq") == p["seq"]:
                    p["sends"] = 1
            return
        if frame.get("kind") != "done":
            return
        with self._plock:
            p = self._pending
            if p is None or frame.get("seq") != p["seq"]:
                return  # duplicate/stale completion: drop on the floor
            self._pending = None
        t_start = frame.get("t_start")
        if t_start is None:
            t_start = p["t_sent"]
        self.wire_latencies.append(max(t_start - p["t_sent"], 0.0))
        self.local_queue_latencies.append(max(p["t_sent"] - p["t_submit"], 0.0))
        self._post(CompletionRecord(
            unit=self.name, chunk=p["chunk"],
            elapsed=float(frame.get("elapsed", 0.0)),
            dispatch_latency=max(t_start - p["t_submit"], 0.0),
            error=frame.get("error"), result=frame.get("result"),
        ))

    # -- failure ------------------------------------------------------------
    def _post_lost(self, chunk: Chunk, why: str) -> None:
        self.lost = True
        bus = self._bus
        if bus is not None:
            bus.post(CompletionRecord(
                unit=self.name, chunk=chunk, elapsed=0.0, dispatch_latency=0.0,
                error=WorkerLost(f"unit {self.name!r}: {why}"), result=None,
            ))

    def _fail_pending(self, why: str) -> None:
        with self._plock:
            p, self._pending = self._pending, None
        self.lost = True
        self._stop.set()
        if p is not None:
            self._post_lost(p["chunk"], why)

    def describe(self) -> str:
        where = self.address if self.address is not None else "injected transport"
        return f"RemoteUnit({self.name!r} @ {where})"


# ---------------------------------------------------------------------------
# transportable work helpers
# ---------------------------------------------------------------------------
class SleepWork:
    """Per-item sleep work that survives the pickling transport.

    Work functions sent to a :class:`SocketTransport` worker unpickle *by
    module reference* on the far side, so they cannot live in a script's
    ``__main__`` (the worker has a different ``__main__``).  Benchmarks
    that model compute with calibrated sleeps import this instead.
    """

    def __init__(self, seconds_per_item: float) -> None:
        self.seconds_per_item = float(seconds_per_item)

    def __call__(self, chunk) -> None:
        time.sleep(chunk.size * self.seconds_per_item)


# ---------------------------------------------------------------------------
# worker subprocesses
# ---------------------------------------------------------------------------
_BANNER = "ENEAC_WORKER"


class WorkerHandle:
    """A spawned worker subprocess: its address and its lifetime."""

    def __init__(self, proc: subprocess.Popen, address: str) -> None:
        self.proc = proc
        self.address = address

    def terminate(self, timeout: float = 10.0) -> None:
        if self.proc.poll() is None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                self.kill()

    def kill(self) -> None:
        if self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait()

    @property
    def alive(self) -> bool:
        return self.proc.poll() is None

    def __enter__(self) -> "WorkerHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.terminate()


def spawn_worker(*, host: str = "127.0.0.1",
                 startup_timeout: float = 60.0) -> WorkerHandle:
    """Launch ``python -m repro.core.transport`` and wait for its address.

    The subprocess prints ``ENEAC_WORKER <host:port>`` once its listener
    is bound; this parses that line (with a timeout, so a worker that
    dies on import fails fast instead of hanging the caller) and returns
    a handle whose ``address`` plugs straight into
    ``register_unit(backend=f"remote:{handle.address}")``.

    The worker inherits the parent's ``sys.path``, because submitted
    work functions unpickle by module reference on the far side — the
    worker must be able to import whatever module defines them (test
    modules included).
    """
    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    paths = [src_dir] + [p for p in sys.path if p and p != src_dir]
    env["PYTHONPATH"] = os.pathsep.join(paths)
    env.setdefault("JAX_PLATFORMS", "cpu")
    entry = ("import sys; from repro.core.transport import _main; "
             "sys.exit(_main(sys.argv[1:]))")
    proc = subprocess.Popen(
        [sys.executable, "-c", entry, "--host", host, "--port", "0"],
        stdout=subprocess.PIPE, text=True, env=env,
    )
    deadline = time.monotonic() + startup_timeout
    line = ""
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(
                f"worker subprocess exited with {proc.returncode} before "
                "announcing its address"
            )
        ready, _, _ = select.select([proc.stdout], [], [], 0.25)
        if not ready:
            continue
        line = proc.stdout.readline()
        if line.startswith(_BANNER):
            return WorkerHandle(proc, line.split()[1].strip())
        if not line:  # EOF without banner
            break
    proc.kill()
    raise RuntimeError(
        f"worker subprocess did not announce an address within "
        f"{startup_timeout}s (last line: {line!r})"
    )


def _main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Serve ENEAC remote backend units over TCP")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (printed on stdout)")
    args = ap.parse_args(argv)
    server = WorkerServer(args.host, args.port)
    print(f"{_BANNER} {server.address}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(_main())

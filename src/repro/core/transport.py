"""Multi-host transport backends: one runtime driving remote shard engines.

The ENEAC loop so far kept every compute unit in the dispatcher's address
space — threads, process pools, device streams.  This module stretches
the :class:`~repro.core.backends.BackendUnit` boundary across a *message
transport*, the way HEROv2 (arXiv:2201.03861) stretches the host↔PULP
offload path across a real interconnect, while keeping dispatch latency
observable end-to-end (HTS, arXiv:1907.00271):

* **Frame codec** — length-prefixed pickled frames
  (:func:`encode_frame`, :class:`FrameDecoder`): a 4-byte big-endian
  payload length followed by the pickled frame dict.
* :class:`Transport` — the message boundary: ``send(frame)`` /
  ``recv(timeout)`` / ``close()``.  Two real implementations:
  :class:`LoopbackTransport` (an in-process queue pair that passes frames
  by reference — the deterministic test medium) and
  :class:`SocketTransport` (localhost/LAN TCP with the length-prefixed
  pickle codec).  :class:`FlakyTransport` wraps either with seeded
  drop / delay / duplicate / reorder fault injection — the first place in
  this repo where a completion can be lost by the *medium* instead of the
  code, which is why the reliability protocol below exists.
* :class:`RemoteWorker` — the far side: a serve loop that hosts real
  backend units (thread / inline / process / jax) behind one transport
  session, executes submitted chunks on them, and pumps their
  completions back as frames.  :class:`WorkerServer` accepts TCP
  connections and runs one :class:`RemoteWorker` per connection;
  ``python -m repro.core.transport`` serves one from a fresh process and
  :func:`spawn_worker` launches that as a managed subprocess.
* :class:`RemoteUnit` — the near side: a
  :class:`~repro.core.backends.BackendUnit` proxy that makes a remote
  worker look like any other unit.  ``submit(chunk, work_fn)`` forwards a
  frame without blocking; a receiver thread pumps ``done`` frames back
  onto the run's :class:`~repro.core.backends.CompletionBus`; dispatch
  latency is split into its local-queue and wire components
  (``RunReport.wire_latency``).

Reliability protocol (what makes the FlakyTransport battery pass):

* every submit carries a per-unit monotonically increasing ``seq``; the
  engine guarantees one chunk in flight per unit, so the proxy
  retransmits the pending frame on a timer until its completion arrives;
* the worker executes a seq **at most once**: duplicates of an already
  accepted seq re-send the cached ``done`` frame, or answer ``busy``
  while it is still executing — so dropped/duplicated/reordered frames
  never duplicate work-function side effects, and the retransmit budget
  measures worker *silence* rather than execution time (a chunk may
  legitimately run for minutes);
* the proxy ignores ``done`` frames whose seq is not the pending one, so
  duplicated completions are dropped on the floor;
* a definitive connection loss (EOF) or retransmit exhaustion posts a
  :class:`~repro.core.backends.WorkerLost` completion, which
  :class:`~repro.core.backends.BackendEngine` answers by removing the
  unit and requeueing its in-flight chunk to the survivors exactly once
  (an ``action="lost"`` event in ``RunReport.events``).

Failure semantics, stated honestly: when only *frames* are lost the
protocol preserves exact-once execution.  When the **worker itself** is
lost, a chunk it had already executed (whose completion never arrived)
is requeued and re-executed by a survivor — results stay correct because
the dead worker's results never surfaced, but external side effects need
an idempotent sink (e.g. write-per-index files, not appends).  This is
the standard at-least-once boundary of any distributed work queue; the
tests pin both halves of the contract.
"""

from __future__ import annotations

import argparse
import hashlib
import logging
import math
import os
import pickle
import queue
import random
import select
import socket
import struct
import subprocess
import sys
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from .backends import (
    BackendUnit,
    CompletionBus,
    CompletionRecord,
    WorkerDead,
    WorkerLost,
    make_backend,
)
from .scheduler import Chunk

logger = logging.getLogger(__name__)

__all__ = [
    "Transport",
    "TransportError",
    "TransportClosed",
    "LoopbackTransport",
    "SocketTransport",
    "FlakyTransport",
    "RemoteWorker",
    "WorkerServer",
    "RemoteUnit",
    "AUTO_BATCH_MAX",
    "SleepWork",
    "WorkerHandle",
    "spawn_worker",
    "encode_frame",
    "FrameDecoder",
    "MAX_FRAME_BYTES",
]


class TransportError(ConnectionError):
    """The transport failed to carry a frame (protocol or session error)."""


class TransportClosed(TransportError):
    """The transport is closed (locally or by the peer) — definitive EOF."""


# ---------------------------------------------------------------------------
# frame codec: length-prefixed pickled frames
# ---------------------------------------------------------------------------
_HEADER = struct.Struct(">I")
MAX_FRAME_BYTES = 256 * 1024 * 1024  # refuse absurd lengths (corrupt header)


def encode_frame(frame: dict) -> bytes:
    """``frame`` -> 4-byte big-endian payload length + pickled payload."""
    payload = pickle.dumps(frame, protocol=pickle.HIGHEST_PROTOCOL)
    if len(payload) > MAX_FRAME_BYTES:
        raise TransportError(
            f"frame of {len(payload)} bytes exceeds MAX_FRAME_BYTES"
        )
    return _HEADER.pack(len(payload)) + payload


class FrameDecoder:
    """Incremental decoder: feed byte chunks, get complete frames out.

    TCP delivers a byte stream, not messages; the decoder buffers partial
    frames across ``feed`` calls and yields each frame exactly once, in
    order, no matter how the stream was segmented.
    """

    def __init__(self) -> None:
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[dict]:
        self._buf += data
        out: List[dict] = []
        while True:
            if len(self._buf) < _HEADER.size:
                break
            (n,) = _HEADER.unpack_from(self._buf)
            if n > MAX_FRAME_BYTES:
                raise TransportError(
                    f"frame header claims {n} bytes (> {MAX_FRAME_BYTES}); "
                    "stream is corrupt"
                )
            if len(self._buf) < _HEADER.size + n:
                break
            payload = bytes(self._buf[_HEADER.size:_HEADER.size + n])
            del self._buf[:_HEADER.size + n]
            try:
                out.append(pickle.loads(payload))
            except Exception as exc:
                # The length prefix kept the stream aligned, so a payload
                # that cannot unpickle here (e.g. a work_fn whose module
                # the peer cannot import) is dropped as a poison frame —
                # the retransmit/WorkerLost protocol turns it into a
                # requeue instead of a dead session thread.
                out.append({"kind": "undecodable", "message": repr(exc)})
        return out


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------
class Transport:
    """Message boundary between a :class:`RemoteUnit` and its worker.

    ``send`` must be safe to call from multiple threads; ``recv`` is only
    ever called from one receiver thread.  ``recv`` returns ``None`` on
    timeout and raises :class:`TransportClosed` on definitive EOF.
    """

    def send(self, frame: dict) -> None:
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None) -> Optional[dict]:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    @property
    def closed(self) -> bool:
        raise NotImplementedError


_EOF = object()


class LoopbackTransport(Transport):
    """In-process transport: a queue pair passing frames *by reference*.

    The deterministic test medium: no sockets, no pickling — which is
    deliberate, because by-reference delivery is what lets in-process
    tests share a side-effect ledger with the "remote" worker and assert
    exact-once semantics directly.  (Message-level fidelity — everything
    must survive pickling — is :class:`SocketTransport`'s job.)
    """

    def __init__(self) -> None:
        self._inbox: "queue.Queue" = queue.Queue()
        self._peer: Optional["LoopbackTransport"] = None
        self._closed = False

    @classmethod
    def pair(cls) -> Tuple["LoopbackTransport", "LoopbackTransport"]:
        a, b = cls(), cls()
        a._peer, b._peer = b, a
        return a, b

    def send(self, frame: dict) -> None:
        peer = self._peer
        if self._closed or peer is None or peer._closed:
            raise TransportClosed("loopback endpoint closed")
        peer._inbox.put(frame)

    def recv(self, timeout: Optional[float] = None) -> Optional[dict]:
        if self._closed:
            raise TransportClosed("loopback endpoint closed")
        try:
            item = self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None
        if item is _EOF:
            self._inbox.put(_EOF)  # later recvs see EOF too
            raise TransportClosed("peer closed the loopback")
        return item

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._inbox.put(_EOF)
        if self._peer is not None:
            self._peer._inbox.put(_EOF)

    @property
    def closed(self) -> bool:
        return self._closed


def parse_address(address: str) -> Tuple[str, int]:
    """``"host:port"`` -> ``(host, port)`` (last colon splits the port)."""
    host, sep, port = str(address).rpartition(":")
    if not sep or not host or not port.isdigit():
        raise ValueError(f"address must be 'host:port', got {address!r}")
    return host, int(port)


class SocketTransport(Transport):
    """Length-prefixed pickled frames over a stream socket (TCP or UNIX)."""

    def __init__(self, sock: socket.socket) -> None:
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass  # AF_UNIX / socketpair: no Nagle to disable
        self._sock = sock
        self._decoder = FrameDecoder()
        self._ready: deque = deque()
        self._send_lock = threading.Lock()
        self._closed = False

    @classmethod
    def connect(cls, address: str, timeout: float = 10.0) -> "SocketTransport":
        host, port = parse_address(address)
        sock = socket.create_connection((host, port), timeout=timeout)
        sock.settimeout(None)
        return cls(sock)

    def send(self, frame: dict) -> None:
        data = encode_frame(frame)  # pickling errors surface to the caller
        with self._send_lock:
            if self._closed:
                raise TransportClosed("socket transport closed")
            try:
                self._sock.sendall(data)
            except OSError as exc:
                self._closed = True
                raise TransportClosed(f"send failed: {exc}") from exc

    def recv(self, timeout: Optional[float] = None) -> Optional[dict]:
        deadline = None if timeout is None else time.perf_counter() + timeout
        while True:
            if self._ready:
                return self._ready.popleft()
            if self._closed:
                raise TransportClosed("socket transport closed")
            remaining: Optional[float] = None
            if deadline is not None:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    return None
            try:
                self._sock.settimeout(remaining)
                data = self._sock.recv(1 << 16)
            except socket.timeout:
                return None
            except OSError as exc:
                self._closed = True
                raise TransportClosed(f"recv failed: {exc}") from exc
            if not data:
                self._closed = True
                raise TransportClosed("peer closed the connection")
            self._ready.extend(self._decoder.feed(data))

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass

    @property
    def closed(self) -> bool:
        return self._closed


class FlakyTransport(Transport):
    """Seeded fault injection on the send path of any transport.

    Each sent frame independently draws from the seeded RNG: it may be
    **dropped** (never delivered), **duplicated** (delivered twice),
    **held for reordering** (delivered after the *next* frame), or
    **delayed** (delivered up to ``max_delay`` seconds late from a timer
    thread).  Receives pass straight through — wrap both endpoints to
    fault both directions.  Faults never raise: a frame racing a closing
    transport is just another drop, which the reliability protocol must
    absorb anyway.

    ``kinds`` restricts injection to frames of the named kinds (e.g.
    ``kinds=("heartbeat",)`` faults the liveness signal while work and
    completion frames ride a clean medium) — the lever the
    heartbeat-loss-vs-merely-slow battery needs to prove that a lossy
    heartbeat path alone never convicts a live worker.
    """

    def __init__(
        self,
        inner: Transport,
        *,
        seed: int,
        drop: float = 0.0,
        duplicate: float = 0.0,
        reorder: float = 0.0,
        delay: float = 0.0,
        max_delay: float = 0.02,
        kinds: Optional[Tuple[str, ...]] = None,
    ) -> None:
        self.inner = inner
        self.kinds = tuple(kinds) if kinds is not None else None
        self.drop = float(drop)
        self.duplicate = float(duplicate)
        self.reorder = float(reorder)
        self.delay = float(delay)
        self.max_delay = float(max_delay)
        self._rng = random.Random(seed)
        self._held: Optional[dict] = None
        self._lock = threading.Lock()
        self.stats = {"sent": 0, "dropped": 0, "duplicated": 0,
                      "reordered": 0, "delayed": 0}

    def _deliver(self, frame: dict) -> None:
        try:
            self.inner.send(frame)
        except TransportError:
            pass  # racing a close: equivalent to a drop

    def send(self, frame: dict) -> None:
        if self.kinds is not None and frame.get("kind") not in self.kinds:
            self._deliver(frame)  # out-of-scope kinds ride a clean medium
            return
        with self._lock:
            self.stats["sent"] += 1
            if self._rng.random() < self.drop:
                self.stats["dropped"] += 1
                return
            dup = self._rng.random() < self.duplicate
            hold = self._rng.random() < self.reorder
            delay_s = (
                self._rng.uniform(0.0, self.max_delay)
                if self._rng.random() < self.delay else 0.0
            )
            to_send: List[dict] = []
            if hold:
                self.stats["reordered"] += 1
                held, self._held = self._held, frame
                if held is not None:
                    to_send.append(held)  # an older frame jumps the queue
            else:
                to_send.append(frame)
                held, self._held = self._held, None
                if held is not None:
                    to_send.append(held)  # delivered after its successor
                if dup:
                    self.stats["duplicated"] += 1
                    to_send.append(frame)
        for f in to_send:
            if delay_s > 0.0:
                self.stats["delayed"] += 1
                timer = threading.Timer(delay_s, self._deliver, args=(f,))
                timer.daemon = True
                timer.start()
            else:
                self._deliver(f)

    def recv(self, timeout: Optional[float] = None) -> Optional[dict]:
        return self.inner.recv(timeout)

    def close(self) -> None:
        self.inner.close()  # a still-held frame dies with the session

    @property
    def closed(self) -> bool:
        return self.inner.closed


# ---------------------------------------------------------------------------
# the far side: a worker hosting real backend units
# ---------------------------------------------------------------------------
_DONE_CACHE_DEPTH = 32  # completion items kept per unit for dup-resend
_HOSTABLE = ("thread", "threads", "inline", "process", "processes", "jax")


class RemoteWorker:
    """Serve one transport session: host backend units, execute, report.

    Frames handled:

    * ``hello {unit, backend, heartbeat?}`` — start hosting a backend
      unit for ``unit`` (idempotent: duplicates re-ack with ``ready``);
      a bad backend spec answers with an ``error`` frame instead.  A
      positive ``heartbeat`` interval subscribes the client to periodic
      ``heartbeat {unit, queue_depth, inflight}`` frames — the ``busy``
      liveness answer generalized from "this seq is executing" to "this
      unit is alive", carrying the worker's accepted-but-uncompleted
      chunk count so the client can drive membership and autoscaling
      decisions from observed depth.  No request → no heartbeat frames
      (the legacy wire shape, exactly).
    * ``register_fn {unit, fn_id, fn}`` — the dispatch fast path's
      descriptor cache: store ``fn`` in the session registry so later
      work items can reference it by ``fn_id`` instead of re-shipping
      the pickled callable per chunk.  Idempotent; registry is
      per-session, so a worker restart naturally empties it.
    * ``submit {unit, seq, chunk, fn|fn_ref, t_submit, floor}`` — execute
      one chunk, **at most once per seq**: duplicates of an accepted seq
      re-send the cached ``done`` item, or answer ``busy`` while that seq
      is still executing (the client's liveness signal for long-running
      chunks), so retransmits and transport duplicates never duplicate
      side effects.  A ``fn_ref`` that is not in the registry (lost or
      never-sent registration, worker restart) answers ``unknown_fn`` —
      the client re-registers and retransmits.
    * ``work_batch {unit, floor, items: [{seq, chunk, fn|fn_ref,
      t_submit}, ...]}`` — several chunks in one frame (the client's
      ``batch_frames`` coalescing); each item is accepted/deduped
      independently under the same seq protocol, and ``floor`` (the
      client's lowest still-pending seq) prunes the accepted-seq set and
      the done cache.  With batching the client may have several frames
      racing, so acceptance is an exact per-seq set — a reordered older
      frame is still accepted after a newer one, and only seqs below
      ``floor`` (completions the client already processed) are dropped
      as stale.
    * ``bye {unit}`` — graceful drain: stop hosting the unit (its
      in-flight chunks complete first; thread/pool shutdown waits).
    * ``shutdown`` — end the serve loop.

    Completions drain through one pump pass per bus wakeup: all finished
    chunks of a unit found in one drain are posted as a single
    ``done_batch`` frame (a lone completion keeps the legacy ``done``
    shape), each item carrying ``t_accept`` (frame arrival) and
    ``t_start`` (execution start) so the client can attribute the wire
    transit per chunk without double counting.

    All timestamps are ``time.perf_counter()`` — CLOCK_MONOTONIC, which
    on Linux is shared by every process on one machine, so worker-side
    execution-start times compose with client-side submit times into one
    dispatch-latency measurement across *local* processes (same trick
    :class:`ProcessPoolUnit` uses).  Across machines the two clocks have
    unrelated epochs: execution/coverage semantics are unaffected, but
    the reported latency split is only meaningful when client and worker
    share a host (the supported benchmark/test topology).
    """

    def __init__(self, transport: Transport, *, poll_interval: float = 0.2) -> None:
        self.transport = transport
        self.poll_interval = poll_interval
        self.bus = CompletionBus()
        self._units: Dict[str, BackendUnit] = {}
        self._fns: Dict[str, Callable] = {}            # session fn registry
        self._accepted: Dict[str, set] = {}            # unit -> accepted seqs
        self._floor: Dict[str, int] = {}               # unit -> client floor
        # unit -> seq -> (t_accept, chunk), insertion-ordered
        self._inflight: Dict[str, "OrderedDict[int, Tuple[float, Chunk]]"] = {}
        self._done_cache: Dict[str, "OrderedDict[int, dict]"] = {}
        self._hb_interval: Dict[str, float] = {}   # unit -> requested secs
        self._hb_next: Dict[str, float] = {}       # unit -> next beat due
        self._beater: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._stop = threading.Event()

    # -- outbound ------------------------------------------------------------
    @staticmethod
    def _strip(frame: dict, reason: Exception) -> dict:
        err = TransportError(f"completion payload not transportable: {reason}")
        if "items" in frame:
            return {**frame, "items": [
                {**item, "result": None, "error": err}
                for item in frame["items"]]}
        return {**frame, "result": None, "error": err}

    def _send(self, frame: dict) -> None:
        try:
            self.transport.send(frame)
            return
        except TransportClosed:
            self._stop.set()
            return
        except Exception as exc:
            # an untransportable payload (unpicklable result/error, or a
            # frame over MAX_FRAME_BYTES): strip it and keep the protocol
            # alive so the client gets an explanatory error instead of a
            # retransmit-exhaustion "lost worker"
            reason = exc
        try:
            self.transport.send(self._strip(frame, reason))
        except Exception as exc:
            # Not just TransportError: *any* failure here (a send-path bug,
            # an OSError the transport did not wrap) used to propagate into
            # the pump thread and kill it silently — the client would see a
            # stall and burn its whole retransmit budget.  The session is
            # unrecoverable either way, so end it deliberately: the client
            # gets a definitive EOF (WorkerLost → exact-once requeue)
            # instead of silence.
            logger.warning(
                "worker session send failed twice (%r after strip %r); "
                "ending session", exc, reason,
            )
            self._stop.set()

    # -- inbound -------------------------------------------------------------
    def _handle_hello(self, frame: dict) -> None:
        name = frame.get("unit")
        spec = frame.get("backend") or "thread"
        if name not in self._units:
            if not isinstance(spec, str) or spec not in _HOSTABLE:
                self._send({"kind": "error", "unit": name,
                            "message": f"worker cannot host backend {spec!r} "
                                       f"(want one of {_HOSTABLE})"})
                return
            unit = make_backend(spec, name)
            unit.start(self.bus)
            with self._lock:
                self._units[name] = unit
                self._accepted[name] = set()
                self._floor[name] = 0
                self._inflight[name] = OrderedDict()
                self._done_cache[name] = OrderedDict()
        hb = frame.get("heartbeat")
        if isinstance(hb, (int, float)) and hb > 0:
            start_beater = False
            with self._lock:
                self._hb_interval[name] = float(hb)
                self._hb_next[name] = 0.0  # first beat right after ready
                if self._beater is None or not self._beater.is_alive():
                    self._beater = threading.Thread(
                        target=self._beat_loop, daemon=True,
                        name="eneac-worker-beat",
                    )
                    start_beater = True
            if start_beater:
                self._beater.start()
        self._send({"kind": "ready", "unit": name})

    def _handle_register(self, frame: dict) -> None:
        fn_id, fn = frame.get("fn_id"), frame.get("fn")
        if fn_id is not None and fn is not None:
            with self._lock:
                self._fns[fn_id] = fn

    def _handle_work(self, frame: dict) -> None:
        """Accept the work items of a ``submit`` or ``work_batch`` frame."""
        name = frame.get("unit")
        items = frame.get("items") if frame.get("kind") == "work_batch" else [frame]
        t_accept = time.perf_counter()
        replies: List[dict] = []
        resend_items: List[dict] = []
        to_exec: List[Tuple[Chunk, Callable]] = []
        with self._lock:
            unit = self._units.get(name)
            if unit is None:
                return  # work raced ahead of hello; retransmit will return
            floor = frame.get("floor")
            if isinstance(floor, int) and floor > self._floor[name]:
                self._floor[name] = floor
                accepted = self._accepted[name]
                accepted -= {s for s in accepted if s < floor}
                cache = self._done_cache[name]
                for seq in [s for s in cache if s < floor]:
                    del cache[seq]
            for item in items or ():
                seq = item.get("seq")
                if seq is None or seq < self._floor[name]:
                    continue  # stale: the client already moved past it
                if seq in self._accepted[name]:
                    cached = self._done_cache[name].get(seq)
                    if cached is not None:
                        resend_items.append(cached)  # lost done: resend
                    elif seq in self._inflight[name]:
                        # still executing: answer the probe so the client's
                        # retransmit budget measures *silence*, not work
                        replies.append({"kind": "busy", "unit": name,
                                        "seq": seq})
                    # else: completed and pruned — drop
                    continue
                if "fn" in item:
                    fn = item["fn"]
                else:
                    fn = self._fns.get(item.get("fn_ref"))
                    if fn is None:
                        # registration lost or pre-restart: NACK so the
                        # client re-registers and retransmits this seq
                        replies.append({"kind": "unknown_fn", "unit": name,
                                        "seq": seq,
                                        "fn_id": item.get("fn_ref")})
                        continue
                self._accepted[name].add(seq)
                self._inflight[name][seq] = (t_accept, item["chunk"])
                to_exec.append((item["chunk"], fn))
        if resend_items:
            if len(resend_items) == 1:
                self._send({"kind": "done", "unit": name, **resend_items[0]})
            else:
                self._send({"kind": "done_batch", "unit": name,
                            "items": resend_items})
        for reply in replies:
            self._send(reply)
        for chunk, fn in to_exec:
            unit.submit(chunk, fn)

    def _handle_bye(self, frame: dict) -> None:
        name = frame.get("unit")
        with self._lock:
            unit = self._units.pop(name, None)
            self._accepted.pop(name, None)
            self._floor.pop(name, None)
            self._inflight.pop(name, None)
            self._done_cache.pop(name, None)
            self._hb_interval.pop(name, None)
            self._hb_next.pop(name, None)
        if unit is not None:
            unit.close()  # waits for in-flight chunks (graceful drain)

    def _beat_loop(self) -> None:
        """Send each subscribed unit's periodic ``heartbeat`` frame.

        A dedicated timer thread (not the completion pump — the pump
        sleeps up to ``poll_interval`` per wakeup, which would starve
        intervals tighter than that).  ``queue_depth`` is the worker's
        accepted-but-uncompleted chunk count for the unit; ``inflight``
        is the slice of that depth the unit's backend can actually be
        executing right now (capped by its capacity).  Exits when the
        last subscription is dropped; a later ``hello`` restarts it.
        """
        while not self._stop.is_set():
            beats: List[dict] = []
            now = time.perf_counter()
            with self._lock:
                if not self._hb_interval:
                    return
                shortest = min(self._hb_interval.values())
                for name, interval in self._hb_interval.items():
                    if now < self._hb_next.get(name, 0.0):
                        continue
                    self._hb_next[name] = now + interval
                    depth = len(self._inflight.get(name, ()))
                    unit = self._units.get(name)
                    cap = max(int(getattr(unit, "capacity", 1) or 1), 1)
                    beats.append({"kind": "heartbeat", "unit": name,
                                  "queue_depth": depth,
                                  "inflight": min(depth, cap)})
            for beat in beats:
                self._send(beat)
            self._stop.wait(timeout=shortest / 2.0)

    def _pump(self) -> None:
        """Forward hosted-unit completions, one frame per unit per drain.

        Several completions of the same unit found in one drain coalesce
        into a single ``done_batch`` frame — the worker-side half of the
        frame-batching fast path; a lone completion keeps the legacy
        ``done`` frame shape.

        The loop body is exception-proof: every completion is inserted
        into the done cache *before* its frame is sent, so if anything
        here throws, the item is recoverable — the client's retransmit
        of the still-pending seq hits the dedup path and re-sends the
        cached ``done``.  An uncaught exception must therefore never
        kill this thread (the old behavior: a dead pump looked exactly
        like a stalled worker until the client burned its whole
        retransmit budget); it is logged and the pump keeps draining.
        """
        while not self._stop.is_set():
            try:
                self._pump_once()
            except Exception as exc:
                logger.warning(
                    "worker completion pump error (%r); completions remain "
                    "recoverable from the done cache via retransmit", exc,
                )

    def _pump_once(self) -> None:
        """One bus wait + drain + send pass (see :meth:`_pump`)."""
        self.bus.wait(timeout=self.poll_interval)
        grouped: "OrderedDict[str, List[dict]]" = OrderedDict()
        for rec in self.bus.drain():
            with self._lock:
                pend = self._inflight.get(rec.unit)
                entry = None
                if pend:
                    for seq, (t_accept, chunk) in pend.items():
                        if (chunk.start, chunk.stop) == (rec.chunk.start,
                                                         rec.chunk.stop):
                            entry = (seq, t_accept)
                            del pend[seq]
                            break
                if entry is None:
                    continue  # completion of a bye'd unit's last chunk
                seq, t_accept = entry
                item = {
                    "seq": seq, "chunk": rec.chunk,
                    "elapsed": rec.elapsed, "t_accept": t_accept,
                    "t_start": t_accept + rec.dispatch_latency,
                    "error": rec.error, "result": rec.result,
                }
                cache = self._done_cache.get(rec.unit)
                if cache is not None:
                    cache[seq] = item
                    while len(cache) > _DONE_CACHE_DEPTH:
                        cache.popitem(last=False)
            grouped.setdefault(rec.unit, []).append(item)
        for name, items in grouped.items():
            if len(items) == 1:
                self._send({"kind": "done", "unit": name, **items[0]})
            else:
                self._send({"kind": "done_batch", "unit": name,
                            "items": items})

    # -- the loop ------------------------------------------------------------
    def serve(self) -> None:
        """Blocking serve loop; returns when the session ends."""
        pump = threading.Thread(target=self._pump, daemon=True,
                                name="eneac-worker-pump")
        pump.start()
        try:
            while not self._stop.is_set():
                try:
                    frame = self.transport.recv(timeout=self.poll_interval)
                except TransportClosed:
                    break
                if frame is None:
                    continue
                kind = frame.get("kind")
                if kind == "hello":
                    self._handle_hello(frame)
                elif kind in ("submit", "work_batch"):
                    self._handle_work(frame)
                elif kind == "register_fn":
                    self._handle_register(frame)
                elif kind == "bye":
                    self._handle_bye(frame)
                elif kind == "shutdown":
                    break
                # unknown kinds are ignored (forward compatibility)
        finally:
            self._stop.set()
            pump.join(timeout=10.0)
            beater = self._beater
            if beater is not None:
                beater.join(timeout=5.0)
            with self._lock:
                units, self._units = dict(self._units), {}
            for name, unit in units.items():
                try:
                    unit.close()
                except Exception as exc:
                    # shutdown is best-effort, but a failed close is a
                    # leaked backend (threads, subprocesses) — say so
                    logger.warning(
                        "failed to close hosted unit %r at session end: %r",
                        name, exc,
                    )
            self.transport.close()

    def stop(self) -> None:
        self._stop.set()


class WorkerServer:
    """TCP front door: one :class:`RemoteWorker` session per connection."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0) -> None:
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self.host = host
        self.port = self._sock.getsockname()[1]
        self.address = f"{self.host}:{self.port}"
        self._stop = threading.Event()
        self._workers: List[RemoteWorker] = []
        self._threads: List[threading.Thread] = []
        self._accept_thread: Optional[threading.Thread] = None

    def serve_forever(self) -> None:
        self._sock.settimeout(0.25)
        while not self._stop.is_set():
            try:
                conn, _addr = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            worker = RemoteWorker(SocketTransport(conn))
            t = threading.Thread(target=worker.serve, daemon=True,
                                 name=f"eneac-worker-conn{len(self._threads)}")
            t.start()
            self._workers.append(worker)
            self._threads.append(t)

    def start(self) -> "WorkerServer":
        """Serve from a daemon thread (in-process test servers)."""
        self._accept_thread = threading.Thread(
            target=self.serve_forever, daemon=True, name="eneac-worker-accept"
        )
        self._accept_thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        for worker in self._workers:
            worker.stop()
        for t in self._threads:
            t.join(timeout=5.0)
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)


# ---------------------------------------------------------------------------
# the near side: the proxy unit
# ---------------------------------------------------------------------------
# Adaptive frame-batching bounds: "auto" never shrinks the wire shape
# below the legacy one-chunk frame and never coalesces more chunks than
# this into a single frame (a lost frame costs one retransmit of
# everything on it, so unbounded batches would magnify fault recovery).
AUTO_BATCH_MAX = 32
_AUTO_BATCH_ALPHA = 0.4


class RemoteUnit(BackendUnit):
    """A :class:`BackendUnit` whose execution happens behind a transport.

    Construct with either ``address="host:port"`` (connects a
    :class:`SocketTransport` at ``start``; reconnects on restart) or an
    already-connected ``transport=`` endpoint (loopback tests; single
    session).  ``remote_backend`` names the backend the worker hosts for
    this unit ("thread" by default).

    Dispatch fast path knobs:

    * ``fn_cache`` (default on) — the session descriptor cache: each
      distinct work function is shipped **once** via a ``register_fn``
      frame and referenced by a content-hash id in every work item
      after that, instead of re-pickling the whole callable per chunk.
      A changed function hashes differently and re-registers; an
      unpicklable one (loopback lambdas) falls back to an identity-based
      id, still by-reference-safe.  If the worker does not know the id
      (dropped registration, worker restart → new session), it answers
      ``unknown_fn`` and the client re-registers and retransmits — the
      seq/dedup exact-once invariant is preserved because the work item
      itself was never accepted.
    * ``batch_frames`` (default 1) — coalesce up to this many queued
      chunks into one ``work_batch`` frame, amortizing the per-frame
      wire cost.  The unit advertises ``capacity = batch_frames`` so the
      engine pipelines that many chunks; scheduler-visible granularity
      and per-chunk completion accounting are unchanged, and
      ``batch_frames=1`` keeps the legacy one-``submit``-per-chunk wire
      shape exactly.  ``batch_frames="auto"`` sizes the width adaptively
      from what the unit learns on the wire: an EWMA of raw frame
      transit time (send → worker accept, the cost one frame pays
      regardless of how many chunks ride it) against an EWMA of
      per-chunk service time, so a high-latency link grows the batch
      until the wire cost is amortized below one chunk's work.  The
      width starts at 1 (legacy shape), is re-evaluated at every flush
      boundary, and is clamped to ``[1, AUTO_BATCH_MAX]``; the converged
      value is surfaced per unit as ``RunReport.batch_frames``.

    ``submit`` is non-blocking: it buffers the chunk (sending
    immediately when a batch fills or :meth:`flush` is called); the
    receiver thread retransmits all still-pending work every
    ``retry_interval`` seconds until each ``done`` arrives (the worker
    dedups by exact seq set, so retransmits are safe), posts completions
    to the run's bus, and records the dispatch-latency split —

    * ``dispatch_latencies``: submit → remote execution start (total),
    * ``local_queue_latencies``: submit → first socket write,
    * ``wire_latencies``: first write → remote execution start, with the
      frame's transit time attributed **per chunk** (divided by the
      number of chunks that shared the frame) so a batched frame's wire
      time is never double-counted; surfaced as
      ``RunReport.wire_latency``.

    The split subtracts worker-side from client-side ``perf_counter``
    readings, so it is meaningful when both share a machine (subprocess
    workers — the supported topology); a cross-machine worker skews the
    latency numbers by the clock-epoch offset without affecting
    execution or coverage semantics.

    Definitive EOF, a failed send, or ``max_retries`` unanswered
    retransmits post a :class:`~repro.core.backends.WorkerLost`
    completion instead — the engine's signal to requeue the in-flight
    chunks and drop this unit from the run.

    Heartbeat liveness (fleet membership): ``heartbeat=SECS`` subscribes
    to periodic worker ``heartbeat`` frames (requested via the ``hello``
    handshake) and arms missed-heartbeat conviction — if *nothing* is
    heard from the worker (heartbeats, completions, busy answers; any
    frame proves the process is alive) for ``patience`` consecutive
    intervals, the unit posts a
    :class:`~repro.core.backends.WorkerDead` completion: the engine
    retires it through the elastic path (``action="dead"``) without
    waiting for a retransmit budget to burn down mid-chunk — and, unlike
    the retransmit path, an *idle* unit's death is detected too, which
    is what lets a :class:`~repro.core.fleet.FleetManager` convict
    members between runs.  Conviction is patience-gated exactly like
    :class:`~repro.core.straggler.StragglerDetector`: one late beat is
    not a verdict, only sustained silence is.  The most recent heartbeat
    payload is kept in :attr:`last_heartbeat` (``queue_depth`` /
    ``inflight``) for membership and autoscaling observers.
    """

    kind_name = "remote"

    def __init__(
        self,
        name: str,
        address: Optional[str] = None,
        *,
        transport: Optional[Transport] = None,
        remote_backend: str = "thread",
        retry_interval: float = 0.1,
        max_retries: int = 100,
        connect_timeout: float = 10.0,
        batch_frames: Union[int, str] = 1,
        fn_cache: bool = True,
        heartbeat: Optional[float] = None,
        patience: int = 3,
    ) -> None:
        super().__init__(name)
        if (address is None) == (transport is None):
            raise ValueError("pass exactly one of address= or transport=")
        if remote_backend not in _HOSTABLE:
            raise ValueError(
                f"remote_backend must be one of {_HOSTABLE}, "
                f"got {remote_backend!r} (no proxy chains)"
            )
        if heartbeat is not None and not float(heartbeat) > 0:
            raise ValueError(f"heartbeat must be positive, got {heartbeat!r}")
        if int(patience) < 1:
            raise ValueError(f"patience must be >= 1, got {patience}")
        self.auto_batch = batch_frames == "auto"
        if self.auto_batch:
            self._batch = 1  # legacy wire shape until the link is measured
        else:
            if isinstance(batch_frames, str):
                raise ValueError(
                    f"batch_frames must be an int >= 1 or 'auto', "
                    f"got {batch_frames!r}"
                )
            if int(batch_frames) < 1:
                raise ValueError(f"batch_frames must be >= 1, got {batch_frames}")
            self._batch = int(batch_frames)
        self.address = address
        self.remote_backend = remote_backend
        self.retry_interval = float(retry_interval)
        self.max_retries = int(max_retries)
        self.connect_timeout = float(connect_timeout)
        self.fn_cache = bool(fn_cache)
        self.heartbeat = None if heartbeat is None else float(heartbeat)
        self.patience = int(patience)
        self.last_heartbeat: Optional[dict] = None  # latest beat payload
        self._last_heard = 0.0       # perf_counter of the last frame heard
        self._closed = False
        # Adaptive-width state: raw frame transit vs. per-chunk service
        # EWMAs (seconds); kept across restarts — the link does not
        # forget its character when a session reconnects.
        self._ewma_transit: Optional[float] = None
        self._ewma_service: Optional[float] = None
        self._transport = transport
        self.lost = False
        self.wire_latencies: List[float] = []
        self.local_queue_latencies: List[float] = []
        self._seq = 0
        # seq -> {seq, chunk, fn, t_submit, t_sent, sends, next_resend,
        #         batch_n}; insertion order == seq order
        self._pending: "OrderedDict[int, dict]" = OrderedDict()
        self._unsent: List[int] = []
        self._registered: set = set()               # fn_ids the worker knows
        self._fn_refs: Dict[str, Callable] = {}     # keep ids alive
        self._fn_id_cache: Dict[int, str] = {}      # id(fn) -> fn_id
        self._plock = threading.Lock()
        self._stop = threading.Event()
        self._recv_thread: Optional[threading.Thread] = None

    # -- adaptive frame batching --------------------------------------------
    @property
    def batch_frames(self) -> int:
        """Current frame-coalescing width (fixed value, or the adaptive
        one when constructed with ``batch_frames="auto"``)."""
        return self._batch

    @property
    def capacity(self) -> int:
        # the engine pipelines exactly one frame's worth of chunks
        return self._batch

    @property
    def effective_batch_frames(self) -> int:
        """Alias surfaced into ``RunReport.batch_frames`` by the engine."""
        return self._batch

    def _auto_resize(self) -> None:
        """Re-size the adaptive width from the learned link character.

        Target: enough chunks per frame that the raw frame transit time
        (paid once per frame, whatever rides on it) is amortized below
        one chunk's service time — ``ceil(transit / service)``, clamped
        to ``[1, AUTO_BATCH_MAX]``.  Called at flush boundaries so the
        width only moves between frames, never inside one.
        """
        if not self.auto_batch:
            return
        with self._plock:
            transit, service = self._ewma_transit, self._ewma_service
        if transit is None or service is None:
            return
        target = math.ceil(transit / max(service, 1e-9))
        self._batch = max(1, min(int(target), AUTO_BATCH_MAX))

    # -- lifecycle ----------------------------------------------------------
    def start(self, bus: CompletionBus) -> None:
        super().start(bus)
        self.wire_latencies = []
        self.local_queue_latencies = []
        with self._plock:
            # fresh session: the worker's fn registry is per-session, so
            # every descriptor must be re-shipped after a restart
            self._pending = OrderedDict()
            self._unsent = []
            self._registered = set()
            self._fn_refs = {}
            self._fn_id_cache = {}
        if self._transport is None or self._transport.closed:
            if self.address is None:
                raise TransportClosed(
                    f"unit {self.name!r}: injected transport is closed and "
                    "there is no address to reconnect to"
                )
            self._transport = SocketTransport.connect(
                self.address, timeout=self.connect_timeout
            )
        self.lost = False
        self._closed = False
        self._stop.clear()
        self._handshake()
        self._last_heard = time.perf_counter()  # ready answered: alive now
        self._recv_thread = threading.Thread(
            target=self._recv_loop, daemon=True,
            name=f"eneac-remote-{self.name}",
        )
        self._recv_thread.start()

    def _handshake(self) -> None:
        """hello → ready, retransmitting until the worker answers."""
        hello = {"kind": "hello", "unit": self.name,
                 "backend": self.remote_backend}
        if self.heartbeat is not None:
            hello["heartbeat"] = self.heartbeat
        deadline = time.perf_counter() + self.connect_timeout
        next_hello = 0.0
        while time.perf_counter() < deadline:
            if time.perf_counter() >= next_hello:
                self._transport.send(hello)
                next_hello = time.perf_counter() + max(self.retry_interval, 0.02)
            frame = self._transport.recv(timeout=0.02)
            if frame is None:
                continue
            kind = frame.get("kind")
            if kind == "ready" and frame.get("unit") == self.name:
                return
            if kind == "error" and frame.get("unit") == self.name:
                raise TransportError(
                    f"worker refused unit {self.name!r}: {frame.get('message')}"
                )
            # stale frames from an earlier session are ignored
        raise TransportError(
            f"worker for unit {self.name!r} did not answer hello within "
            f"{self.connect_timeout}s"
        )

    def close(self) -> None:
        if self._closed:
            return  # idempotent: a second close must not re-send bye
        self._closed = True
        self._stop.set()
        if self._transport is not None and not self._transport.closed:
            try:
                self._transport.send({"kind": "bye", "unit": self.name})
            except TransportError as exc:
                # A swallowed failure here used to leave the worker
                # hosting a retired unit forever (it never saw the bye
                # and the session stayed open).  The close still
                # proceeds — the transport.close() below gives the
                # worker a definitive EOF — but the failed drain is
                # surfaced instead of silently dropped.
                logger.warning(
                    "unit %r: graceful bye failed (%r); closing the "
                    "transport so the worker sees EOF instead",
                    self.name, exc,
                )
        thread = self._recv_thread
        if (thread is not None and thread.is_alive()
                and thread is not threading.current_thread()):
            thread.join(timeout=5.0)  # bounded: never hangs the caller
        self._recv_thread = None
        if self._transport is not None:
            self._transport.close()
        super().close()

    # -- descriptor cache ---------------------------------------------------
    def _fn_id(self, fn: Callable) -> str:
        """Content-hash id for ``fn`` (identity-cached per object).

        ``h:<sha1>`` of the pickled callable — two objects with the same
        content share a registration, and a *changed* function hashes
        differently so it re-registers.  Unpicklable callables (loopback
        lambdas, closures over live objects) get an identity id
        ``r:<id>``; the strong reference kept in ``_fn_refs`` makes the
        id stable for the session.
        """
        key = id(fn)
        cached = self._fn_id_cache.get(key)
        if cached is not None and self._fn_refs.get(cached) is fn:
            return cached
        try:
            blob = pickle.dumps(fn, protocol=pickle.HIGHEST_PROTOCOL)
            fid = "h:" + hashlib.sha1(blob).hexdigest()[:16]
        except Exception:
            fid = f"r:{id(fn):x}"
        self._fn_id_cache[key] = fid
        self._fn_refs[fid] = fn
        return fid

    # -- submission ---------------------------------------------------------
    def submit(self, chunk: Chunk, work_fn: Callable[[Chunk], Any]) -> None:
        if self.lost or self._transport is None or self._transport.closed:
            self._post_lost(chunk, "transport already lost at submit")
            return
        t_submit = time.perf_counter()
        with self._plock:
            seq = self._seq
            self._seq += 1
            self._pending[seq] = {
                "seq": seq, "chunk": chunk, "fn": work_fn,
                "t_submit": t_submit, "t_sent": None, "sends": 0,
                "next_resend": 0.0, "batch_n": 1,
            }
            self._unsent.append(seq)
            full = len(self._unsent) >= self.batch_frames
        if full:
            self.flush()

    def flush(self) -> None:
        """Send every buffered (not-yet-transmitted) chunk now.

        A flush is also the adaptive-width re-evaluation boundary: the
        buffered frame goes out at the width it was filled for, then the
        width adjusts for the next fill.
        """
        self._transmit(resend=False)
        self._auto_resize()

    def _transmit(self, *, resend: bool) -> None:
        """Frame and send pending work: the unsent buffer (``resend=False``)
        or everything already on the wire (``resend=True``, one batch).

        A single item keeps the legacy ``submit`` frame shape; two or
        more coalesce into one ``work_batch``.  Needed ``register_fn``
        frames precede the work frame.  ``floor`` — the lowest seq the
        client still cares about — rides on every work frame so the
        worker can prune its accepted-seq set and done cache.
        """
        frames: List[dict] = []
        with self._plock:
            if resend:
                seqs = [s for s, p in self._pending.items()
                        if p["t_sent"] is not None]
            else:
                seqs, self._unsent = self._unsent, []
            if not seqs:
                return
            now = time.perf_counter()
            floor = min(self._pending) if self._pending else self._seq
            items: List[dict] = []
            for seq in seqs:
                p = self._pending.get(seq)
                if p is None:
                    continue  # completed while queued for resend
                if p["t_sent"] is None:
                    p["t_sent"] = now
                p["sends"] += 1
                p["next_resend"] = now + self.retry_interval
                item = {"seq": seq, "chunk": p["chunk"],
                        "t_submit": p["t_submit"]}
                if self.fn_cache:
                    fid = self._fn_id(p["fn"])
                    if fid not in self._registered:
                        frames.append({"kind": "register_fn",
                                       "unit": self.name,
                                       "fn_id": fid, "fn": p["fn"]})
                        self._registered.add(fid)
                    item["fn_ref"] = fid
                else:
                    item["fn"] = p["fn"]
                items.append(item)
            if not items:
                return
            if not resend:
                # first transmission: record how many chunks share the
                # frame, for the per-chunk wire-time attribution
                for item in items:
                    p = self._pending.get(item["seq"])
                    if p is not None:
                        p["batch_n"] = len(items)
            if len(items) == 1:
                frames.append({"kind": "submit", "unit": self.name,
                               "floor": floor, **items[0]})
            else:
                frames.append({"kind": "work_batch", "unit": self.name,
                               "floor": floor, "items": items})
        try:
            for frame in frames:
                self._transport.send(frame)
        except TransportError:
            self._fail_pending("connection lost while sending work")

    # -- the receiver thread -------------------------------------------------
    def _recv_loop(self) -> None:
        tick = max(min(self.retry_interval / 2.0, 0.05), 0.005)
        if self.heartbeat is not None:
            # convictions must be checked a few times per interval or a
            # coarse tick adds a whole tick of detection latency
            tick = min(tick, self.heartbeat / 4.0)
        while not self._stop.is_set():
            try:
                frame = self._transport.recv(timeout=tick)
            except TransportClosed:
                self._fail_pending("connection closed by the worker")
                return
            if frame is not None:
                # any frame from the session proves the worker process is
                # alive, whatever unit or seq it concerns
                self._last_heard = time.perf_counter()
                self._on_frame(frame)
            if self._convict_if_silent():
                return
            self._maybe_retransmit()

    def _convict_if_silent(self) -> bool:
        """Missed-heartbeat conviction (heartbeat-enabled units only).

        Patience-gated like the straggler detector: the worker is
        convicted as *dead* only after ``patience`` full intervals with
        no frame of any kind — one dropped or late beat is absorbed.
        Unlike retransmit exhaustion this fires for an idle unit too,
        so a dead worker is discovered without submitting work to it.
        """
        if self.heartbeat is None or self.lost:
            return False
        silent_for = time.perf_counter() - self._last_heard
        if silent_for <= self.patience * self.heartbeat:
            return False
        self._fail_pending(
            f"no heartbeat for {silent_for:.3f}s "
            f"(> patience {self.patience} x {self.heartbeat}s)",
            error_cls=WorkerDead,
        )
        return True

    def _maybe_retransmit(self) -> None:
        exhausted = False
        resend = False
        flush_stranded = False
        now = time.perf_counter()
        with self._plock:
            for p in self._pending.values():
                if p["t_sent"] is None:
                    # safety net: an unsent chunk nobody flushed (a driver
                    # bypassing the engine's flush) still goes out
                    if now >= p["t_submit"] + self.retry_interval:
                        flush_stranded = True
                    continue
                if now >= p["next_resend"]:
                    if p["sends"] > self.max_retries:
                        exhausted = True
                        break
                    resend = True
        if exhausted:
            self._fail_pending(
                f"no completion after {self.max_retries} retransmits"
            )
            return
        if flush_stranded:
            self.flush()
        if resend:
            self._transmit(resend=True)

    def _on_frame(self, frame: dict) -> None:
        if frame.get("unit") != self.name:
            return
        kind = frame.get("kind")
        if kind == "heartbeat":
            # liveness already noted in the recv loop; keep the payload
            # (queue_depth / inflight) for membership + autoscaling eyes
            self.last_heartbeat = frame
            return
        if kind == "busy":
            # the worker is alive and executing this pending seq: the
            # retransmit budget bounds unresponsiveness, not work time
            with self._plock:
                p = self._pending.get(frame.get("seq"))
                if p is not None:
                    p["sends"] = 1
            return
        if kind == "unknown_fn":
            # the worker does not know this descriptor (registration lost
            # or worker restarted): re-register and retransmit right away.
            # sends keeps counting (unlike busy) so a poison registration
            # still exhausts into WorkerLost instead of looping forever.
            with self._plock:
                self._registered.discard(frame.get("fn_id"))
                p = self._pending.get(frame.get("seq"))
                if p is not None:
                    p["next_resend"] = 0.0
            return
        if kind == "done":
            self._on_done_item(frame)
        elif kind == "done_batch":
            for item in frame.get("items") or ():
                self._on_done_item(item)

    def _on_done_item(self, item: dict) -> None:
        with self._plock:
            p = self._pending.pop(item.get("seq"), None)
        if p is None:
            return  # duplicate/stale completion: drop on the floor
        t_sent = p["t_sent"] if p["t_sent"] is not None else p["t_submit"]
        t_start = item.get("t_start")
        if t_start is None:
            t_start = t_sent
        t_accept = item.get("t_accept")
        if t_accept is None:
            t_accept = t_start
        batch_n = max(int(p.get("batch_n") or 1), 1)
        # Per-chunk wire attribution: the frame's transit time
        # (send -> worker accept) is shared by every chunk in the frame,
        # so each chunk gets 1/batch_n of it; the remote queue wait
        # (accept -> execution start) is genuinely per-chunk.  Summed
        # over a batch this counts the frame's transit exactly once.
        wire = (max(t_accept - t_sent, 0.0) / batch_n
                + max(t_start - t_accept, 0.0))
        self.wire_latencies.append(wire)
        self.local_queue_latencies.append(max(t_sent - p["t_submit"], 0.0))
        if self.auto_batch:
            # Raw (undivided) frame transit vs. per-chunk service time:
            # the attributed per-chunk wire number above shrinks as the
            # batch grows, which would feed back into ever-smaller
            # targets; sizing needs the cost one frame actually pays.
            a = _AUTO_BATCH_ALPHA
            transit = max(t_accept - t_sent, 0.0)
            service = max(float(item.get("elapsed", 0.0)), 0.0)
            with self._plock:
                self._ewma_transit = (transit if self._ewma_transit is None
                                      else a * transit + (1 - a) * self._ewma_transit)
                self._ewma_service = (service if self._ewma_service is None
                                      else a * service + (1 - a) * self._ewma_service)
        self._post(CompletionRecord(
            unit=self.name, chunk=p["chunk"],
            elapsed=float(item.get("elapsed", 0.0)),
            dispatch_latency=max(t_start - p["t_submit"], 0.0),
            error=item.get("error"), result=item.get("result"),
        ))

    # -- failure ------------------------------------------------------------
    def _post_lost(self, chunk: Optional[Chunk], why: str,
                   error_cls: type = WorkerLost) -> None:
        self.lost = True
        bus = self._bus
        if bus is not None:
            bus.post(CompletionRecord(
                unit=self.name, chunk=chunk, elapsed=0.0, dispatch_latency=0.0,
                error=error_cls(f"unit {self.name!r}: {why}"), result=None,
            ))

    def _fail_pending(self, why: str, *, error_cls: type = WorkerLost) -> None:
        with self._plock:
            pending, self._pending = self._pending, OrderedDict()
            self._unsent = []
        self.lost = True
        self._stop.set()
        # one WorkerLost/WorkerDead is enough: the engine answers it by
        # removing the unit, which requeues *all* of its outstanding
        # chunks at once.  A heartbeat conviction with nothing pending
        # (idle unit) still posts — with chunk=None — so membership
        # observers learn of the death without waiting for a submit.
        first = next(iter(pending.values()), None)
        if first is not None:
            self._post_lost(first["chunk"], why, error_cls)
        elif error_cls is not WorkerLost:
            self._post_lost(None, why, error_cls)

    def describe(self) -> str:
        where = self.address if self.address is not None else "injected transport"
        return f"RemoteUnit({self.name!r} @ {where})"


# ---------------------------------------------------------------------------
# transportable work helpers
# ---------------------------------------------------------------------------
class SleepWork:
    """Per-item sleep work that survives the pickling transport.

    Work functions sent to a :class:`SocketTransport` worker unpickle *by
    module reference* on the far side, so they cannot live in a script's
    ``__main__`` (the worker has a different ``__main__``).  Benchmarks
    that model compute with calibrated sleeps import this instead.
    """

    def __init__(self, seconds_per_item: float) -> None:
        self.seconds_per_item = float(seconds_per_item)

    def __call__(self, chunk) -> None:
        time.sleep(chunk.size * self.seconds_per_item)


# ---------------------------------------------------------------------------
# worker subprocesses
# ---------------------------------------------------------------------------
_BANNER = "ENEAC_WORKER"


class WorkerHandle:
    """A spawned worker subprocess: its address and its lifetime."""

    def __init__(self, proc: subprocess.Popen, address: str) -> None:
        self.proc = proc
        self.address = address

    def terminate(self, timeout: float = 10.0) -> None:
        if self.proc.poll() is None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=timeout)
            except subprocess.TimeoutExpired:
                self.kill()

    def kill(self) -> None:
        if self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait()

    @property
    def alive(self) -> bool:
        return self.proc.poll() is None

    def __enter__(self) -> "WorkerHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.terminate()


def spawn_worker(*, host: str = "127.0.0.1",
                 startup_timeout: float = 60.0) -> WorkerHandle:
    """Launch ``python -m repro.core.transport`` and wait for its address.

    The subprocess prints ``ENEAC_WORKER <host:port>`` once its listener
    is bound; this parses that line (with a timeout, so a worker that
    dies on import fails fast instead of hanging the caller) and returns
    a handle whose ``address`` plugs straight into
    ``register_unit(backend=f"remote:{handle.address}")``.

    The worker inherits the parent's ``sys.path``, because submitted
    work functions unpickle by module reference on the far side — the
    worker must be able to import whatever module defines them (test
    modules included).
    """
    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    paths = [src_dir] + [p for p in sys.path if p and p != src_dir]
    env["PYTHONPATH"] = os.pathsep.join(paths)
    env.setdefault("JAX_PLATFORMS", "cpu")
    entry = ("import sys; from repro.core.transport import _main; "
             "sys.exit(_main(sys.argv[1:]))")
    proc = subprocess.Popen(
        [sys.executable, "-c", entry, "--host", host, "--port", "0"],
        stdout=subprocess.PIPE, text=True, env=env,
    )
    deadline = time.monotonic() + startup_timeout
    line = ""
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(
                f"worker subprocess exited with {proc.returncode} before "
                "announcing its address"
            )
        ready, _, _ = select.select([proc.stdout], [], [], 0.25)
        if not ready:
            continue
        line = proc.stdout.readline()
        if line.startswith(_BANNER):
            return WorkerHandle(proc, line.split()[1].strip())
        if not line:  # EOF without banner
            break
    proc.kill()
    raise RuntimeError(
        f"worker subprocess did not announce an address within "
        f"{startup_timeout}s (last line: {line!r})"
    )


def _main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Serve ENEAC remote backend units over TCP")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (printed on stdout)")
    args = ap.parse_args(argv)
    server = WorkerServer(args.host, args.port)
    print(f"{_BANNER} {server.address}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(_main())

"""Fleet membership: discovery, heartbeat liveness, autoscaling, recovery.

The paper's scheduler assumes a *known* set of compute units.  This
module owns the step before that assumption holds: which workers are in
the fleet right now, which of them are still alive, and how many there
should be.

* :class:`HeartbeatBook` — the membership ledger.  Workers announce
  (``join``), then report liveness (``beat``, fed from the transport's
  ``heartbeat`` frames); a member silent for longer than
  ``patience x heartbeat`` is *convicted* dead on the next :meth:`sweep`.
  The patience gate mirrors :class:`~repro.core.straggler.StragglerDetector`:
  one missed beat is weather, ``patience`` consecutive missed beats is a
  verdict.  Every membership change lands in a monotone event log.
* :class:`Autoscaler` — a pure sizing policy: observed queue depth plus
  the cost model's learned per-unit throughput
  (:meth:`~repro.core.costmodel.CostModel.predict_drain`) give the
  smallest fleet that drains the backlog within ``horizon`` seconds.
  Scale-up covers the whole gap at once (backlog hurts now); scale-down
  drains one unit per cooldown (capacity is cheap to keep, expensive to
  rebuild).  With no learned data the policy holds size — it never
  scales blind.
* :class:`FailureTrace` / :func:`simulate_fleet` — seeded churn
  (join/leave/crash/slow) replayed two ways: virtual heartbeat timelines
  through a :class:`HeartbeatBook` (conviction correctness: every crash
  convicted, no slow-but-alive unit convicted), then the derived
  membership timeline through
  :meth:`~repro.core.runtime.HeteroRuntime.parallel_for` under
  :class:`~repro.core.runtime.SimulatedClock` (exact-once coverage under
  churn).  Deterministic per seed — the CI battery replays many seeds.
* :class:`FleetManager` — the wall-clock owner: spawns
  :func:`~repro.core.transport.spawn_worker` subprocesses, registers
  them as ``remote:<addr>?heartbeat=..&patience=..`` units (so the
  transport layer's missed-heartbeat conviction feeds the engine's
  retire path), and applies :class:`Autoscaler` decisions to real
  processes.  Mid-run worker death is the transport/engine's job
  (``action="lost"``/``"dead"`` + exact-once requeue); whole-run death
  is :func:`repro.checkpoint.coverage.checkpointed_parallel_for`'s.
"""

from __future__ import annotations

import math
import random
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .costmodel import CostModel
from .elastic import ElasticSchedule
from .transport import WorkerHandle, spawn_worker

__all__ = [
    "Autoscaler",
    "FailureTrace",
    "FleetManager",
    "FleetSimResult",
    "HeartbeatBook",
    "TraceEvent",
    "simulate_fleet",
]


# ---------------------------------------------------------------------------
# membership ledger
# ---------------------------------------------------------------------------
@dataclass
class _Member:
    name: str
    last_heard: float
    queue_depth: int = 0
    inflight: int = 0


class HeartbeatBook:
    """Patience-gated membership ledger over explicit timestamps.

    Time is an argument, not a clock read, so the same book serves the
    wall-clock :class:`FleetManager` (pass ``time.perf_counter()``) and
    the seeded virtual-time simulation (pass trace times) — and every
    conviction decision is replayable.

    Timestamps must be non-decreasing across *all* calls; the book
    raises on time travel rather than producing an unorderable event
    log.  Events are dicts ``{"t", "action", "unit"}`` with action in
    ``join | leave | dead``, appended in call order — monotone ``t`` is
    an invariant the fleet battery pins per seed.
    """

    def __init__(self, *, heartbeat: float, patience: int = 3) -> None:
        if heartbeat <= 0:
            raise ValueError(f"heartbeat must be positive, got {heartbeat}")
        if patience < 1:
            raise ValueError(f"patience must be >= 1, got {patience}")
        self.heartbeat = float(heartbeat)
        self.patience = int(patience)
        self._members: Dict[str, _Member] = {}
        self._events: List[dict] = []
        self._now = 0.0

    # -- invariants ---------------------------------------------------------
    def _advance(self, t: float) -> float:
        t = float(t)
        if t < self._now:
            raise ValueError(
                f"time went backwards: {t} < last seen {self._now}"
            )
        self._now = t
        return t

    # -- membership feed ----------------------------------------------------
    def join(self, t: float, unit: str) -> None:
        t = self._advance(t)
        if unit in self._members:
            raise ValueError(f"unit {unit!r} is already a member")
        self._members[unit] = _Member(name=unit, last_heard=t)
        self._events.append({"t": t, "action": "join", "unit": unit})

    def beat(self, t: float, unit: str, *, queue_depth: int = 0,
             inflight: int = 0) -> None:
        """A liveness report (the transport's ``heartbeat`` frame payload).

        Beats from non-members are dropped, not an error: a convicted
        worker's in-flight beats may still arrive after the sweep, and a
        late beat must not resurrect a membership the engine has already
        retired.
        """
        t = self._advance(t)
        m = self._members.get(unit)
        if m is None:
            return
        m.last_heard = t
        m.queue_depth = int(queue_depth)
        m.inflight = int(inflight)

    def leave(self, t: float, unit: str) -> None:
        """A graceful departure (the transport's ``bye``)."""
        t = self._advance(t)
        if unit not in self._members:
            raise ValueError(f"unit {unit!r} is not a member")
        del self._members[unit]
        self._events.append({"t": t, "action": "leave", "unit": unit})

    def sweep(self, t: float) -> List[str]:
        """Convict every member silent for more than patience x heartbeat.

        Returns the convicted names (event ``action="dead"``, matching
        the engine's silence-vs-loss distinction) in name order.
        """
        t = self._advance(t)
        limit = self.patience * self.heartbeat
        dead = sorted(
            name for name, m in self._members.items()
            if (t - m.last_heard) > limit
        )
        for name in dead:
            del self._members[name]
            self._events.append({"t": t, "action": "dead", "unit": name})
        return dead

    # -- views --------------------------------------------------------------
    @property
    def members(self) -> List[str]:
        return sorted(self._members)

    @property
    def events(self) -> List[dict]:
        return list(self._events)

    def queue_depth(self) -> int:
        """Total reported backlog across live members (autoscaler input)."""
        return sum(m.queue_depth for m in self._members.values())

    def deadline(self, unit: str) -> float:
        """The time at which ``unit`` becomes convictable."""
        m = self._members.get(unit)
        if m is None:
            raise KeyError(unit)
        return m.last_heard + self.patience * self.heartbeat

    def __len__(self) -> int:
        return len(self._members)

    def __contains__(self, unit: str) -> bool:
        return unit in self._members


# ---------------------------------------------------------------------------
# sizing policy
# ---------------------------------------------------------------------------
class Autoscaler:
    """Queue-depth + learned-throughput fleet sizing.

    Pure policy: :meth:`decide` maps ``(t, queue_depth, n_units)`` to a
    signed membership delta; applying it (spawning/draining) is the
    caller's job (:class:`FleetManager` on a wall clock, the simulation
    in virtual time).  The target size is the smallest fleet whose
    predicted drain time (:meth:`CostModel.predict_drain`) fits inside
    ``horizon`` seconds, clamped to ``[min_units, max_units]``.

    Asymmetry is deliberate: scale-up closes the whole gap in one step
    (an over-deep queue is the failure mode the paper's async engine
    exists to avoid), scale-down releases one unit per ``cooldown_s``
    (readmitting capacity costs a worker spawn + handshake).  A model
    with no observations for ``kernel`` yields delta 0 — never scale on
    a guess.
    """

    def __init__(
        self,
        cost_model: Optional[CostModel] = None,
        *,
        kernel: str = "default",
        horizon: float = 1.0,
        min_units: int = 1,
        max_units: int = 8,
        cooldown_s: float = 1.0,
    ) -> None:
        if horizon <= 0:
            raise ValueError(f"horizon must be positive, got {horizon}")
        if min_units < 1:
            raise ValueError(f"min_units must be >= 1, got {min_units}")
        if max_units < min_units:
            raise ValueError(
                f"max_units {max_units} < min_units {min_units}"
            )
        if cooldown_s < 0:
            raise ValueError(f"cooldown_s must be >= 0, got {cooldown_s}")
        self.cost_model = cost_model
        self.kernel = kernel
        self.horizon = float(horizon)
        self.min_units = int(min_units)
        self.max_units = int(max_units)
        self.cooldown_s = float(cooldown_s)
        self._last_change: Optional[float] = None

    def target(self, queue_depth: int) -> Optional[int]:
        """Clamped ideal size, or None when the model has no data."""
        if queue_depth <= 0:
            return self.min_units
        if self.cost_model is None:
            return None
        per_unit = self.cost_model.fleet_throughput(self.kernel)
        if per_unit is None:
            return None
        need = math.ceil(queue_depth / (per_unit * self.horizon))
        return max(self.min_units, min(self.max_units, need))

    def decide(self, t: float, *, queue_depth: int, n_units: int) -> int:
        """Signed unit delta to apply now (0 = hold)."""
        tgt = self.target(queue_depth)
        if tgt is None or tgt == n_units:
            return 0
        if self._last_change is not None and \
                (t - self._last_change) < self.cooldown_s:
            return 0
        delta = (tgt - n_units) if tgt > n_units else -1
        # never drain below the floor even if n_units started above max
        if delta < 0 and n_units + delta < self.min_units:
            return 0
        self._last_change = t
        return delta


# ---------------------------------------------------------------------------
# seeded churn traces
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TraceEvent:
    """One membership fate in a seeded churn trace.

    ``action`` is ``join`` (a fresh unit announces at ``t``), ``leave``
    (graceful bye at ``t``), ``crash`` (goes silent at ``t``: heartbeats
    stop, no bye), or ``slow`` (from ``t`` on, beats arrive stretched by
    ``factor`` < patience — alive, just late; a correct book never
    convicts it).
    """

    t: float
    action: str
    unit: str
    factor: float = 1.0

    def __post_init__(self) -> None:
        if self.action not in ("join", "leave", "crash", "slow"):
            raise ValueError(f"unknown trace action {self.action!r}")
        if self.t < 0:
            raise ValueError(f"event time must be >= 0, got {self.t}")


class FailureTrace:
    """A seeded, replayable churn timeline over an initial fleet."""

    def __init__(self, seed: int, initial_units: Sequence[str],
                 events: Sequence[TraceEvent], horizon: float) -> None:
        self.seed = int(seed)
        self.initial_units = list(initial_units)
        self.events = sorted(events, key=lambda e: e.t)
        self.horizon = float(horizon)

    @classmethod
    def generate(
        cls,
        seed: int,
        *,
        num_units: int = 100,
        horizon: float = 10.0,
        crash_frac: float = 0.15,
        leave_frac: float = 0.10,
        slow_frac: float = 0.15,
        join_frac: float = 0.10,
    ) -> "FailureTrace":
        """Deterministic churn for ``seed``: each initial unit draws one
        fate (stay / leave / crash / slow) and ``join_frac`` fresh units
        announce mid-run.  Fractions are bounded so a majority of the
        fleet always survives — total loss is a different failure mode
        (job abort), not elasticity.
        """
        if num_units < 2:
            raise ValueError(f"need at least 2 units, got {num_units}")
        if crash_frac + leave_frac > 0.5:
            raise ValueError(
                "crash_frac + leave_frac must stay <= 0.5 so survivors "
                f"remain a majority, got {crash_frac + leave_frac}"
            )
        rng = random.Random(seed)
        units = [f"u{i:03d}" for i in range(num_units)]
        fates = (["crash"] * int(num_units * crash_frac)
                 + ["leave"] * int(num_units * leave_frac)
                 + ["slow"] * int(num_units * slow_frac))
        fates += ["stay"] * (num_units - len(fates))
        rng.shuffle(fates)
        events: List[TraceEvent] = []
        for unit, fate in zip(units, fates):
            if fate == "stay":
                continue
            # churn lands mid-run: not at t=0 (that's just a smaller
            # fleet) and not at the horizon (those events are no-ops)
            t = rng.uniform(0.1, 0.9) * horizon
            if fate == "slow":
                # stretched but under the conviction limit: a correct
                # book must keep these (the straggler layer's problem)
                factor = rng.uniform(1.2, 2.4)
                events.append(TraceEvent(t=t, action="slow", unit=unit,
                                         factor=factor))
            else:
                events.append(TraceEvent(t=t, action=fate, unit=unit))
        for j in range(int(num_units * join_frac)):
            t = rng.uniform(0.1, 0.9) * horizon
            events.append(TraceEvent(t=t, action="join", unit=f"j{j:03d}"))
        return cls(seed, units, events, horizon)

    def fate_of(self, unit: str) -> Optional[TraceEvent]:
        for ev in self.events:
            if ev.unit == unit:
                return ev
        return None

    @property
    def crashed(self) -> List[str]:
        return sorted(e.unit for e in self.events if e.action == "crash")

    @property
    def left(self) -> List[str]:
        return sorted(e.unit for e in self.events if e.action == "leave")

    @property
    def slowed(self) -> List[str]:
        return sorted(e.unit for e in self.events if e.action == "slow")

    @property
    def joined(self) -> List[str]:
        return sorted(e.unit for e in self.events if e.action == "join")


# ---------------------------------------------------------------------------
# virtual-time fleet simulation
# ---------------------------------------------------------------------------
@dataclass
class FleetSimResult:
    """What one seeded replay produced — everything the battery asserts."""

    seed: int
    trace: FailureTrace
    book_events: List[dict]
    convicted: List[str]
    false_convictions: List[str]
    missed_crashes: List[str]
    conviction_delay: Dict[str, float]
    schedule: ElasticSchedule
    report: object  # RunReport; untyped to keep the import graph acyclic
    survivors: List[str] = field(default_factory=list)


def simulate_fleet(
    seed: int,
    *,
    num_units: int = 100,
    heartbeat: float = 0.05,
    patience: int = 3,
    horizon: float = 10.0,
    items_per_unit: int = 6,
    trace: Optional[FailureTrace] = None,
) -> FleetSimResult:
    """Replay one seeded churn trace through the whole membership stack.

    Phase 1 — liveness: every unit's heartbeat timeline (stopping at its
    crash, ending with a bye at its leave, stretching by its slow
    factor) is fed through a :class:`HeartbeatBook` in global time
    order, sweeping at every step.  Convictions are compared against the
    trace's ground truth: ``false_convictions`` (convicted but alive —
    must be empty: slow is not dead) and ``missed_crashes`` (crashed but
    never convicted before the horizon — must be empty: silence is
    always noticed).

    Phase 2 — coverage: the book's verdicts become an
    :class:`~repro.core.elastic.ElasticSchedule` (graceful leaves at
    their bye times, crashes at their *conviction* times — detection
    latency included — merged with trace joins), replayed by
    ``parallel_for`` under :class:`SimulatedClock` so the engine's
    exact-once requeue is exercised under the same churn.  The caller
    asserts the report's coverage tiles the space exactly and its event
    log is time-monotone.
    """
    # local import: runtime imports backends/transport; fleet is imported
    # by core/__init__ after runtime, so a module-level import would cycle
    from .runtime import HeteroRuntime, SimulatedClock

    tr = trace if trace is not None else FailureTrace.generate(
        seed, num_units=num_units, horizon=horizon)
    book = HeartbeatBook(heartbeat=heartbeat, patience=patience)

    # -- phase 1: virtual heartbeat timelines --------------------------------
    # (t, order, kind, unit, payload); order breaks ties deterministically
    feed: List[Tuple[float, int, str, str, float]] = []
    order = 0

    def emit(t: float, kind: str, unit: str, payload: float = 0.0) -> None:
        nonlocal order
        feed.append((t, order, kind, unit, payload))
        order += 1

    for unit in tr.initial_units:
        emit(0.0, "join", unit)
    for ev in tr.events:
        if ev.action == "join":
            emit(ev.t, "join", ev.unit)

    for unit in tr.initial_units + tr.joined:
        fate = tr.fate_of(unit)
        start = fate.t if (fate is not None and fate.action == "join") else 0.0
        stop = tr.horizon
        interval = heartbeat
        if fate is not None and fate.action in ("crash", "leave"):
            stop = fate.t
        t = start + interval
        while t < stop:
            if fate is not None and fate.action == "slow" and t >= fate.t:
                interval = heartbeat * fate.factor
            emit(t, "beat", unit)
            t += interval
        if fate is not None and fate.action == "leave":
            emit(fate.t, "bye", unit)

    convicted: List[str] = []
    conviction_t: Dict[str, float] = {}
    for t, _, kind, unit, _payload in sorted(feed, key=lambda e: (e[0], e[1])):
        if kind == "join":
            book.join(t, unit)
        elif kind == "beat":
            book.beat(t, unit)
        elif kind == "bye":
            book.leave(t, unit)
        for name in book.sweep(t):
            convicted.append(name)
            conviction_t[name] = t
    for name in book.sweep(tr.horizon):
        convicted.append(name)
        conviction_t[name] = tr.horizon

    crashed = set(tr.crashed)
    false_convictions = sorted(set(convicted) - crashed)
    missed_crashes = sorted(crashed - set(convicted))
    delays = {u: conviction_t[u] - float(tr.fate_of(u).t)
              for u in crashed if u in conviction_t}

    # -- phase 2: membership timeline under the real engine ------------------
    losses = ElasticSchedule()
    for ev in tr.events:
        if ev.action == "leave":
            losses.leave(ev.t, ev.unit)
        elif ev.action == "crash" and ev.unit in conviction_t:
            # the engine learns of a crash at *conviction*, not at the
            # instant of death — detection latency is part of the model
            losses.leave(conviction_t[ev.unit], ev.unit)
    joins = ElasticSchedule()
    for ev in tr.events:
        if ev.action == "join":
            joins.join(ev.t, ev.unit, kind="cc", speed=1.0)
    schedule = losses.merge(joins)

    rt = HeteroRuntime(clock=SimulatedClock())
    for unit in tr.initial_units:
        fate = tr.fate_of(unit)
        speed = 1.0
        if fate is not None and fate.action == "slow":
            speed = 1.0 / fate.factor
        rt.register_unit(unit, "cc", speed=speed)
    report = rt.parallel_for(
        num_items=num_units * items_per_unit,
        policy="multidynamic",
        acc_chunk=max(items_per_unit // 2, 1),
        elastic=schedule,
    )

    return FleetSimResult(
        seed=seed,
        trace=tr,
        book_events=book.events,
        convicted=sorted(set(convicted)),
        false_convictions=false_convictions,
        missed_crashes=missed_crashes,
        conviction_delay=delays,
        schedule=schedule,
        report=report,
        survivors=book.members,
    )


# ---------------------------------------------------------------------------
# wall-clock fleet
# ---------------------------------------------------------------------------
class FleetManager:
    """Owns real worker subprocesses and their runtime registrations.

    ``scale_to(n)`` / ``autoscale_step()`` spawn
    :func:`~repro.core.transport.spawn_worker` processes and register
    each as a ``remote:<addr>?heartbeat=..&patience=..`` unit on the
    runtime, so every fleet member gets transport-level liveness: a
    silent worker is convicted by its :class:`RemoteUnit` proxy and
    retired through the engine's elastic path (``action="dead"``,
    exact-once requeue) without any fleet-level polling.

    Draining removes the registration first and then terminates the
    process — the reverse order would turn every scale-down into a fake
    worker-loss event.  Use as a context manager; :meth:`shutdown` is
    idempotent.
    """

    def __init__(
        self,
        runtime,
        *,
        heartbeat: float = 0.5,
        patience: int = 3,
        autoscaler: Optional[Autoscaler] = None,
        unit_prefix: str = "fleet",
        spawn: Callable[[], WorkerHandle] = spawn_worker,
    ) -> None:
        if heartbeat <= 0:
            raise ValueError(f"heartbeat must be positive, got {heartbeat}")
        if patience < 1:
            raise ValueError(f"patience must be >= 1, got {patience}")
        self.runtime = runtime
        self.heartbeat = float(heartbeat)
        self.patience = int(patience)
        self.autoscaler = autoscaler
        self.unit_prefix = unit_prefix
        self._spawn = spawn
        self._handles: Dict[str, WorkerHandle] = {}
        self._next_id = 0
        self._events: List[dict] = []

    # -- membership ---------------------------------------------------------
    def spec_for(self, handle: WorkerHandle) -> str:
        return (f"remote:{handle.address}"
                f"?heartbeat={self.heartbeat}&patience={self.patience}")

    def spawn_unit(self) -> str:
        """One worker subprocess -> one registered heartbeat-proxied unit."""
        handle = self._spawn()
        name = f"{self.unit_prefix}{self._next_id}"
        self._next_id += 1
        try:
            self.runtime.register_unit(name, "cc",
                                       backend=self.spec_for(handle))
        except Exception:
            handle.terminate()
            raise
        self._handles[name] = handle
        self._events.append({"t": time.perf_counter(), "action": "join",
                             "unit": name})
        return name

    def drain_unit(self, name: str) -> None:
        """Graceful scale-down: deregister, then terminate the process."""
        handle = self._handles.pop(name, None)
        if handle is None:
            raise KeyError(f"unknown fleet unit {name!r}")
        self.runtime.deregister_unit(name)
        handle.terminate()
        self._events.append({"t": time.perf_counter(), "action": "leave",
                             "unit": name})

    def kill_unit(self, name: str) -> None:
        """SIGKILL the worker but keep its registration — the crash is
        for the transport/engine layers to detect and retire.  Fault
        injection for tests, mostly."""
        handle = self._handles.get(name)
        if handle is None:
            raise KeyError(f"unknown fleet unit {name!r}")
        handle.kill()
        self._events.append({"t": time.perf_counter(), "action": "kill",
                             "unit": name})

    def reap(self) -> List[str]:
        """Deregister members whose process already exited (killed or
        crashed on their own).  Returns the reaped names."""
        gone = sorted(n for n, h in self._handles.items() if not h.alive)
        for name in gone:
            self._handles.pop(name)
            self.runtime.deregister_unit(name)
            self._events.append({"t": time.perf_counter(), "action": "dead",
                                 "unit": name})
        return gone

    def scale_to(self, n: int) -> List[str]:
        """Spawn or drain until the fleet has exactly ``n`` members.
        Returns the names touched.  Drains retire the newest members
        first (oldest members have the warmest caches)."""
        if n < 0:
            raise ValueError(f"fleet size must be >= 0, got {n}")
        touched: List[str] = []
        while len(self._handles) < n:
            touched.append(self.spawn_unit())
        for name in sorted(self._handles, reverse=True)[:len(self._handles) - n]:
            self.drain_unit(name)
            touched.append(name)
        return touched

    def autoscale_step(self, queue_depth: int,
                       now: Optional[float] = None) -> int:
        """One policy tick: ask the attached :class:`Autoscaler` for a
        delta at the observed ``queue_depth`` and apply it.  Returns the
        applied delta (0 without an autoscaler or on hold)."""
        if self.autoscaler is None:
            return 0
        t = time.perf_counter() if now is None else now
        delta = self.autoscaler.decide(t, queue_depth=queue_depth,
                                       n_units=len(self._handles))
        if delta:
            self.scale_to(len(self._handles) + delta)
        return delta

    # -- views & lifecycle --------------------------------------------------
    @property
    def members(self) -> List[str]:
        return sorted(self._handles)

    @property
    def events(self) -> List[dict]:
        return list(self._events)

    def handle(self, name: str) -> WorkerHandle:
        return self._handles[name]

    def __len__(self) -> int:
        return len(self._handles)

    def shutdown(self) -> None:
        for name in sorted(self._handles):
            handle = self._handles.pop(name)
            try:
                self.runtime.deregister_unit(name)
            except KeyError:
                pass
            handle.terminate()

    def __enter__(self) -> "FleetManager":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

"""Heterogeneous throughput modelling and work partitioning.

At pod scale the ENEAC "CC vs ACC" split becomes *data-parallel groups of
unequal throughput*: mixed TPU generations, thermally throttled hosts, or
transient stragglers.  SPMD lock-step means every collective waits for the
slowest group, so the only lever is the one the paper identifies: give each
unit an amount of work proportional to its measured throughput so that all
units finish a step at the same time.

The iteration space is the step's *microbatches* (gradient-accumulation
chunks — the direct analogue of the paper's iteration chunks): each group
runs ``k_g`` microbatches of a fixed shape (fixed shape ⇒ one compiled
executable, no recompile churn) and contributes gradients weighted by the
tokens it actually processed, keeping the global gradient unbiased.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

__all__ = ["ThroughputTracker", "HeteroPartition", "HeterogeneousPartitioner"]


class ThroughputTracker:
    """EWMA throughput per group — the runtime feedback of MultiDynamic."""

    def __init__(self, alpha: float = 0.3) -> None:
        self.alpha = alpha
        self._tp: Dict[str, float] = {}

    def update(self, group: str, items: float, elapsed: float) -> float:
        inst = items / max(elapsed, 1e-12)
        prev = self._tp.get(group)
        self._tp[group] = inst if prev is None else self.alpha * inst + (1 - self.alpha) * prev
        return self._tp[group]

    def get(self, group: str, default: float = 1.0) -> float:
        return self._tp.get(group, default)

    def snapshot(self) -> Dict[str, float]:
        return dict(self._tp)


@dataclass(frozen=True)
class HeteroPartition:
    """An integer split of ``total_microbatches`` across groups."""

    counts: Dict[str, int]
    # gradient weight per group = fraction of total tokens it processed;
    # used to de-bias the gradient average when counts differ.
    weights: Dict[str, float]

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def max_over_min(self) -> float:
        vals = [v for v in self.counts.values() if v > 0]
        return max(vals) / min(vals) if vals else 1.0


class HeterogeneousPartitioner:
    """Throughput-proportional integer partition with hysteresis.

    * proportional share via the largest-remainder (Hamilton) method so the
      counts always sum exactly to ``total``;
    * every healthy group gets at least ``min_per_group`` (a group with 0
      microbatches would idle through the collectives anyway);
    * hysteresis: a new partition is only adopted if some group's count
      changes by more than ``rebalance_threshold`` (relative), avoiding
      flapping from throughput noise — the scheduling analogue of the
      paper's observation that chunk-size churn hurts regular workloads.
    """

    def __init__(
        self,
        *,
        min_per_group: int = 1,
        rebalance_threshold: float = 0.25,
    ) -> None:
        self.min_per_group = min_per_group
        self.rebalance_threshold = rebalance_threshold
        self._current: Optional[HeteroPartition] = None

    # -- pure computation ------------------------------------------------
    def proportional(self, total: int, throughputs: Dict[str, float]) -> HeteroPartition:
        groups = sorted(throughputs)
        n = len(groups)
        if n == 0:
            raise ValueError("no groups")
        if total < n * self.min_per_group:
            raise ValueError(
                f"total={total} microbatches cannot give {n} groups "
                f">= {self.min_per_group} each"
            )
        tsum = sum(max(throughputs[g], 1e-12) for g in groups)
        # Reserve the minimum, distribute the rest proportionally.
        reserve = n * self.min_per_group
        spare = total - reserve
        quotas = {g: spare * max(throughputs[g], 1e-12) / tsum for g in groups}
        counts = {g: self.min_per_group + int(math.floor(quotas[g])) for g in groups}
        leftover = total - sum(counts.values())
        # Largest remainder
        remainders = sorted(groups, key=lambda g: quotas[g] - math.floor(quotas[g]), reverse=True)
        for g in remainders[:leftover]:
            counts[g] += 1
        weights = {g: counts[g] / total for g in groups}
        return HeteroPartition(counts=counts, weights=weights)

    # -- stateful with hysteresis -----------------------------------------
    def update(self, total: int, throughputs: Dict[str, float]) -> HeteroPartition:
        proposed = self.proportional(total, throughputs)
        if self._current is None or set(self._current.counts) != set(proposed.counts):
            self._current = proposed
            return proposed
        # adopt only if materially different
        for g, new in proposed.counts.items():
            old = self._current.counts[g]
            if old == 0 or abs(new - old) / max(old, 1) > self.rebalance_threshold:
                self._current = proposed
                return proposed
        return self._current

    @property
    def current(self) -> Optional[HeteroPartition]:
        return self._current

    # -- analysis ----------------------------------------------------------
    @staticmethod
    def step_time(partition: HeteroPartition, throughputs: Dict[str, float]) -> float:
        """Predicted step wall time = slowest group's time (SPMD lock-step)."""
        return max(
            partition.counts[g] / max(throughputs.get(g, 1e-12), 1e-12)
            for g in partition.counts
        )

    @staticmethod
    def uniform(total: int, groups: Sequence[str]) -> HeteroPartition:
        """The homogeneous baseline every framework ships."""
        n = len(groups)
        base = total // n
        rem = total % n
        counts = {g: base + (1 if i < rem else 0) for i, g in enumerate(sorted(groups))}
        weights = {g: counts[g] / total for g in counts}
        return HeteroPartition(counts=counts, weights=weights)

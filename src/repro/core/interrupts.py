"""Completion-driven execution engine (ENEAC §3.2 interrupt mechanism).

The paper attaches a dedicated hardware interrupt controller + software
driver + host thread to *each* FPGA accelerator, so (a) every accelerator
runs fully asynchronously and (b) the host thread that offloaded a chunk
sleeps until the interrupt fires instead of burning a CPU core polling.

TPU/JAX adaptation: there are no user-visible interrupts, but JAX's async
dispatch gives the same structure — device work is enqueued and the host
is only blocked when it *chooses* to synchronize.  We reify the paper's
design as:

* :class:`CompletionEvent` — the interrupt analogue: ``fire()`` from the
  completion context (device callback, worker thread), ``wait()`` from the
  offloading host thread which *sleeps* on a condition variable.
* :class:`AsyncEngine` — one host thread per compute unit (exactly the
  paper's per-accelerator host thread), each looping: request chunk from
  the scheduler → dispatch → sleep until completion → report → repeat.
* :class:`PollingEngine` — the "no interrupts" baseline of Table-1 configs
  (4) and (6): a single host thread busy-spins over the units checking for
  completion, stealing cycles from the CC workers.  For the benchmark we
  model the steal by running CC work on the *same* thread that polls.

Both engines drive the *same* :class:`~repro.core.scheduler.MultiDynamicScheduler`,
so the Table-1 reproduction isolates the interrupt mechanism exactly as the
paper does (config (6) vs (7), (4) vs (5)).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .scheduler import Chunk, MultiDynamicScheduler

__all__ = ["CompletionEvent", "AsyncEngine", "PollingEngine", "RunReport"]


class CompletionEvent:
    """Interrupt analogue: host thread sleeps, completion context wakes it."""

    def __init__(self) -> None:
        self._cond = threading.Condition()
        self._fired = False
        self._payload = None

    def fire(self, payload=None) -> None:
        with self._cond:
            self._fired = True
            self._payload = payload
            self._cond.notify_all()

    def wait(self, timeout: Optional[float] = None):
        with self._cond:
            if not self._cond.wait_for(lambda: self._fired, timeout=timeout):
                raise TimeoutError("completion event did not fire")
            return self._payload

    def reset(self) -> None:
        with self._cond:
            self._fired = False
            self._payload = None


@dataclass
class RunReport:
    wall_time: float
    items: int
    chunks: int
    per_worker_items: Dict[str, int]
    per_worker_chunks: Dict[str, int]
    per_worker_busy: Dict[str, float]
    load_balance: float
    # Sorted (start, stop) spans of completed chunks; filled by
    # :class:`repro.core.runtime.HeteroRuntime` (None for bare engine runs).
    coverage: Optional[List[tuple]] = None
    # Elasticity timeline: one dict per unit join/leave processed during the
    # run — {"t", "action", "unit", "requeued": (start, stop) | None}.
    events: Optional[List[dict]] = None
    # Per-shard sub-reports when the run iterated a ShardedSpace; unit keys
    # in the merged per_worker_* maps are prefixed "s{shard}/".
    shard_reports: Optional[List["RunReport"]] = None
    # Mean submit->execution-start latency per unit in seconds, measured by
    # the backend layer (wall-clock interrupt runs only; None otherwise).
    # Low values with overlapping busy times are what "real asynchrony"
    # looks like: the dispatcher never sits between a free unit and work.
    dispatch_latency: Optional[Dict[str, float]] = None
    # The wire + remote-queue component of dispatch_latency for units that
    # executed behind a transport (repro.core.transport.RemoteUnit): mean
    # first-send -> remote-execution-start seconds per unit.  When several
    # chunks shared one work_batch frame (batch_frames > 1), the frame's
    # transit time is attributed per chunk — divided by the number of
    # chunks in the frame — so summing a batch's samples counts the wire
    # hop exactly once instead of once per chunk; the remote queue wait
    # remains genuinely per-chunk.  The local queue component is
    # dispatch_latency[u] - wire_latency[u].  None when no remote unit
    # took part in the run.  Measured by differencing client- and
    # worker-side monotonic clocks, so only meaningful when both share a
    # machine (worker subprocesses).
    wire_latency: Optional[Dict[str, float]] = None
    # Effective frame-coalescing width per transport-backed unit at run
    # end.  For a fixed ``batch_frames=N`` RemoteUnit this is just N; for
    # ``batch_frames="auto"`` it is the converged adaptive value (learned
    # wire transit vs. per-chunk service time, re-evaluated at flush
    # boundaries).  None when no transport unit took part in the run.
    batch_frames: Optional[Dict[str, int]] = None

    @property
    def throughput(self) -> float:
        """Items per millisecond — the paper's metric."""
        return self.items / max(self.wall_time * 1e3, 1e-12)

    @property
    def num_shards(self) -> int:
        return len(self.shard_reports) if self.shard_reports else 1

    @property
    def cross_shard_balance(self) -> float:
        """max shard makespan / mean shard makespan (1.0 = perfect).

        The sharded analogue of ``load_balance``: how evenly the global
        space was split across host shards, each of which load-balances
        internally via its own scheduler.
        """
        if not self.shard_reports:
            return 1.0
        spans = [r.wall_time for r in self.shard_reports]
        mean = sum(spans) / len(spans)
        return max(spans) / max(mean, 1e-12)

    @property
    def makespan(self) -> float:
        """Wall (or virtual) time from first dispatch to last completion."""
        return self.wall_time

    @property
    def utilization(self) -> Dict[str, float]:
        """Busy fraction per unit over the run's makespan."""
        w = max(self.wall_time, 1e-12)
        return {n: min(b / w, 1.0) for n, b in self.per_worker_busy.items()}


WorkFn = Callable[[Chunk], None]


class AsyncEngine:
    """Per-unit host threads + completion events (the paper's §3.2 design).

    ``work_fns[name]`` performs one chunk on unit ``name`` and returns when
    the unit's result is available (for JAX work this is where the function
    calls ``block_until_ready`` on *its own* stream — other units keep
    running, which is the entire point).
    """

    def __init__(self, scheduler: MultiDynamicScheduler, work_fns: Dict[str, WorkFn]) -> None:
        self.scheduler = scheduler
        self.work_fns = work_fns
        missing = set(scheduler.workers) - set(work_fns)
        if missing:
            raise ValueError(f"no work_fn for workers {sorted(missing)}")
        self._errors: List[BaseException] = []
        self._error_lock = threading.Lock()

    def _host_thread(self, name: str) -> None:
        fn = self.work_fns[name]
        while True:
            chunk = self.scheduler.next_chunk(name, now=time.perf_counter())
            if chunk is None:
                return
            t0 = time.perf_counter()
            try:
                fn(chunk)
            except BaseException as exc:  # propagate to .run()
                with self._error_lock:
                    self._errors.append(exc)
                self.scheduler.complete(name, time.perf_counter() - t0)
                return
            self.scheduler.complete(name, time.perf_counter() - t0)

    def run(self) -> RunReport:
        t0 = time.perf_counter()
        threads = [
            threading.Thread(target=self._host_thread, args=(name,), name=f"eneac-{name}")
            for name in self.scheduler.workers
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if self._errors:
            raise self._errors[0]
        wall = time.perf_counter() - t0
        return self._report(wall)

    def _report(self, wall: float) -> RunReport:
        states = self.scheduler.workers
        return RunReport(
            wall_time=wall,
            items=sum(w.items_done for w in states.values()),
            chunks=sum(w.chunks_done for w in states.values()),
            per_worker_items={n: w.items_done for n, w in states.items()},
            per_worker_chunks={n: w.chunks_done for n, w in states.items()},
            per_worker_busy={n: w.total_busy_time for n, w in states.items()},
            load_balance=self.scheduler.load_balance(),
        )


class PollingEngine:
    """Busy-wait baseline (Table-1 configs without interrupts).

    A single host thread drives every unit round-robin: it dispatches ACC
    chunks asynchronously but must *poll* for their completion, and while it
    polls it is the same thread that would execute CC chunks — so CC
    throughput is stolen by the polling loop.  We model the paper's
    measured behaviour by executing all work on the one driver thread:
    ACC work still completes at ACC speed (the accelerator itself is
    asynchronous) but the host serializes dispatch/poll/CC-work.
    """

    def __init__(
        self,
        scheduler: MultiDynamicScheduler,
        work_fns: Dict[str, WorkFn],
        poll_interval: float = 0.0,
    ) -> None:
        self.scheduler = scheduler
        self.work_fns = work_fns
        self.poll_interval = poll_interval

    def run(self) -> RunReport:
        t0 = time.perf_counter()
        names = list(self.scheduler.workers)
        active = True
        while active:
            active = False
            for name in names:
                chunk = self.scheduler.next_chunk(name, now=time.perf_counter())
                if chunk is None:
                    continue
                active = True
                c0 = time.perf_counter()
                self.work_fns[name](chunk)  # serialized on the driver thread
                if self.poll_interval:
                    time.sleep(self.poll_interval)
                self.scheduler.complete(name, time.perf_counter() - c0)
        wall = time.perf_counter() - t0
        states = self.scheduler.workers
        return RunReport(
            wall_time=wall,
            items=sum(w.items_done for w in states.values()),
            chunks=sum(w.chunks_done for w in states.values()),
            per_worker_items={n: w.items_done for n, w in states.items()},
            per_worker_chunks={n: w.chunks_done for n, w in states.items()},
            per_worker_busy={n: w.total_busy_time for n, w in states.items()},
            load_balance=self.scheduler.load_balance(),
        )

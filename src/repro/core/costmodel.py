"""Online per-(unit, kernel) cost model — measured capability descriptors.

The oracle/static policies split the iteration space from *user-supplied*
throughputs; the paper can do that because it calibrates each FPGA block
offline.  Production units drift (thermal throttling, contended hosts,
changed kernels), so the ROADMAP's answer is to *measure*: every
:class:`~repro.core.interrupts.RunReport` already carries per-unit items,
busy time, dispatch latency, and wire latency — exactly the observations
a per-(unit, kernel) capability descriptor needs.  This module turns that
history into a reusable model, in the shape of lumos's per-unit-class
``HeterogSys`` budgets and the Zynq coarse-grain performance estimator
(arXiv:1508.06830):

* :class:`CostEntry` — the capability descriptor for one (unit, kernel)
  pair: EWMA throughput (items/s), EWMA dispatch latency, EWMA wire
  latency, and the sample/item counts behind them.
* :class:`CostModel` — the store: ``observe_report(report, kernel)``
  folds a finished run in (the runtime calls it after every
  ``parallel_for``), ``lookup(unit, kernel)`` returns the descriptor,
  ``speeds(units, kernel)`` feeds the ``policy="learned"`` split, and
  ``save()``/construction-time load persist the model across runs as a
  versioned JSON artifact (schema :data:`STORE_SCHEMA`).  A corrupted or
  version-mismatched store never crashes a run: it warns
  (:class:`CostModelWarning`) and cold-starts.

Shard handling: a :class:`~repro.core.space.ShardedSpace` run namespaces
its merged per-unit maps ``s{k}/{unit}``, but the physical unit behind
``s0/acc0`` and the one behind a later non-sharded ``acc0`` run are the
same resource.  :func:`base_unit_name` strips the shard prefix before
any observation lands, so one physical unit never fragments into ``k``
phantom entries (pinned by ``tests/test_costmodel.py``).

Everything here is pure host-side bookkeeping — no jax, no threads of
its own; a single lock makes observation safe from engine callbacks.
"""

from __future__ import annotations

import json
import math
import os
import re
import threading
import warnings
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "CostEntry",
    "CostModel",
    "CostModelWarning",
    "STORE_SCHEMA",
    "base_unit_name",
]

STORE_SCHEMA = "costmodel/v1"

# Merged shard reports prefix unit keys "s{k}/"; one level, never nested.
_SHARD_PREFIX = re.compile(r"^s\d+/")


class CostModelWarning(UserWarning):
    """A persisted cost store could not be used and was cold-started."""


def base_unit_name(name: str) -> str:
    """Strip a ``s{k}/`` shard prefix: the physical unit's stable key.

    ``s0/acc0`` and ``s3/acc0`` are shard-engine views of the same
    ``acc0`` resource; learning must merge them, not fragment them.
    Names that carry no shard prefix pass through unchanged.
    """
    return _SHARD_PREFIX.sub("", name)


@dataclass
class CostEntry:
    """Capability descriptor for one (unit, kernel) pair.

    ``throughput`` is items/second, EWMA over run-level observations;
    ``dispatch_latency`` / ``wire_latency`` are EWMA seconds (None until
    the backend layer has produced a sample — simulated runs never do).
    ``samples`` counts observations, ``items`` the cumulative items they
    covered.
    """

    unit: str
    kernel: str
    throughput: Optional[float] = None
    dispatch_latency: Optional[float] = None
    wire_latency: Optional[float] = None
    samples: int = 0
    items: int = 0

    def seconds_for(self, items: int) -> Optional[float]:
        """Predicted execution seconds for ``items`` on this unit."""
        if not self.throughput:
            return None
        return items / self.throughput

    def overhead(self) -> float:
        """Fixed per-chunk seconds before work lands on the unit.

        Dispatch latency (submit -> executing) already *contains* the
        outbound wire time for remote units, so the two terms are not
        additive: take the larger of the learned values.  0.0 when the
        backend layer has produced no latency sample (simulated runs).
        """
        return max(self.dispatch_latency or 0.0, self.wire_latency or 0.0, 0.0)

    def predict(self, items: int, *, chunks: int = 1) -> Optional[float]:
        """Predicted completion seconds for ``items`` issued as ``chunks``
        dispatches: execution time plus per-chunk dispatch+wire overhead.
        None until a throughput has been learned."""
        exec_s = self.seconds_for(items)
        if exec_s is None:
            return None
        return exec_s + max(int(chunks), 0) * self.overhead()


class CostModel:
    """EWMA cost store learned from :class:`RunReport` history.

    ``path`` enables persistence: an existing store is loaded eagerly at
    construction (corruption or a schema mismatch warns and cold-starts
    instead of raising — a stale store must never block a run) and
    :meth:`save` writes the current state back atomically.  ``alpha`` is
    the EWMA smoothing factor shared by every entry.
    """

    def __init__(self, path: Optional[str] = None, *, alpha: float = 0.4) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.path = os.fspath(path) if path is not None else None
        self.alpha = float(alpha)
        self._lock = threading.Lock()
        self._entries: Dict[Tuple[str, str], CostEntry] = {}
        if self.path is not None and os.path.exists(self.path):
            self._load(self.path)

    # -- observation ---------------------------------------------------------
    def _entry(self, unit: str, kernel: str) -> CostEntry:
        key = (base_unit_name(unit), str(kernel))
        entry = self._entries.get(key)
        if entry is None:
            entry = CostEntry(unit=key[0], kernel=key[1])
            self._entries[key] = entry
        return entry

    def _ewma(self, prev: Optional[float], value: float) -> float:
        if prev is None:
            return value
        return self.alpha * value + (1 - self.alpha) * prev

    def observe(self, unit: str, kernel: str, *, items: int, elapsed: float) -> float:
        """Record ``items`` completed in ``elapsed`` busy seconds; returns
        the updated EWMA throughput (items/s)."""
        if items <= 0:
            raise ValueError(f"items must be positive, got {items}")
        inst = items / max(elapsed, 1e-12)
        with self._lock:
            entry = self._entry(unit, kernel)
            entry.throughput = self._ewma(entry.throughput, inst)
            entry.samples += 1
            entry.items += int(items)
            return entry.throughput

    def observe_latency(
        self, unit: str, kernel: str, *,
        dispatch: Optional[float] = None, wire: Optional[float] = None,
    ) -> None:
        """Fold backend-layer latency samples (seconds) into the entry."""
        with self._lock:
            entry = self._entry(unit, kernel)
            if dispatch is not None:
                entry.dispatch_latency = self._ewma(entry.dispatch_latency,
                                                    float(dispatch))
            if wire is not None:
                entry.wire_latency = self._ewma(entry.wire_latency, float(wire))

    def observe_report(self, report, kernel: str = "default") -> None:
        """Fold one finished run into the model.

        Per-unit items/busy become a throughput observation; the
        ``dispatch_latency`` / ``wire_latency`` maps become latency
        observations.  Shard-prefixed keys (``s{k}/unit``) are merged
        onto the physical unit name *before* the EWMA update: items and
        busy time sum across shards, latencies average across the shard
        replicas that produced samples.
        """
        items: Dict[str, int] = {}
        busy: Dict[str, float] = {}
        for name, n in (report.per_worker_items or {}).items():
            items[base_unit_name(name)] = items.get(base_unit_name(name), 0) + n
        for name, b in (report.per_worker_busy or {}).items():
            busy[base_unit_name(name)] = busy.get(base_unit_name(name), 0.0) + b
        for name, n in items.items():
            if n > 0 and busy.get(name, 0.0) > 0.0:
                self.observe(name, kernel, items=n, elapsed=busy[name])
        for attr, field in (("dispatch_latency", "dispatch"),
                            ("wire_latency", "wire")):
            merged: Dict[str, List[float]] = {}
            for name, v in (getattr(report, attr, None) or {}).items():
                merged.setdefault(base_unit_name(name), []).append(float(v))
            for name, vals in merged.items():
                if name in items and items[name] > 0:
                    self.observe_latency(
                        name, kernel, **{field: sum(vals) / len(vals)}
                    )

    def forget(self, unit: str, kernel: Optional[str] = None) -> None:
        """Drop entries for ``unit`` (one kernel, or all when None)."""
        base = base_unit_name(unit)
        with self._lock:
            gone = [k for k in self._entries
                    if k[0] == base and (kernel is None or k[1] == kernel)]
            for k in gone:
                del self._entries[k]

    # -- queries -------------------------------------------------------------
    def lookup(self, unit: str, kernel: str) -> Optional[CostEntry]:
        """The capability descriptor for (unit, kernel), or None (a copy —
        callers cannot corrupt the model through it)."""
        with self._lock:
            entry = self._entries.get((base_unit_name(unit), str(kernel)))
            return CostEntry(**asdict(entry)) if entry is not None else None

    def throughput(self, unit: str, kernel: str,
                   default: Optional[float] = None) -> Optional[float]:
        entry = self.lookup(unit, kernel)
        if entry is None or entry.throughput is None:
            return default
        return entry.throughput

    def speeds(self, units: Sequence[str], kernel: str) -> Dict[str, float]:
        """Learned items/s for the given units — only those with data.

        The ``policy="learned"`` split uses this: when every unit has an
        entry the split is an oracle-style proportional pre-split over
        *measured* speeds; missing units mean cold start (adaptive
        fallback).
        """
        out: Dict[str, float] = {}
        for name in units:
            tp = self.throughput(name, kernel)
            if tp is not None and tp > 0:
                out[name] = tp
        return out

    def coverage(self, units: Sequence[str], kernel: str) -> bool:
        """True when every unit has a learned throughput for ``kernel``."""
        return len(self.speeds(units, kernel)) == len(set(units))

    def overheads(self, units: Sequence[str], kernel: str) -> Dict[str, float]:
        """Learned per-chunk dispatch+wire seconds for the given units.

        Every requested unit gets an entry (0.0 when nothing has been
        learned) — the latency-aware split treats missing data as free
        dispatch rather than excluding the unit.
        """
        out: Dict[str, float] = {}
        for name in units:
            entry = self.lookup(name, kernel)
            out[name] = entry.overhead() if entry is not None else 0.0
        return out

    def fleet_throughput(self, kernel: str) -> Optional[float]:
        """Mean learned items/s across units for ``kernel`` (None if no
        data) — the aggregate a serving admission policy predicts with.
        A measured 0.0 (stalled unit) counts as an observation; the
        result is floored so callers can divide by it."""
        with self._lock:
            vals = [e.throughput for (u, k), e in self._entries.items()
                    if k == kernel and e.throughput is not None]
        if not vals:
            return None
        return max(sum(vals) / len(vals), 1e-9)

    def predict_drain(self, kernel: str, items: int,
                      n_units: int) -> Optional[float]:
        """Predicted seconds to drain ``items`` across ``n_units`` workers.

        Uses the mean learned per-unit throughput for ``kernel``
        (:meth:`fleet_throughput`), so the estimate is for a fleet of
        *typical* units — the question an autoscaler asks ("at the
        current size, how long until the queue empties?"), not a
        per-unit placement question.  ``None`` until the model has at
        least one observation for the kernel.
        """
        if items <= 0:
            return 0.0
        if n_units <= 0:
            return math.inf
        per_unit = self.fleet_throughput(kernel)
        if per_unit is None:
            return None
        return float(items) / (per_unit * n_units)

    def kernels(self) -> List[str]:
        with self._lock:
            return sorted({k for _, k in self._entries})

    def entries(self) -> List[CostEntry]:
        with self._lock:
            return [CostEntry(**asdict(e)) for e in self._entries.values()]

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # -- persistence ---------------------------------------------------------
    def to_dict(self) -> dict:
        with self._lock:
            return {
                "schema": STORE_SCHEMA,
                "alpha": self.alpha,
                "entries": [asdict(e) for e in
                            sorted(self._entries.values(),
                                   key=lambda e: (e.unit, e.kernel))],
            }

    def save(self, path: Optional[str] = None) -> str:
        """Write the store atomically (tmp + rename); returns the path."""
        target = os.fspath(path) if path is not None else self.path
        if target is None:
            raise ValueError("no path: pass save(path) or CostModel(path=...)")
        tmp = f"{target}.tmp"
        with open(tmp, "w") as fh:
            json.dump(self.to_dict(), fh, indent=1, sort_keys=True)
            fh.write("\n")
        os.replace(tmp, target)
        return target

    def _load(self, path: str) -> None:
        try:
            with open(path) as fh:
                doc = json.load(fh)
            if not isinstance(doc, dict) or doc.get("schema") != STORE_SCHEMA:
                raise ValueError(
                    f"schema {doc.get('schema')!r} != {STORE_SCHEMA!r}"
                    if isinstance(doc, dict) else "store is not a JSON object"
                )
            entries = {}
            for raw in doc.get("entries", []):
                entry = CostEntry(**raw)
                entries[(entry.unit, entry.kernel)] = entry
        except Exception as exc:
            # Exception, not BaseException: a Ctrl-C or SystemExit during
            # load must propagate, not be swallowed into a cold start.
            warnings.warn(
                f"cost store {path!r} unusable ({exc}); cold-starting — "
                "learned splits fall back to adaptive until re-observed",
                CostModelWarning,
                stacklevel=3,
            )
            return
        with self._lock:
            self._entries = entries

    def describe(self) -> str:
        with self._lock:
            return (f"CostModel({len(self._entries)} entries, "
                    f"alpha={self.alpha}, path={self.path!r})")

"""Iteration spaces: what ``parallel_for`` iterates over.

ENEAC's scheduler operates on an abstract iteration space — the paper
runs it over HOTSPOT grid rows and SPMM sparse rows alike, because the
MultiDynamic loop only needs "hand me the next contiguous chunk of
indices".  This module makes that space a first-class object so the same
scheduler/engine machinery covers three shapes of work:

* :class:`FlatSpace` — the classic ``[0, N)`` range (rows, microbatches,
  request slots).  ``parallel_for(num_items=N)`` is sugar for it.
* :class:`TiledSpace` — a 2D element grid decomposed into tiles, for
  Pallas-kernel workloads (hotspot stencils, block-ELL SPMM): the
  scheduler sees a flat tile index, the work function decodes it back to
  ``(row_slice, col_slice)`` element coordinates via :meth:`TiledSpace.
  tile_slices`.  Tile shape is the accelerator's native block (e.g. the
  MXU's (8, 128)), so an ACC chunk is a run of whole hardware tiles.
* :class:`ShardedSpace` — a global space partitioned across host shards.
  Each shard runs its *own* scheduler + engine over its contiguous slice
  (the multi-device extension of the paper's single-SoC loop, after
  arXiv:1802.03316), and the runtime merges the per-shard
  :class:`~repro.core.interrupts.RunReport`s into one global report with
  per-shard coverage and cross-shard load balance.

Spaces are pure host-side index arithmetic — no jax, no threads — so
they compose with every policy, engine, and clock.
"""

from __future__ import annotations

import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

from .scheduler import Chunk

__all__ = ["IterationSpace", "FlatSpace", "TiledSpace", "ShardedSpace", "as_space"]


class IterationSpace:
    """Base: a finite, contiguously indexable space ``[0, num_items)``.

    Subclasses only add *interpretation* (what an index means) and
    *partitioning* (how the space splits across shards); chunking within
    a shard always stays with the scheduler.
    """

    num_items: int

    def __len__(self) -> int:
        return self.num_items

    def describe(self) -> str:
        return f"{type(self).__name__}({self.num_items})"


class FlatSpace(IterationSpace):
    """The paper's original ``[0, N)`` iteration space."""

    def __init__(self, num_items: int) -> None:
        if num_items <= 0:
            raise ValueError(f"num_items must be positive, got {num_items}")
        self.num_items = int(num_items)


class TiledSpace(IterationSpace):
    """A 2D element grid decomposed into scheduler-visible tiles.

    ``grid=(R, C)`` are element dimensions, ``tile=(tr, tc)`` the tile
    shape; the space has ``ceil(R/tr) * ceil(C/tc)`` items, one per tile,
    laid out row-major so a contiguous chunk is a run of tiles sweeping
    columns fastest (the cache/HBM-friendly order for stencils and
    block-ELL rows alike).  Edge tiles are clipped to the grid.
    """

    def __init__(self, grid: Tuple[int, int], tile: Tuple[int, int]) -> None:
        if len(grid) != 2 or len(tile) != 2:
            raise ValueError(f"grid/tile must be 2D, got {grid} / {tile}")
        if min(grid) <= 0 or min(tile) <= 0:
            raise ValueError(f"grid/tile entries must be positive: {grid} / {tile}")
        self.grid = (int(grid[0]), int(grid[1]))
        self.tile = (int(tile[0]), int(tile[1]))
        self.tiles = (
            math.ceil(self.grid[0] / self.tile[0]),
            math.ceil(self.grid[1] / self.tile[1]),
        )
        self.num_items = self.tiles[0] * self.tiles[1]

    def tile_index(self, i: int) -> Tuple[int, int]:
        """Flat item index -> (tile_row, tile_col)."""
        if not 0 <= i < self.num_items:
            raise IndexError(f"tile {i} outside [0, {self.num_items})")
        return divmod(i, self.tiles[1])

    def tile_slices(self, i: int) -> Tuple[slice, slice]:
        """Flat item index -> element ``(row_slice, col_slice)``, edge-clipped."""
        ti, tj = self.tile_index(i)
        r0, c0 = ti * self.tile[0], tj * self.tile[1]
        return (
            slice(r0, min(r0 + self.tile[0], self.grid[0])),
            slice(c0, min(c0 + self.tile[1], self.grid[1])),
        )

    def chunk_slices(self, chunk: Chunk) -> List[Tuple[slice, slice]]:
        """All element slices covered by a scheduler chunk, in issue order."""
        return [self.tile_slices(i) for i in chunk.indices()]

    def describe(self) -> str:
        return (
            f"TiledSpace(grid={self.grid}, tile={self.tile}, "
            f"tiles={self.tiles[0]}x{self.tiles[1]})"
        )


class ShardedSpace(IterationSpace):
    """A global space split into contiguous per-host shards.

    Each shard is scheduled *independently* — its own tracked scheduler
    and engine over ``[start_k, stop_k)``, with the full unit set
    replicated per shard (one host's worth of ACC+CC units each) — and
    the runtime's merge step reassembles a global report.  ``weights``
    skews the partition for known-heterogeneous hosts (items proportional
    to weight, largest-remainder rounding, every shard non-empty while
    items allow).

    The inner space may itself be a :class:`TiledSpace`, in which case
    shard slices are runs of tiles.

    ``placement`` pins compute units to shards: a ``{unit_name: shard}``
    mapping consumed by the runtime when it builds per-shard schedulers.
    Unpinned units are replicated onto every shard (the PR 3 default);
    pinned units are scheduled *only* by their shard's engine — required
    for real backend units (a device stream belongs to one host) and for
    remote units (``backend="remote:<host:port>"``: the worker behind
    the transport *is* a host, so exactly one shard engine may drive
    it), the shard-aware placement hook the ROADMAP names.
    """

    def __init__(
        self,
        inner: Union[int, IterationSpace],
        num_shards: int,
        *,
        weights: Sequence[float] = (),
        placement: Optional[Mapping[str, int]] = None,
    ) -> None:
        if isinstance(inner, ShardedSpace):
            raise TypeError("ShardedSpace cannot nest another ShardedSpace")
        self.inner: IterationSpace = (
            FlatSpace(inner) if isinstance(inner, int) else inner
        )
        if num_shards <= 0:
            raise ValueError(f"num_shards must be positive, got {num_shards}")
        if num_shards > self.inner.num_items:
            raise ValueError(
                f"{num_shards} shards for {self.inner.num_items} items: "
                "some shards would be empty"
            )
        self.num_shards = int(num_shards)
        self.num_items = self.inner.num_items
        if weights:
            if len(weights) != num_shards:
                raise ValueError(
                    f"{len(weights)} weights for {num_shards} shards"
                )
            if min(weights) <= 0:
                raise ValueError(f"weights must be positive: {list(weights)}")
            self.weights = tuple(float(w) for w in weights)
        else:
            self.weights = tuple(1.0 for _ in range(num_shards))
        if placement:
            bad = {u: k for u, k in placement.items()
                   if not 0 <= int(k) < num_shards}
            if bad:
                raise ValueError(
                    f"placement maps units to nonexistent shards: {bad} "
                    f"(have {num_shards} shards)"
                )
            self.placement: Optional[Dict[str, int]] = {
                str(u): int(k) for u, k in placement.items()
            }
        else:
            self.placement = None
        self._bounds = self._partition()

    def _partition(self) -> List[Tuple[int, int]]:
        n, total = self.num_items, sum(self.weights)
        # largest-remainder apportionment with a floor of 1 item per shard
        raw = [n * w / total for w in self.weights]
        counts = [max(1, int(r)) for r in raw]
        while sum(counts) > n:
            counts[counts.index(max(counts))] -= 1
        remainders = sorted(
            range(self.num_shards), key=lambda k: raw[k] - int(raw[k]), reverse=True
        )
        k = 0
        while sum(counts) < n:
            counts[remainders[k % self.num_shards]] += 1
            k += 1
        bounds, start = [], 0
        for c in counts:
            bounds.append((start, start + c))
            start += c
        assert start == n, (bounds, n)
        return bounds

    def shard_bounds(self, k: int) -> Tuple[int, int]:
        """Global ``(start, stop)`` of shard ``k``."""
        return self._bounds[k]

    @property
    def bounds(self) -> List[Tuple[int, int]]:
        return list(self._bounds)

    def shard_of(self, i: int) -> int:
        """Which shard owns global index ``i``."""
        for k, (a, b) in enumerate(self._bounds):
            if a <= i < b:
                return k
        raise IndexError(f"index {i} outside [0, {self.num_items})")

    def describe(self) -> str:
        return (
            f"ShardedSpace({self.inner.describe()}, num_shards={self.num_shards})"
        )


def as_space(space_or_n: Union[int, IterationSpace, None], num_items: int) -> IterationSpace:
    """Normalize ``parallel_for``'s (space, num_items) pair to a space."""
    if space_or_n is None:
        return FlatSpace(num_items)
    if isinstance(space_or_n, int):
        return FlatSpace(space_or_n)
    if isinstance(space_or_n, IterationSpace):
        if num_items and num_items != space_or_n.num_items:
            raise ValueError(
                f"num_items={num_items} contradicts {space_or_n.describe()}"
            )
        return space_or_n
    raise TypeError(f"not an IterationSpace: {space_or_n!r}")

"""Backend units: genuine asynchronous dispatch for wall-clock runs.

Before this module, a :class:`~repro.core.runtime.WallClock` run executed
every ``work_fn`` *inside* the engine's own threads — asynchrony was an
artifact of how :class:`~repro.core.interrupts.AsyncEngine` was written,
not a property of the compute units.  The paper's model (and HEROv2's
runtime) is the opposite: each heterogeneous processing unit is a real
execution resource with its own stream, the host *submits* work to it and
is told — asynchronously — when the unit finishes.  This module reifies
that boundary:

* :class:`BackendUnit` — the protocol: ``start(bus)`` /
  ``submit(chunk, work_fn)`` (non-blocking, future-style: completion is
  delivered to the run's :class:`CompletionBus`) / ``close()``.
* :class:`InlineUnit` — synchronous execution on the dispatcher thread
  (the degenerate backend: useful as a baseline for dispatch overhead and
  for deterministic engine tests).
* :class:`ThreadUnit` — one dedicated worker thread per unit, modelling a
  CPU core (the paper's CC).  The default wall-clock backend.
* :class:`ProcessPoolUnit` — a single-worker process pool, modelling a
  separate CPU (no GIL sharing).  Work functions must be picklable.
* :class:`JaxDeviceUnit` — dispatches the work function onto a jax
  device's stream: jitted calls return immediately (XLA async dispatch)
  and a waiter thread turns ``block_until_ready`` into the completion
  signal.  Degrades to :class:`ThreadUnit` semantics when jax is absent.
* :class:`~repro.core.transport.RemoteUnit` (in :mod:`repro.core.transport`)
  — the same protocol stretched across a process/host boundary: submits
  become frames on a :class:`~repro.core.transport.Transport`, and a
  worker connection drop surfaces as a :class:`WorkerLost` completion the
  engine answers by requeueing the in-flight chunk (see below).
* :class:`BackendEngine` — the event-driven dispatcher the runtime's
  ``_run_wall`` builds on: one loop thread hands each idle backend a
  chunk the moment it goes idle, completions arrive on a condition
  variable from the backends' real threads, and
  :class:`~repro.core.elastic.ElasticSchedule` join/leave events are
  applied mid-run under the tracked scheduler's lock so the exact-once
  coverage invariant holds under real concurrency.

Elastic semantics under a wall clock differ from the simulated abort
model in one deliberate way: a **leave retires the unit** — it stops
receiving chunks at the event time, but an in-flight chunk *completes
and counts*, because real device work cannot be recalled mid-stream.
(Under :class:`~repro.core.runtime.SimulatedClock` a leave models an
instantaneous FPGA reprogram: the in-flight chunk is requeued.)  A
departing unit's never-issued pre-split assignment is still drained
into the requeue buffer and served to survivors, and a joining unit is
given a fresh backend and starts stealing immediately — so work-function
side effects happen exactly once per index even under churn, which is
what `tests/test_backends.py` pins across randomized schedules.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

from .elastic import ElasticEvent
from .scheduler import Chunk

__all__ = [
    "BackendUnit",
    "CompletionBus",
    "CompletionRecord",
    "InlineUnit",
    "ThreadUnit",
    "ProcessPoolUnit",
    "JaxDeviceUnit",
    "WorkerLost",
    "WorkerDead",
    "BackendEngine",
    "BACKENDS",
    "make_backend",
]

WorkFn = Callable[[Chunk], Any]

BACKENDS = ("inline", "thread", "process", "jax", "remote")

# The full spec grammar, quoted once so every "unknown backend" error can
# list it (tests pin this — an unknown spec must teach the valid ones).
VALID_BACKEND_SPECS = (
    "'inline'", "'thread'/'threads'", "'process'/'processes'", "'jax'",
    "'remote:<host:port>' (optional '?batch_frames=N&fn_cache=0|1"
    "&heartbeat=SECS&patience=N' suffix)",
)

# Dispatch fast-path and liveness knobs accepted in a remote spec's
# query string.  ``heartbeat`` (float seconds) asks the worker for
# periodic liveness frames; ``patience`` is how many missed intervals
# convict the worker as dead.
REMOTE_SPEC_KNOBS = ("batch_frames", "fn_cache", "heartbeat", "patience")


class WorkerLost(ConnectionError):
    """A unit's execution medium died with a chunk possibly in flight.

    Posted as a :class:`CompletionRecord` error by transport-backed units
    (:class:`~repro.core.transport.RemoteUnit`) when the connection to
    their worker drops or retransmits are exhausted.  Unlike a work-
    function error — which fails the run — a lost worker is a *membership*
    event: :class:`BackendEngine` removes the unit and requeues its
    in-flight chunk to the survivors exactly once, the same path an
    elastic leave takes.
    """


class WorkerDead(WorkerLost):
    """Missed-heartbeat conviction: the worker went *silent*, it did not
    visibly drop the connection.

    Posted by a heartbeat-enabled :class:`~repro.core.transport.RemoteUnit`
    when the worker has sent nothing (heartbeats included) for
    ``patience`` intervals — the membership ledger's verdict, as opposed
    to the definitive EOF behind a plain :class:`WorkerLost`.  The engine
    handles both identically (remove + exact-once requeue) but records
    ``action="dead"`` instead of ``action="lost"`` so a report
    distinguishes silence from loss mid-chunk.
    """


@dataclass
class CompletionRecord:
    """One finished (or failed) submission, posted to the run's bus."""

    unit: str
    chunk: Chunk
    elapsed: float               # execution time (dispatch -> result ready)
    dispatch_latency: float      # submit() -> execution actually starting
    error: Optional[BaseException] = None
    result: Any = None           # work_fn return value (serving uses this)


class CompletionBus:
    """The interrupt line of a run: backends post, the engine sleeps.

    Sharded hot path: each unit posts into its own deque slot (append is
    GIL-atomic, no shared lock) and raises a single shared
    :class:`threading.Event` — only the first post after a drain pays the
    notify, subsequent posts are a plain attribute check.  ``wait`` and
    ``drain`` belong to the single consumer (the dispatcher thread); the
    drain clears the event *before* sweeping the slots so a post racing
    the sweep re-arms it and can never be silently lost.  This is the
    wall-clock materialization of the paper's per-accelerator interrupt —
    except one bus serves all units, which is exactly what lets the
    dispatcher hand out the next chunk to *whichever* unit finished
    first.

    ``register(unit)`` pre-creates a unit's slot; posts from units that
    never registered land in a shared default slot, so the API is
    drop-in for the previous condition-variable bus.
    """

    def __init__(self) -> None:
        self._event = threading.Event()
        self._lock = threading.Lock()          # slot registry only, not posts
        self._default: deque = deque()
        self._slots: Dict[str, deque] = {}
        # copy-on-write scan tuple: producers may register new slots while
        # the consumer sweeps, so the sweep iterates an immutable snapshot
        self._scan: Tuple[deque, ...] = (self._default,)

    def register(self, unit: str) -> None:
        """Idempotently create a dedicated slot for ``unit``."""
        with self._lock:
            if unit not in self._slots:
                self._slots[unit] = deque()
                self._scan = tuple(self._slots.values()) + (self._default,)

    def post(self, rec: CompletionRecord) -> None:
        slot = self._slots.get(rec.unit)
        if slot is None:
            slot = self._default
        slot.append(rec)
        if not self._event.is_set():
            self._event.set()

    def _pending(self) -> bool:
        for slot in self._scan:
            if slot:
                return True
        return False

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Sleep until at least one completion is pending (or timeout)."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        while True:
            if self._pending():
                return True
            # Eat a stale set, then re-check: a producer appends *before*
            # setting, so anything posted before the clear is visible to
            # the re-check, and anything after it re-sets the event.
            self._event.clear()
            if self._pending():
                return True
            if deadline is None:
                self._event.wait()
            else:
                remaining = deadline - time.perf_counter()
                if remaining <= 0 or not self._event.wait(remaining):
                    return self._pending()

    def drain(self) -> List[CompletionRecord]:
        self._event.clear()
        out: List[CompletionRecord] = []
        for slot in self._scan:
            while slot:
                try:
                    out.append(slot.popleft())
                except IndexError:  # pragma: no cover - single-consumer guard
                    break
        return out


class BackendUnit:
    """Protocol + shared bookkeeping for one asynchronously-driven unit.

    Lifecycle: ``start(bus)`` before the first submit (re-startable, so
    one instance can serve consecutive runs), ``submit(chunk, work_fn)``
    only while the unit has spare :attr:`capacity` (the engine polices
    this; plain units advertise ``capacity = 1``, i.e. one chunk in
    flight), ``close()`` at run end.  ``submit`` must not block on the
    work itself: completion is reported by posting a
    :class:`CompletionRecord` to the bus.

    Units that coalesce submissions (``capacity > 1``, e.g. a
    :class:`~repro.core.transport.RemoteUnit` with ``batch_frames > 1``)
    may buffer submits; the engine calls :meth:`flush` after each
    dispatch round to push out a partial batch.  For everything else
    ``flush`` is a no-op.
    """

    kind_name = "backend"
    #: max chunks the engine may keep in flight on this unit at once
    capacity = 1

    def __init__(self, name: str) -> None:
        self.name = name
        self._bus: Optional[CompletionBus] = None
        self.dispatch_latencies: List[float] = []

    # -- lifecycle ----------------------------------------------------------
    def start(self, bus: CompletionBus) -> None:
        self._bus = bus
        bus.register(self.name)
        self.dispatch_latencies = []

    def submit(self, chunk: Chunk, work_fn: WorkFn) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        """Push out any buffered submissions (no-op for unbatched units)."""

    def close(self) -> None:
        self._bus = None

    # -- shared helpers -----------------------------------------------------
    def _post(self, rec: CompletionRecord) -> None:
        assert self._bus is not None, f"unit {self.name!r} not started"
        self.dispatch_latencies.append(rec.dispatch_latency)
        self._bus.post(rec)

    def _execute(self, chunk: Chunk, work_fn: WorkFn, submitted: float) -> None:
        """Run one chunk synchronously and post the completion."""
        t_start = time.perf_counter()
        result, error = None, None
        try:
            result = work_fn(chunk)
        except BaseException as exc:
            error = exc
        t_end = time.perf_counter()
        self._post(CompletionRecord(
            unit=self.name, chunk=chunk, elapsed=t_end - t_start,
            dispatch_latency=t_start - submitted, error=error, result=result,
        ))

    def describe(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


class InlineUnit(BackendUnit):
    """Synchronous execution on the dispatcher thread.

    The degenerate backend: no overlap, but identical submit/complete
    bookkeeping — the control for dispatch-latency measurements and the
    deterministic option for engine unit tests.
    """

    kind_name = "inline"

    def submit(self, chunk: Chunk, work_fn: WorkFn) -> None:
        self._execute(chunk, work_fn, time.perf_counter())


class ThreadUnit(BackendUnit):
    """A dedicated worker thread per unit — the default real backend.

    ``submit`` enqueues and returns immediately; the worker executes and
    posts the completion.  Dispatch latency is queue wait: submit time to
    execution start.
    """

    kind_name = "thread"

    def __init__(self, name: str) -> None:
        super().__init__(name)
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None

    def start(self, bus: CompletionBus) -> None:
        super().start(bus)
        if self._thread is None or not self._thread.is_alive():
            self._queue = queue.Queue()
            self._thread = threading.Thread(
                target=self._worker, name=f"eneac-unit-{self.name}", daemon=True
            )
            self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            submitted, chunk, work_fn = item
            self._execute(chunk, work_fn, submitted)

    def submit(self, chunk: Chunk, work_fn: WorkFn) -> None:
        assert self._queue is not None, f"unit {self.name!r} not started"
        self._queue.put((time.perf_counter(), chunk, work_fn))

    def close(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._queue.put(None)
            self._thread.join(timeout=10.0)
        self._thread = None
        self._queue = None
        super().close()


def _process_entry(work_fn: WorkFn, chunk: Chunk, submitted: float):
    """Runs in the pool worker; perf_counter is CLOCK_MONOTONIC, which is
    system-wide on Linux, so the dispatch latency spans the process hop."""
    t_start = time.perf_counter()
    result = work_fn(chunk)
    t_end = time.perf_counter()
    return result, t_end - t_start, t_start - submitted


class ProcessPoolUnit(BackendUnit):
    """A single-worker process pool — multi-process CPU dispatch.

    Work functions (and their closures) must be picklable, and side
    effects land in the *worker* process: callers get results back via
    :attr:`CompletionRecord.result`, not shared memory.  If the host
    cannot spawn processes (sandboxed CI), the unit degrades to in-thread
    execution and sets :attr:`degraded`.
    """

    kind_name = "process"

    def __init__(self, name: str) -> None:
        super().__init__(name)
        self._pool = None
        self.degraded = False
        self._fallback: Optional[ThreadUnit] = None

    def start(self, bus: CompletionBus) -> None:
        super().start(bus)
        if self._pool is None and not self.degraded:
            try:
                import multiprocessing
                from concurrent.futures import ProcessPoolExecutor

                # spawn, not fork: the host process carries jax/XLA threads
                # and forking a multithreaded process can deadlock
                self._pool = ProcessPoolExecutor(
                    max_workers=1,
                    mp_context=multiprocessing.get_context("spawn"),
                )
                # force worker spawn now so a broken sandbox fails fast
                self._pool.submit(int, 0).result(timeout=60)
            except BaseException:
                self._pool = None
                self.degraded = True
        if self.degraded:
            if self._fallback is None:
                self._fallback = ThreadUnit(self.name)
            self._fallback.start(bus)
            self._fallback.dispatch_latencies = self.dispatch_latencies

    def submit(self, chunk: Chunk, work_fn: WorkFn) -> None:
        if self.degraded:
            assert self._fallback is not None
            self._fallback.submit(chunk, work_fn)
            return
        submitted = time.perf_counter()
        fut = self._pool.submit(_process_entry, work_fn, chunk, submitted)

        def on_done(f, *, chunk=chunk) -> None:
            error, result, elapsed, lat = None, None, 0.0, 0.0
            try:
                result, elapsed, lat = f.result()
            except BaseException as exc:
                error = exc
                elapsed = time.perf_counter() - submitted
            self._post(CompletionRecord(
                unit=self.name, chunk=chunk, elapsed=elapsed,
                dispatch_latency=lat, error=error, result=result,
            ))

        fut.add_done_callback(on_done)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None
        if self._fallback is not None:
            self._fallback.close()
            self._fallback = None
        super().close()


def _jax_module():
    """Import hook the tests monkeypatch to simulate a jax-less host."""
    try:
        import jax
    except Exception:  # pragma: no cover - depends on environment
        return None
    return jax


class JaxDeviceUnit(BackendUnit):
    """Dispatch onto a jax device stream via non-blocking jit calls.

    ``submit`` invokes the work function under ``jax.default_device``:
    jitted computations are *enqueued* on the device and return
    placeholder arrays immediately (XLA async dispatch), so the dispatch
    call is cheap.  A waiter thread then calls ``block_until_ready`` on
    the returned arrays — that is the completion interrupt.  Work
    functions that return nothing are still correct (the waiter has
    nothing to block on, so completion fires after dispatch), but then
    the elapsed time only covers the host-side call.

    When jax is unavailable the unit degrades to a :class:`ThreadUnit`
    (synchronous execution on a dedicated thread) and sets
    :attr:`degraded` — callers keep working, just without device overlap.
    """

    kind_name = "jax"

    def __init__(self, name: str, device=None) -> None:
        super().__init__(name)
        self._requested_device = device
        self._device = None
        self.degraded = False
        self._fallback: Optional[ThreadUnit] = None
        self._waitq: Optional[queue.Queue] = None
        self._waiter: Optional[threading.Thread] = None
        self._jax = None

    def start(self, bus: CompletionBus) -> None:
        super().start(bus)
        self._jax = _jax_module()
        if self._jax is None:
            self.degraded = True
            if self._fallback is None:
                self._fallback = ThreadUnit(self.name)
            self._fallback.start(bus)
            self._fallback.dispatch_latencies = self.dispatch_latencies
            return
        if self._device is None:
            self._device = (
                self._requested_device
                if self._requested_device is not None
                else self._jax.devices()[0]
            )
        if self._waiter is None or not self._waiter.is_alive():
            self._waitq = queue.Queue()
            self._waiter = threading.Thread(
                target=self._wait_loop, name=f"eneac-jaxwait-{self.name}",
                daemon=True,
            )
            self._waiter.start()

    def _wait_loop(self) -> None:
        while True:
            item = self._waitq.get()
            if item is None:
                return
            submitted, dispatched, chunk, out, error = item
            if error is None:
                try:
                    self._jax.block_until_ready(out)
                except BaseException as exc:
                    error = exc
            t_end = time.perf_counter()
            self._post(CompletionRecord(
                unit=self.name, chunk=chunk, elapsed=t_end - dispatched,
                dispatch_latency=dispatched - submitted, error=error,
                result=out,
            ))

    def submit(self, chunk: Chunk, work_fn: WorkFn) -> None:
        if self.degraded:
            assert self._fallback is not None
            self._fallback.submit(chunk, work_fn)
            return
        submitted = time.perf_counter()
        out, error = None, None
        try:
            with self._jax.default_device(self._device):
                out = work_fn(chunk)  # jitted work: enqueued, not awaited
        except BaseException as exc:
            error = exc
        self._waitq.put((submitted, time.perf_counter(), chunk, out, error))

    def close(self) -> None:
        if self._waiter is not None and self._waiter.is_alive():
            self._waitq.put(None)
            self._waiter.join(timeout=10.0)
        self._waiter = None
        self._waitq = None
        if self._fallback is not None:
            self._fallback.close()
            self._fallback = None
        super().close()


def make_backend(spec: Union[str, BackendUnit, None], name: str) -> BackendUnit:
    """Normalize a backend spec (string / instance / None) to a unit.

    ``None`` means the runtime default — a :class:`ThreadUnit`, matching
    the paper's one-host-thread-per-unit design.
    """
    if isinstance(spec, BackendUnit):
        if spec.name != name:
            raise ValueError(
                f"backend unit is named {spec.name!r} but would back unit "
                f"{name!r}; names must match — completions are routed by "
                "unit name, and one backend instance can serve one unit only"
            )
        return spec
    if spec is None:
        return ThreadUnit(name)
    text = str(spec)
    if text.startswith("remote:"):
        address = text[len("remote:"):]
        opts: Dict[str, Any] = {}
        if "?" in address:
            address, _, query = address.partition("?")
            for part in query.split("&"):
                if not part:
                    continue
                key, _, value = part.partition("=")
                if key not in REMOTE_SPEC_KNOBS:
                    raise ValueError(
                        f"unknown remote backend knob {key!r} in {spec!r}: "
                        "valid knobs are " + ", ".join(REMOTE_SPEC_KNOBS)
                    )
                if key == "batch_frames" and value == "auto":
                    opts[key] = "auto"
                    continue
                if key == "heartbeat":
                    # the one float-valued knob: an interval in seconds
                    try:
                        opts[key] = float(value)
                    except ValueError:
                        raise ValueError(
                            f"remote backend knob heartbeat={value!r} in "
                            f"{spec!r} must be a number of seconds"
                        ) from None
                    if not opts[key] > 0:
                        raise ValueError(
                            f"remote backend knob heartbeat={value!r} in "
                            f"{spec!r} must be positive"
                        )
                    continue
                try:
                    opts[key] = int(value)
                except ValueError:
                    raise ValueError(
                        f"remote backend knob {key}={value!r} in {spec!r} "
                        "must be an integer"
                        + (" or 'auto'" if key == "batch_frames" else "")
                    ) from None
        if not address:
            raise ValueError(
                "remote backend spec needs a worker address: "
                "'remote:<host:port>'"
            )
        from .transport import RemoteUnit  # late: transport builds on this module
        return RemoteUnit(
            name, address=address,
            batch_frames=opts.get("batch_frames", 1),
            fn_cache=bool(opts.get("fn_cache", 1)),
            heartbeat=opts.get("heartbeat"),
            patience=int(opts.get("patience", 3)),
        )
    aliases = {
        "inline": InlineUnit,
        "thread": ThreadUnit, "threads": ThreadUnit,
        "process": ProcessPoolUnit, "processes": ProcessPoolUnit,
        "jax": JaxDeviceUnit,
    }
    cls = aliases.get(text)
    if cls is None:
        raise ValueError(
            f"unknown backend {spec!r}: valid specs are "
            + ", ".join(VALID_BACKEND_SPECS)
            + ", or a BackendUnit instance"
        )
    return cls(name)


# ---------------------------------------------------------------------------
# the event-driven wall-clock engine
# ---------------------------------------------------------------------------
class BackendEngine:
    """Completion-driven dispatcher over real backend units.

    The paper's Fig. 2 loop with the asynchrony made real: the dispatcher
    (caller thread) is the only client of the tracked scheduler — it
    hands each idle backend a chunk, sleeps on the :class:`CompletionBus`
    until any backend finishes (or the next elastic event is due), and
    applies membership changes between dispatches.  Because scheduler
    mutations are serialized on this thread *and* guarded by the tracked
    scheduler's internal lock, the exact-once coverage invariant holds
    even though executions genuinely overlap.

    ``elastic`` events use run-relative wall seconds.  Leave = retire
    (in-flight chunk completes and counts; pre-split leftovers are
    requeued); join = a fresh backend from ``join_backend`` starts
    stealing immediately.  Events due after full coverage are dropped.

    ``straggler`` attaches a
    :class:`~repro.core.straggler.StragglerDetector`: every successful
    completion feeds the unit's per-item service time, and a unit the
    detector convicts (EWMA over the fleet-median threshold for its
    configured consecutive patience) is *quarantined* — retired through
    the same path as an elastic leave, so the exact-once requeue
    invariant carries over unchanged.  At most one quarantine per unit
    per run; the last active unit is never quarantined (slow coverage
    beats no coverage).  Recorded as an ``action="straggler"`` event.
    """

    def __init__(
        self,
        sched,
        fns: Mapping[str, Optional[WorkFn]],
        units: Dict[str, BackendUnit],
        *,
        expected: int,
        elastic: Sequence[ElasticEvent] = (),
        default_fn: Optional[WorkFn] = None,
        join_backend: Optional[Callable[[ElasticEvent], BackendUnit]] = None,
        straggler=None,
    ) -> None:
        self.sched = sched
        self.fns: Dict[str, Optional[WorkFn]] = dict(fns)
        self.units = dict(units)
        self.expected = expected
        self.pending = sorted(elastic, key=lambda e: e.t)
        self.default_fn = default_fn
        self.join_backend = join_backend or (lambda ev: ThreadUnit(ev.unit))
        self.straggler = straggler
        self.bus = CompletionBus()
        self.events: List[dict] = []          # RunReport.events entries
        self._own_units = set()               # started here -> closed here
        self._all_units = dict(units)         # includes retired units (stats)
        self._inflight: Dict[str, int] = {}   # unit -> chunks in flight
        self._last_caps: Dict[str, int] = {}  # capacity last synced to sched
        self._leaving: set = set()
        self._straggled: set = set()
        self._errors: List[BaseException] = []
        self._t0 = 0.0

    # -- helpers ------------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _any_busy(self) -> bool:
        return any(self._inflight.values())

    def _capacity(self, name: str) -> int:
        unit = self.units.get(name)
        return max(int(getattr(unit, "capacity", 1) or 1), 1)

    def _dispatch(self, name: str) -> bool:
        """Fill ``name`` up to its capacity, then flush its send buffer.

        A ``capacity == 1`` unit behaves exactly as before: one chunk in
        flight, the next issued only after its completion is processed.
        A pipelined unit (e.g. RemoteUnit with ``batch_frames > 1``) is
        handed up to ``capacity`` chunks back-to-back so it can coalesce
        them into one wire frame; scheduler-visible granularity and
        per-chunk completion accounting are unchanged.
        """
        if name in self._leaving or name in self.sched.removed:
            return False
        issued = False
        cap = self._capacity(name)
        # Adaptive units (batch_frames="auto") re-size their capacity at
        # flush boundaries; the scheduler's in-flight cap must follow or
        # next_chunk raises "requested a chunk while busy" the moment the
        # unit grows past the capacity recorded at run start.
        if cap != self._last_caps.get(name):
            self._last_caps[name] = cap
            set_cap = getattr(self.sched, "set_capacity", None)
            if set_cap is not None:
                set_cap(name, cap)
        while self._inflight.get(name, 0) < cap:
            if self._errors:
                break
            chunk = self.sched.next_chunk(name, now=time.perf_counter())
            if chunk is None:
                break
            self._inflight[name] = self._inflight.get(name, 0) + 1
            self.units[name].submit(chunk, self.fns[name])
            issued = True
        if issued:
            self.units[name].flush()
        return issued

    def _dispatch_idle(self) -> bool:
        any_issued = False
        for name in list(self.units):
            if self._dispatch(name):
                any_issued = True
        return any_issued

    def _retire(self, name: str) -> None:
        """Finalize a leave: remove from the scheduler (requeues pre-split
        leftovers under its lock) and close the unit's backend."""
        self.sched.remove_unit(name)
        self._leaving.discard(name)
        unit = self.units.pop(name, None)
        if unit is not None and name in self._own_units:
            unit.close()

    def _apply_due_events(self) -> None:
        while self.pending and self.pending[0].t <= self._now():
            ev = self.pending.pop(0)
            if self.sched.items_done() >= self.expected:
                continue  # run already covered; stale membership event
            if ev.action == "leave":
                self.events.append({
                    "t": self._now(), "action": "leave", "unit": ev.unit,
                    "requeued": None,
                })
                if self._inflight.get(ev.unit, 0):
                    # real work cannot be recalled: retire after completion
                    self._leaving.add(ev.unit)
                else:
                    self._retire(ev.unit)
            else:
                unit = self.join_backend(ev)
                unit.start(self.bus)
                self.units[ev.unit] = unit
                self._all_units[ev.unit] = unit
                self._own_units.add(ev.unit)
                self.fns[ev.unit] = self.default_fn
                self.sched.add_unit(ev.unit, ev.kind, throughput=ev.speed)
                set_cap = getattr(self.sched, "set_capacity", None)
                if set_cap is not None:
                    set_cap(ev.unit, self._capacity(ev.unit))
                self.events.append({
                    "t": self._now(), "action": "join", "unit": ev.unit,
                    "requeued": None,
                })
                self._dispatch(ev.unit)

    def _lose_unit(self, rec: CompletionRecord) -> None:
        """The medium (not the code) lost this unit: requeue, don't fail.

        A transport-backed unit posts a :class:`WorkerLost` completion when
        its connection drops or retransmits are exhausted.  The chunk was
        *not* completed — so instead of ``complete()`` the unit is removed
        from the tracked scheduler, which moves its in-flight chunk (and
        any never-issued pre-split assignment) to the requeue buffer under
        the scheduler's lock: survivors pick the span up exactly once.
        Recorded as an ``action="lost"`` entry in ``RunReport.events``.
        """
        name = rec.unit
        already_lost = name not in self.units and name in self.sched.removed
        self._inflight.pop(name, None)
        self._leaving.discard(name)
        if name not in self.sched.removed:
            self.sched.remove_unit(name)
        unit = self.units.pop(name, None)
        if unit is not None and name in self._own_units:
            unit.close()
        if already_lost:
            # a second WorkerLost for the same unit (e.g. a batched frame's
            # failure posted per pending chunk): membership already handled
            return
        self.events.append({
            # "dead" = missed-heartbeat conviction (silence); "lost" =
            # definitive EOF / retransmit exhaustion (loss mid-chunk)
            "t": self._now(),
            "action": "dead" if isinstance(rec.error, WorkerDead) else "lost",
            "unit": name,
            "requeued": (rec.chunk.start, rec.chunk.stop)
            if rec.chunk is not None else None,
        })

    def _process_completions(self, recs: List[CompletionRecord]) -> None:
        for rec in recs:
            if isinstance(rec.error, WorkerLost):
                self._lose_unit(rec)
                continue
            if rec.unit in self.sched.removed:
                # completion raced a loss/retire whose in-flight span was
                # already requeued; counting it now would double-cover
                continue
            n = self._inflight.get(rec.unit, 0)
            if n > 1:
                self._inflight[rec.unit] = n - 1
            else:
                self._inflight.pop(rec.unit, None)
            self.sched.complete(rec.unit, rec.elapsed, chunk=rec.chunk)
            if rec.error is not None:
                self._errors.append(rec.error)
            if rec.unit in self._leaving and not self._inflight.get(rec.unit, 0):
                self._retire(rec.unit)
            elif rec.error is None:
                self._observe_straggler(rec)

    def _observe_straggler(self, rec: CompletionRecord) -> None:
        """Feed one completion's per-item service time to the detector and
        quarantine the unit on conviction.

        Quarantine reuses the retire path: the scheduler requeues any
        never-issued pre-split assignment under its lock, so survivors
        pick the span up exactly once — the elastic invariant, unchanged.
        The completion that convicts has already been counted (real work
        is never recalled).  Never convicts the last active unit, and at
        most once per unit per run; ``forget`` drops the departed unit's
        EWMA so its stale sample stops skewing the fleet median.
        """
        det = self.straggler
        if det is None or rec.chunk is None or rec.chunk.size <= 0:
            return
        name = rec.unit
        if name in self._straggled or name in self.sched.removed:
            return
        report = det.observe({name: rec.elapsed / rec.chunk.size})
        if name not in report.stragglers:
            return
        active = [n for n in self.units
                  if n not in self.sched.removed and n not in self._leaving]
        if name not in active or len(active) <= 1:
            return
        self._straggled.add(name)
        self.events.append({
            "t": self._now(), "action": "straggler", "unit": name,
            "requeued": None, "ratio": report.ratios.get(name),
        })
        if self._inflight.get(name, 0):
            # pipelined unit with other chunks still executing: retiring now
            # would requeue work that is in flight remotely (double
            # execution).  Quarantine = stop feeding it; retire on drain.
            self._leaving.add(name)
        else:
            self._retire(name)
        det.forget(name)

    # -- the loop -----------------------------------------------------------
    def run(self) -> float:
        """Drive the space to completion; returns the wall makespan."""
        self._t0 = time.perf_counter()
        set_cap = getattr(self.sched, "set_capacity", None)
        for name, unit in self.units.items():
            unit.start(self.bus)
            self._own_units.add(name)
            self._last_caps[name] = self._capacity(name)
            if set_cap is not None:
                set_cap(name, self._last_caps[name])
        try:
            self._apply_due_events()
            self._dispatch_idle()
            while True:
                if self._any_busy():
                    timeout = None
                    if self.pending:
                        timeout = max(self.pending[0].t - self._now(), 0.0)
                    self.bus.wait(timeout=timeout)
                    self._apply_due_events()
                    self._process_completions(self.bus.drain())
                    self._dispatch_idle()
                    continue
                # nothing in flight: either more work is dispatchable, or
                # we are waiting for a membership event, or we are done
                self._apply_due_events()
                if self._dispatch_idle():
                    continue
                if self._any_busy():
                    continue
                if (self.pending and not self._errors
                        and self.sched.items_done() < self.expected):
                    # idle until the next event (e.g. a rescuing join)
                    time.sleep(max(self.pending[0].t - self._now(), 0.0))
                    self._apply_due_events()
                    continue
                break
        finally:
            for name, unit in self.units.items():
                if name in self._own_units:
                    unit.close()
        if self._errors:
            raise self._errors[0]
        return time.perf_counter() - self._t0

    def dispatch_latency(self) -> Dict[str, float]:
        """Mean submit->execution latency per unit, in seconds."""
        out: Dict[str, float] = {}
        for name, unit in self._all_units.items():
            lats = unit.dispatch_latencies
            if lats:
                out[name] = sum(lats) / len(lats)
        for name in self.sched.workers:
            out.setdefault(name, 0.0)
        return out

    def wire_latency(self) -> Optional[Dict[str, float]]:
        """Mean send->remote-execution-start seconds per transport unit.

        Only units that went over a transport carry ``wire_latencies``
        (see :class:`~repro.core.transport.RemoteUnit`); for everything
        else the wire component of dispatch latency is zero by
        construction, so units without samples are omitted and the whole
        map is ``None`` when no remote unit took part.
        """
        out: Dict[str, float] = {}
        for name, unit in self._all_units.items():
            lats = getattr(unit, "wire_latencies", None)
            if lats:
                out[name] = sum(lats) / len(lats)
        return out or None

    def frame_batching(self) -> Optional[Dict[str, int]]:
        """Effective frame-coalescing width per transport unit at run end.

        Fixed ``batch_frames=N`` units report N; ``batch_frames="auto"``
        units report the adaptive value they converged to.  ``None`` when
        no transport unit took part (local units have no frames to
        batch).
        """
        out: Dict[str, int] = {}
        for name, unit in self._all_units.items():
            width = getattr(unit, "effective_batch_frames", None)
            if width is not None:
                out[name] = int(width)
        return out or None

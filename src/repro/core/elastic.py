"""Elastic scaling: unit join/leave, node-failure handling, mesh rebuild.

The paper reprograms the FPGA with different accelerator counts and the
scheduler just keeps working with whatever units exist.  This module
carries that property across two granularities:

* **Unit level** (:class:`ElasticEvent`, :class:`ElasticSchedule`) — a
  timeline of compute units joining or leaving *mid-run*.
  :meth:`~repro.core.runtime.HeteroRuntime.parallel_for` consumes a
  schedule under :class:`~repro.core.runtime.SimulatedClock`: when a
  unit leaves, its in-flight chunk is requeued and re-issued to a
  surviving unit (exact-once coverage is an invariant the tests pin);
  when a unit joins, it starts stealing chunks immediately, exactly as a
  freshly programmed FPGA block enters the paper's loop.  Every event is
  recorded in the run's :class:`~repro.core.interrupts.RunReport`.
* **Mesh level** (:class:`ElasticMeshManager`, :class:`RescalePlan`) —
  the pod-scale analogue: when a host (8 chips) or a whole slice dies
  mid-run, the job must (1) detect it, (2) compute the largest
  still-coherent mesh from surviving hardware, (3) re-shard the latest
  checkpoint onto the new mesh, and (4) resume — rather than sitting in
  a barrier forever.

The two meet in :meth:`ElasticSchedule.from_mesh`: bind runtime units to
the mesh's failure domains (hosts) and a fault timeline, and device
failures tracked by the mesh manager become unit-leave events for the
scheduler — the registry hook the ROADMAP names.

This module is deliberately runtime-agnostic: it reasons over abstract
device inventories so it is unit-testable on CPU, and `launch/train.py`
wires it to real failure signals (heartbeat timeouts / NCCL-style error
callbacks in a real deployment; simulated fault injection in tests).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "DeviceHealth",
    "RescalePlan",
    "ElasticMeshManager",
    "ElasticEvent",
    "ElasticSchedule",
]


@dataclass(frozen=True)
class ElasticEvent:
    """One unit joining or leaving the run at virtual time ``t``.

    ``t`` is *run-relative*: seconds of virtual time after the run's
    first dispatch, so the same schedule replays identically on a
    runtime whose clock has already advanced through earlier runs.
    ``kind``/``speed`` describe the joining unit (same semantics as
    :class:`~repro.core.runtime.UnitSpec`); both are ignored for leaves.
    """

    t: float
    action: str                    # "join" | "leave"
    unit: str
    kind: str = "cc"
    speed: Optional[float] = None

    def __post_init__(self) -> None:
        if self.action not in ("join", "leave"):
            raise ValueError(f"action must be join|leave, got {self.action!r}")
        if self.t < 0:
            raise ValueError(f"event time must be >= 0, got {self.t}")


class ElasticSchedule:
    """An ordered timeline of :class:`ElasticEvent`s for one run.

    Build directly::

        sched = ElasticSchedule()
        sched.leave(0.5, "cc0")
        sched.join(0.8, "cc9", kind="cc", speed=2e3)

    or derive unit events from mesh-level failures via :meth:`from_mesh`.
    """

    def __init__(self, events: Sequence[ElasticEvent] = ()) -> None:
        self._events: List[ElasticEvent] = list(events)

    def leave(self, t: float, unit: str) -> "ElasticSchedule":
        self._events.append(ElasticEvent(t=t, action="leave", unit=unit))
        return self

    def join(
        self, t: float, unit: str, *, kind: str = "cc", speed: Optional[float] = None
    ) -> "ElasticSchedule":
        self._events.append(
            ElasticEvent(t=t, action="join", unit=unit, kind=kind, speed=speed)
        )
        return self

    @property
    def events(self) -> List[ElasticEvent]:
        """Events in time order (stable for ties: insertion order)."""
        return sorted(self._events, key=lambda e: e.t)

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self):
        return iter(self.events)

    def merge(self, other: "ElasticSchedule") -> "ElasticSchedule":
        """A new schedule holding both timelines (time-sorted on read).

        The fleet layer composes membership from independent sources —
        a failure trace's crashes/leaves and an autoscaler's joins — and
        each source builds its own schedule; ``merge`` is how they become
        one run timeline without either source knowing about the other.
        """
        return ElasticSchedule(self._events + list(other._events))

    @classmethod
    def from_mesh(
        cls,
        manager: "ElasticMeshManager",
        bindings: Mapping[str, int],
        faults: Sequence[Tuple[float, int]],
        joins: Sequence[ElasticEvent] = (),
    ) -> "ElasticSchedule":
        """Unit-leave events from mesh failure domains.

        ``bindings`` maps unit name -> host id; ``faults`` is a timeline
        of ``(t, device_id)`` failures applied to ``manager`` (so its
        health book and any later :meth:`ElasticMeshManager.plan` stay
        consistent with the run).  A device failure takes out its whole
        host, so every unit bound to that host leaves at the fault time.
        ``joins`` are appended verbatim — replacement capacity admitted
        by the operator.
        """
        sched = cls()
        departed: set = set()
        for t, device_id in sorted(faults):
            before = set(manager.lost_ids)
            manager.mark_failed(device_id)
            lost_hosts = {
                manager.host_of(d) for d in manager.lost_ids if d not in before
            }
            for unit, host in bindings.items():
                if host in lost_hosts and unit not in departed:
                    departed.add(unit)
                    sched.leave(t, unit)
        for ev in joins:
            sched._events.append(ev)
        return sched


@dataclass
class DeviceHealth:
    device_id: int
    host_id: int
    healthy: bool = True
    consecutive_misses: int = 0


@dataclass(frozen=True)
class RescalePlan:
    """What to do after a failure: the new mesh and bookkeeping deltas."""

    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    healthy_devices: Tuple[int, ...]
    lost_devices: Tuple[int, ...]
    # data-parallel degree changed ⇒ global batch / accumulation must adapt
    dp_scale: float
    needs_reshard: bool

    @property
    def new_device_count(self) -> int:
        return int(math.prod(self.new_shape))


class ElasticMeshManager:
    """Tracks device health and produces :class:`RescalePlan`s.

    Mesh policy: the model axis is sacred (TP degree is baked into layouts
    and kernel block shapes), so failures are absorbed by shrinking the
    data/pod axes to the largest size that the surviving-device count
    supports with the model axis intact.  This matches production practice:
    you lose DP replicas, never TP shards.
    """

    def __init__(
        self,
        shape: Sequence[int],
        axis_names: Sequence[str],
        *,
        model_axis: str = "model",
        miss_threshold: int = 3,
        host_size: int = 8,
    ) -> None:
        if len(shape) != len(axis_names):
            raise ValueError("shape/axis_names length mismatch")
        self.shape = tuple(shape)
        self.axis_names = tuple(axis_names)
        self.model_axis = model_axis
        self.miss_threshold = miss_threshold
        self.host_size = host_size
        n = math.prod(self.shape)
        self._devices: Dict[int, DeviceHealth] = {
            i: DeviceHealth(device_id=i, host_id=i // host_size) for i in range(n)
        }

    # -- health feed -------------------------------------------------------
    def heartbeat(self, device_id: int) -> None:
        d = self._devices[device_id]
        d.consecutive_misses = 0
        d.healthy = True

    def miss(self, device_id: int) -> None:
        d = self._devices[device_id]
        d.consecutive_misses += 1
        if d.consecutive_misses >= self.miss_threshold:
            self.mark_failed(device_id)

    def mark_failed(self, device_id: int) -> None:
        """A chip failure takes out its host (standard TPU failure domain)."""
        host = self._devices[device_id].host_id
        for d in self._devices.values():
            if d.host_id == host:
                d.healthy = False

    def host_of(self, device_id: int) -> int:
        return self._devices[device_id].host_id

    @property
    def healthy_ids(self) -> List[int]:
        return sorted(d.device_id for d in self._devices.values() if d.healthy)

    @property
    def lost_ids(self) -> List[int]:
        return sorted(d.device_id for d in self._devices.values() if not d.healthy)

    # -- planning ------------------------------------------------------------
    def plan(self) -> Optional[RescalePlan]:
        """None if the current mesh is intact; otherwise the rescale plan."""
        healthy = self.healthy_ids
        total = math.prod(self.shape)
        if len(healthy) == total:
            return None
        model_idx = self.axis_names.index(self.model_axis)
        model_deg = self.shape[model_idx]
        if len(healthy) < model_deg:
            raise RuntimeError(
                f"only {len(healthy)} healthy devices < model degree {model_deg}; "
                "job cannot continue"
            )
        usable_groups = len(healthy) // model_deg
        # Distribute surviving DP capacity over the non-model axes, shrinking
        # the outermost (pod) axis first — whole-slice failures are the norm.
        non_model = [
            (i, s) for i, s in enumerate(self.shape) if i != model_idx
        ]
        new_shape = list(self.shape)
        remaining = usable_groups
        # greedy: keep inner axes as large as possible
        for i, s in non_model:  # outermost first
            inner = math.prod(ns for j, ns in non_model if j > i)
            new_shape[i] = max(1, min(s, remaining // max(inner, 1)))
        # fix rounding: recompute inner-most axis to fit exactly
        def dp_degree(shape: List[int]) -> int:
            return math.prod(s for i, s in enumerate(shape) if i != model_idx)

        while dp_degree(new_shape) > usable_groups:
            for i, _ in non_model:
                if new_shape[i] > 1:
                    new_shape[i] -= 1
                    break
        old_dp = math.prod(s for i, s in enumerate(self.shape) if i != model_idx)
        plan = RescalePlan(
            old_shape=self.shape,
            new_shape=tuple(new_shape),
            axis_names=self.axis_names,
            healthy_devices=tuple(healthy[: math.prod(new_shape)]),
            lost_devices=tuple(self.lost_ids),
            dp_scale=dp_degree(new_shape) / old_dp,
            needs_reshard=True,
        )
        return plan

    def apply(self, plan: RescalePlan) -> None:
        """Adopt the new mesh shape (after checkpoint re-shard completed)."""
        self.shape = plan.new_shape
        keep = set(plan.healthy_devices)
        self._devices = {
            i: d for i, d in self._devices.items() if i in keep
        }
